"""HLO cost analyzer: trip-count-weighted flops vs known closed forms."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_plain_matmul_flops_exact():
    M, K, N = 64, 128, 32
    co = _compile(lambda a, b: a @ b,
                  jax.ShapeDtypeStruct((M, K), jnp.float32),
                  jax.ShapeDtypeStruct((K, N), jnp.float32))
    res = hlo_cost.analyze(co.as_text())
    assert res["flops"] == pytest.approx(2 * M * K * N, rel=0.01)


def test_scan_body_multiplied_by_trip_count():
    L, D = 8, 64

    def f(xs, w):
        def body(c, x):
            return c @ w + x, ()
        c, _ = jax.lax.scan(body, xs[0], xs)
        return c.sum()

    co = _compile(jax.grad(f, argnums=1),
                  jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                  jax.ShapeDtypeStruct((D, D), jnp.float32))
    res = hlo_cost.analyze(co.as_text())
    # fwd: L dots; bwd: 2L dots (transpose wrt c and w)
    expect = 3 * L * 2 * D ** 3
    assert res["flops"] == pytest.approx(expect, rel=0.05)


def test_nested_scans_multiply():
    L1, L2, D = 4, 3, 32

    def f(w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, ()
            c2, _ = jax.lax.scan(inner, c, None, length=L2)
            return c2, ()
        c, _ = jax.lax.scan(outer, jnp.eye(D), None, length=L1)
        return c.sum()

    co = _compile(f, jax.ShapeDtypeStruct((D, D), jnp.float32))
    res = hlo_cost.analyze(co.as_text())
    assert res["flops"] == pytest.approx(L1 * L2 * 2 * D ** 3, rel=0.05)


def test_collective_bytes_nonnegative_and_traffic_sane():
    co = _compile(lambda a: (a * 2).sum(),
                  jax.ShapeDtypeStruct((1024,), jnp.float32))
    res = hlo_cost.analyze(co.as_text())
    assert res["collective_bytes"] == 0.0
    assert 0 < res["traffic_bytes"] < 1e6
