"""End-to-end system behaviour: the full PlexRL stack (Router + HRRS
executor + StateManager + WPGs + RLController) running real model execution
on CPU, including context switching, fault tolerance, and migration."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.core.cluster import PlexCluster
from repro.core.controller import JobConfig
from repro.core.state_manager import Tier

TINY = (("num_layers", 2), ("d_model", 32), ("num_heads", 4),
        ("num_kv_heads", 2), ("head_dim", 8), ("d_ff", 64),
        ("vocab_size", 64), ("tie_embeddings", True))


def _job(job_id, seed, steps=2):
    return JobConfig(job_id=job_id, model_name="qwen2-0.5b", steps=steps,
                     batch_size=4, group_size=2, max_new_tokens=4,
                     seq_len=24, overrides=TINY, seed=seed)


@pytest.fixture(scope="module")
def cluster():
    c = PlexCluster(n_groups=1)
    c.add_job(_job("jobA", 1))
    c.add_job(_job("jobB", 2))
    c.run(interleave=True)
    return c


def test_two_jobs_complete_all_steps(cluster):
    for job in ("jobA", "jobB"):
        ctl = cluster.controllers[job]
        assert len(ctl.metrics_log) == ctl.cfg.steps
        assert len(ctl.reward_log) == ctl.cfg.steps
        for m in ctl.metrics_log:
            assert not np.isnan(m["loss"])


def test_multiplexing_context_switches_happened(cluster):
    # two jobs share one group: the router must have swapped state
    assert cluster.router.executor.switch_count >= 1
    assert len(cluster.router.switch_log) >= 1
    ev = cluster.router.switch_log[-1]
    assert ev["t_offload"] >= 0.0 and ev["t_load"] >= 0.0


def test_per_wpg_serial_order(cluster):
    # executor never ran two ops on one group concurrently: the group lock's
    # holder is empty after drain and all tasks are COMPLETED
    from repro.core.scheduler.executor import State
    assert all(t.state == State.COMPLETED
               for t in cluster.router.executor.tasks.values())
    for lock in cluster.router.executor.locks.values():
        assert lock.holder is None


def test_billing_attributes_busy_time(cluster):
    for job, rec in cluster.billing.items():
        assert rec.busy_seconds > 0.0
        assert rec.steps == 2
        assert rec.gpu_seconds_per_step() > 0.0


def test_hrrs_setup_estimates_fed_back(cluster):
    # after switches, HRRS setup costs reflect measured bandwidths
    assert cluster.router.executor.t_load >= 0.0
    sm = cluster.router.state_managers[0]
    assert sm.job_bytes("jobA:jobA-train") > 0


def test_checkpoint_failure_restore(tmp_path):
    c = PlexCluster(n_groups=1)
    c.add_job(_job("jobC", 3, steps=1))
    c.run()
    paths = c.checkpoint_all(str(tmp_path))
    before = c.router.wpgs["jobC-train"].params()
    lost = c.fail_node(0)
    assert lost, "failure should drop device state"
    c.restore_all(paths)
    after = c.router.wpgs["jobC-train"].params()
    np.testing.assert_array_equal(
        np.asarray(before["ln_f"]["scale"], np.float32),
        np.asarray(after["ln_f"]["scale"], np.float32))


def test_migration_between_groups():
    c = PlexCluster(n_groups=2)
    c.add_job(_job("jobD", 4, steps=1), group_id=0)
    c.run()
    moved = c.migrate_job("jobD", 0, 1)
    assert moved > 0
    wpg = c.router.wpgs["jobD-train"]
    assert c.router.group_of["jobD-train"] == 1
    params = wpg.params()           # gatherable from the new node
    assert params["embed"]["embedding"].shape[0] == 64


def test_weight_sync_between_deployments():
    c = PlexCluster(n_groups=1)
    ctl = c.add_job(_job("jobE", 5, steps=1))
    c.run()
    # create a rollout deployment and sync trained weights into it
    spec = api.DeploymentSpec(deployment_id="jobE-rollout", job_id="jobE",
                              model_name="qwen2-0.5b", role="rollout",
                              overrides=TINY)
    rollout_wpg = c.router.create_deployment(spec, group_id=0)
    train_wpg = c.router.wpgs["jobE-train"]
    res = train_wpg._op_sync_weights(rollout_wpg)
    assert res["synced_bytes"] > 0
    a = train_wpg.params()["embed"]["embedding"]
    b = rollout_wpg.params()["embed"]["embedding"]
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))


def test_host_optimizer_offload_path():
    """ZeRO-offload: grads computed on device, optimizer step on host state."""
    import jax
    from repro.configs import ShapeSpec
    c = PlexCluster(n_groups=1)
    c.add_job(_job("jobF", 6, steps=1))
    c.run()
    wpg = c.router.wpgs["jobF-train"]
    batch = wpg.model.dummy_batch(jax.random.PRNGKey(0),
                                  ShapeSpec("t", "train", 16, 4))
    out = wpg._op_forward_backward(batch)
    before = np.asarray(wpg.params()["ln_f"]["scale"], np.float32).copy()
    res = wpg._op_optim_step(out["grads"], host=True)
    # the step counter is shared with the device optimizer's canonical
    # `opt/step` entry: the job already took one device step in c.run()
    assert res["host"] and res["step"] >= 1
    after = np.asarray(wpg.params()["ln_f"]["scale"], np.float32)
    assert not np.array_equal(before, after)


def test_async_one_step_staleness():
    """§6.3: rollout k+1 may start before update k completes; sync enforced
    via prerequisites. All steps must still complete and train."""
    cfg = JobConfig(job_id="jobAsync", model_name="qwen2-0.5b", steps=3,
                    batch_size=4, group_size=2, max_new_tokens=4, seq_len=24,
                    overrides=TINY, seed=9, async_staleness=1)
    c = PlexCluster(n_groups=1)
    c.add_job(cfg)
    c.run()
    ctl = c.controllers["jobAsync"]
    assert len(ctl.metrics_log) == 3
    assert len(ctl.reward_log) == 3
    for m in ctl.metrics_log:
        assert not np.isnan(m["loss"])
