"""Equivalence suite for the incremental HRRS admission index.

The contract under test (ISSUE 2 tentpole): at EVERY admission point, the
kinetic-tournament index (``TaskExecutor.pick_next``) returns the exact same
next request as Algorithm 1's full re-score (``TaskExecutor.pick_next_full``
over the runnable pool) — including under score ties, prerequisite chains,
failures, setup-cost recalibration, resident-job (switch-bit) changes, and
``VirtualClock`` jumps that cross score-crossing boundaries.

Randomisation goes through the ``hypothesis``/``_hypothesis_compat`` shim so
the suite runs (deterministically) with or without hypothesis installed.
"""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.scheduler import hrrs
from repro.core.scheduler.admission_index import (GroupAdmissionIndex,
                                                  KineticTournament)
from repro.core.scheduler.executor import State, TaskExecutor, VirtualClock


# --------------------------------------------------------------- helpers
def _brute_pick(entries, t, switch, setup):
    """Reference argmax with Algorithm 1's exact key over raw entries."""
    if not entries:
        return None
    best = min(entries, key=lambda e: (
        -hrrs.queued_score(e[3], e[2], t, switch, setup), e[2], e[0]))
    return best[0]


def _oracle_req(ex, group_id):
    task = ex.pick_next_full(group_id)
    return None if task is None else task.request.req_id


def _indexed_req(ex, group_id):
    task = ex.pick_next(group_id)
    return None if task is None else task.request.req_id


def _assert_equiv(ex, groups, ctx):
    for g in groups:
        want = _oracle_req(ex, g)
        got = _indexed_req(ex, g)
        assert got == want, (f"group {g}: index picked {got}, "
                             f"Algorithm 1 picked {want} ({ctx})")


# ------------------------------------------- kinetic tournament vs brute
def test_tournament_winner_flips_at_crossing():
    """Deterministic crossing geometry: a steep latecomer overtakes the
    incumbent once its line crosses; the certificate must fire."""
    kt = KineticTournament(switch=False, setup=0.0)
    kt.insert(1, "a", arrival=0.0, exec_time=1.0, t=0.0)      # steep
    kt.insert(2, "a", arrival=0.0, exec_time=100.0, t=0.0)    # shallow
    # same arrival: the steeper line wins for all t > 0 (t=0 ties -> req 1)
    assert kt.peek(0.0).req_id == 1
    assert kt.peek(50.0).req_id == 1

    kt2 = KineticTournament(switch=False, setup=0.0)
    kt2.insert(1, "a", arrival=0.0, exec_time=10.0, t=0.0)
    kt2.insert(2, "a", arrival=40.0, exec_time=1.0, t=0.0)
    # before req 2 arrives, req 1 leads; then 1 + t/10 vs 1 + (t - 40)
    # cross at t = 400/9 ~ 44.44 and req 2 leads forever
    assert kt2.peek(30.0).req_id == 1
    assert kt2.peek(44.0).req_id == 1
    assert kt2.peek(45.0).req_id == 2
    assert kt2.peek(1000.0).req_id == 2


@settings(max_examples=40)
@given(st.data())
def test_tournament_matches_brute_force(data):
    """Random insert/remove/advance mix: the tournament's peek equals a
    brute-force argmax at every probe time, with heavy ties (integer grids)
    and multiplicative time jumps."""
    switch = data.draw(st.booleans())
    setup = data.draw(st.sampled_from([0.0, 1.0, 7.5]))
    kt = KineticTournament(switch=switch, setup=setup)
    live = {}
    t = 0.0
    next_id = 1
    for _ in range(data.draw(st.integers(min_value=10, max_value=60))):
        action = data.draw(st.sampled_from(
            ["insert", "insert", "insert", "remove", "jump", "crawl"]))
        if action == "insert":
            arrival = t - float(data.draw(st.integers(0, 8)))
            exec_time = float(data.draw(st.sampled_from(
                [0.5, 1.0, 1.0, 2.0, 4.0, 16.0])))
            kt.insert(next_id, "a", arrival, exec_time, t)
            live[next_id] = (next_id, "a", arrival, exec_time)
            next_id += 1
        elif action == "remove" and live:
            victim = data.draw(st.sampled_from(sorted(live)))
            kt.remove(victim, t)
            del live[victim]
        elif action == "jump":
            t += float(data.draw(st.floats(0.0, 1000.0)))
        else:
            t += float(data.draw(st.floats(0.0, 0.5)))
        got = kt.peek(t)
        want = _brute_pick(list(live.values()), t, switch, setup)
        assert (got.req_id if got else None) == want, (t, sorted(live))


# ----------------------------------------- executor-level property test
@settings(max_examples=30)
@given(st.data())
def test_index_equals_algorithm1_at_every_admission_point(data):
    """Randomised workloads through the REAL wired path: submissions with
    prereqs (incl. not-yet-submitted ones), starts/finishes/failures,
    setup-cost recalibration, and VirtualClock jumps — after every event
    the indexed pick must equal the full Algorithm-1 re-score, per group."""
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, policy="hrrs")
    n_groups = data.draw(st.integers(1, 2))
    groups = list(range(n_groups))
    jobs = [f"job{j}" for j in range(data.draw(st.integers(1, 4)))]
    next_id = 1
    running = {g: [] for g in groups}

    for step in range(data.draw(st.integers(10, 50))):
        action = data.draw(st.sampled_from(
            ["submit", "submit", "submit", "start", "finish", "fail",
             "advance", "big_jump", "recalibrate"]))
        if action == "submit":
            prereqs = ()
            if data.draw(st.booleans()) and next_id > 1:
                p = data.draw(st.integers(1, next_id - 1))
                prereqs = (p,)
            elif data.draw(st.booleans()):
                # forward reference: prereq submitted later (or never) —
                # _ready ignores unknown ids until they appear
                prereqs = (next_id + data.draw(st.integers(1, 3)),)
            # ties on purpose: exec times and wait offsets on small grids
            exec_time = float(data.draw(st.sampled_from(
                [0.5, 1.0, 1.0, 2.0, 2.0, 5.0])))
            arrival = clock.now() - float(data.draw(st.integers(0, 4)))
            g = data.draw(st.sampled_from(groups))
            ex.submit(hrrs.Request(req_id=next_id,
                                   job_id=data.draw(st.sampled_from(jobs)),
                                   op="forward", exec_time=exec_time,
                                   arrival_time=arrival),
                      g, prerequisites=prereqs)
            next_id += 1
        elif action == "start":
            g = data.draw(st.sampled_from(groups))
            task = ex.pick_next(g)
            assert (None if task is None else task.request.req_id) == \
                _oracle_req(ex, g), f"step {step}: pre-start divergence"
            if task is not None and ex.try_start(task):
                running[g].append(task)
        elif action in ("finish", "fail"):
            g = data.draw(st.sampled_from(groups))
            if running[g]:
                task = running[g].pop(0)
                ex.finish(task, error="injected" if action == "fail"
                          else None)
        elif action == "advance":
            clock.advance(float(data.draw(st.floats(0.0, 2.0))))
        elif action == "big_jump":
            # cross score-crossing boundaries in one hop
            clock.advance(float(data.draw(st.floats(50.0, 5000.0))))
        else:
            g = data.draw(st.sampled_from(groups))
            ex.set_setup_costs(g, float(data.draw(st.floats(0.0, 10.0))),
                               float(data.draw(st.floats(0.0, 10.0))))
        _assert_equiv(ex, groups, f"step {step} after {action}")

    # drain everything still runnable and keep checking on the way out
    for g in groups:
        for task in running[g]:
            ex.finish(task)
        while True:
            _assert_equiv(ex, groups, "drain")
            task = ex.pick_next(g)
            if task is None or not ex.try_start(task):
                break
            ex.finish(task)
            clock.advance(0.25)


def test_time_jump_across_crossing_changes_pick_consistently():
    """A deterministic boundary case: the pending pool's argmax flips when a
    VirtualClock jump crosses the score-crossing point; index and oracle
    must flip together (this is the kinetic certificate doing its job)."""
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, policy="hrrs")
    ex.submit(hrrs.Request(req_id=1, job_id="a", op="f", exec_time=10.0,
                           arrival_time=0.0), 0)
    ex.submit(hrrs.Request(req_id=2, job_id="a", op="f", exec_time=1.0,
                           arrival_time=40.0), 0)
    clock.advance(41.0)
    # keep arrival <= now for req 2; crossing at t = 400/9 ~ 44.44
    for t in (41.0, 44.0, 44.4, 44.5, 45.0, 1000.0):
        if clock.now() < t:
            clock.advance(t - clock.now())
        assert _indexed_req(ex, 0) == _oracle_req(ex, 0), t
    assert _indexed_req(ex, 0) == 2  # the steep latecomer overtook


def test_switch_bit_changes_via_resident_job():
    """Resident-job changes re-parameterise whole buckets (the switch bit);
    the two-tournament design must track the oracle through a full
    multi-job drain with nonzero setup costs."""
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, t_load=3.0, t_offload=2.0, policy="hrrs")
    ex.set_setup_costs(0, 3.0, 2.0)
    for i in range(12):
        ex.submit(hrrs.Request(req_id=i + 1, job_id=f"job{i % 3}", op="f",
                               exec_time=1.0 + (i % 4),
                               arrival_time=clock.now()), 0)
        clock.advance(0.5)
    order = []
    while True:
        assert _indexed_req(ex, 0) == _oracle_req(ex, 0)
        task = ex.pick_next(0)
        if task is None:
            break
        ex.try_start(task)   # flips resident_job -> switch bits
        order.append(task.request.req_id)
        ex.finish(task)
        clock.advance(1.0)
    assert sorted(order) == list(range(1, 13))


def test_prereq_lifecycle_keeps_index_membership_exact():
    """Index membership must mirror the runnable set through the full
    prerequisite lifecycle: blocked on QUEUED, released by COMPLETED,
    frozen by FAILED, and revoked when a forward-referenced prereq is
    finally submitted."""
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, policy="hrrs")

    def req(i, job="a", e=1.0):
        return hrrs.Request(req_id=i, job_id=job, op="f", exec_time=e,
                            arrival_time=clock.now())

    ex.submit(req(1), 0)
    ex.submit(req(2), 0, prerequisites=(1,))       # blocked on QUEUED 1
    assert _indexed_req(ex, 0) == _oracle_req(ex, 0) == 1
    t1 = ex.pick_next(0)
    ex.try_start(t1)
    assert _indexed_req(ex, 0) == _oracle_req(ex, 0) is None
    ex.finish(t1)                                  # releases 2
    assert _indexed_req(ex, 0) == _oracle_req(ex, 0) == 2

    ex.submit(req(3), 0, prerequisites=(99,))      # unknown prereq: ready
    assert _indexed_req(ex, 0) == _oracle_req(ex, 0)
    ex.submit(req(99, e=0.25), 0)                  # now known + QUEUED:
    assert _indexed_req(ex, 0) == _oracle_req(ex, 0)   # 3 must drop out
    # drain; a FAILED op freezes its dependents out of the index forever
    ex.submit(req(4), 0)
    t = ex.pick_next(0)
    while t is not None:
        ex.try_start(t)
        err = "boom" if t.request.req_id == 99 else None
        ex.finish(t, error=err)
        assert _indexed_req(ex, 0) == _oracle_req(ex, 0)
        clock.advance(0.5)
        t = ex.pick_next(0)
    # 3's prereq FAILED -> never admitted by either path
    assert ex.tasks[3].state == State.QUEUED
    assert _oracle_req(ex, 0) is None and _indexed_req(ex, 0) is None


# ------------------------------------- multi-tenant priority term (PR 8)
def _brute_pick_prio(entries, t, switch, setup):
    """Reference argmax over (req_id, job, arrival, exec, priority) tuples
    with the exact Algorithm-1 key including the tenant priority weight."""
    if not entries:
        return None
    best = min(entries, key=lambda e: (
        -hrrs.queued_score(e[3], e[2], t, switch, setup, e[4]), e[2], e[0]))
    return best[0]


def test_priority_flat_level_crossing_fires():
    """The NEW event class unequal priorities introduce: a risen low-prio
    line crossing a high-prio entry's flat pre-arrival level, strictly
    before the second arrival kink. rho_a=1 (arrival 0, s=1) climbs as
    1 + t; rho_b=10 sits flat at 10 until its arrival at t=100 — the winner
    flips at t=9, far from any arrival. A certificate that only knew
    arrival kinks and the joint rising crossing would fire late and miss
    the flip."""
    kt = KineticTournament(switch=False, setup=0.0)
    kt.insert(1, "a", arrival=0.0, exec_time=1.0, t=0.0, priority=1.0)
    kt.insert(2, "b", arrival=100.0, exec_time=1.0, t=0.0, priority=10.0)
    assert kt.peek(0.0).req_id == 2     # 10 > 1
    assert kt.peek(5.0).req_id == 2     # 10 > 6
    assert kt.peek(8.9).req_id == 2
    assert kt.peek(9.5).req_id == 1     # 10.5 > 10: the riser overtook
    assert kt.peek(50.0).req_id == 1
    # after b arrives its line rises 10x as fast and retakes the lead
    # once 10*(t-100+1) > t+1, i.e. t > 991/9
    assert kt.peek(101.0).req_id == 1   # 102 > 20: not yet
    assert kt.peek(111.0).req_id == 2   # 120 > 112


def test_priority_identity_is_exact_noop():
    """priority=1.0 must produce bit-identical scores to the pre-tenancy
    formula (1.0 * x == x in IEEE754) — the default tenant's behaviour is
    unchanged, not merely close."""
    for w, e, sw, setup in ((0.0, 1.0, False, 0.0), (17.3, 2.5, True, 7.5),
                            (1e9, 1e-9, True, 3.0)):
        assert (hrrs.hrrs_score(w, e, sw, setup, 1.0)
                == hrrs.hrrs_score(w, e, sw, setup))


@settings(max_examples=40)
@given(st.data())
def test_priority_tournament_matches_brute_force(data):
    """Random insert/remove/advance mix over MIXED-priority pools (future
    arrivals included, so flat-level crossings actually occur): the
    tournament's peek equals the priority-weighted brute-force argmax at
    every probe time."""
    switch = data.draw(st.booleans())
    setup = data.draw(st.sampled_from([0.0, 1.0, 7.5]))
    kt = KineticTournament(switch=switch, setup=setup)
    live = {}
    t = 0.0
    next_id = 1
    for _ in range(data.draw(st.integers(min_value=10, max_value=60))):
        action = data.draw(st.sampled_from(
            ["insert", "insert", "insert", "remove", "jump", "crawl"]))
        if action == "insert":
            # arrivals both behind and AHEAD of now: the pre-arrival flat
            # segment is where the new crossing class lives
            arrival = t + float(data.draw(st.integers(-8, 12)))
            exec_time = float(data.draw(st.sampled_from(
                [0.5, 1.0, 1.0, 2.0, 4.0, 16.0])))
            prio = float(data.draw(st.sampled_from(
                [0.5, 1.0, 1.0, 2.0, 4.0, 10.0])))
            kt.insert(next_id, "a", arrival, exec_time, t, priority=prio)
            live[next_id] = (next_id, "a", arrival, exec_time, prio)
            next_id += 1
        elif action == "remove" and live:
            victim = data.draw(st.sampled_from(sorted(live)))
            kt.remove(victim, t)
            del live[victim]
        elif action == "jump":
            t += float(data.draw(st.floats(0.0, 1000.0)))
        else:
            t += float(data.draw(st.floats(0.0, 0.5)))
        got = kt.peek(t)
        want = _brute_pick_prio(list(live.values()), t, switch, setup)
        assert (got.req_id if got else None) == want, (t, sorted(live))


@settings(max_examples=30)
@given(st.data())
def test_index_equals_algorithm1_under_mixed_priorities(data):
    """The acceptance-pinned property: through the REAL wired executor
    path, with each job carrying a distinct tenant priority weight, the
    indexed pick equals the full Algorithm-1 re-score
    (``pick_next_full``) after every event — the kinetic tournament stays
    a valid incremental argmax with the multiplicative tenant term on."""
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, policy="hrrs")
    n_groups = data.draw(st.integers(1, 2))
    groups = list(range(n_groups))
    jobs = [f"job{j}" for j in range(data.draw(st.integers(2, 4)))]
    prio_of = {job: float(data.draw(st.sampled_from(
        [0.5, 1.0, 2.0, 4.0, 10.0]))) for job in jobs}
    next_id = 1
    running = {g: [] for g in groups}

    for step in range(data.draw(st.integers(10, 50))):
        action = data.draw(st.sampled_from(
            ["submit", "submit", "submit", "start", "finish", "fail",
             "advance", "big_jump", "recalibrate"]))
        if action == "submit":
            prereqs = ()
            if data.draw(st.booleans()) and next_id > 1:
                prereqs = (data.draw(st.integers(1, next_id - 1)),)
            exec_time = float(data.draw(st.sampled_from(
                [0.5, 1.0, 1.0, 2.0, 2.0, 5.0])))
            arrival = clock.now() - float(data.draw(st.integers(0, 4)))
            g = data.draw(st.sampled_from(groups))
            job = data.draw(st.sampled_from(jobs))
            ex.submit(hrrs.Request(req_id=next_id, job_id=job,
                                   op="forward", exec_time=exec_time,
                                   arrival_time=arrival,
                                   priority=prio_of[job]),
                      g, prerequisites=prereqs)
            next_id += 1
        elif action == "start":
            g = data.draw(st.sampled_from(groups))
            task = ex.pick_next(g)
            assert (None if task is None else task.request.req_id) == \
                _oracle_req(ex, g), f"step {step}: pre-start divergence"
            if task is not None and ex.try_start(task):
                running[g].append(task)
        elif action in ("finish", "fail"):
            g = data.draw(st.sampled_from(groups))
            if running[g]:
                task = running[g].pop(0)
                ex.finish(task, error="injected" if action == "fail"
                          else None)
        elif action == "advance":
            clock.advance(float(data.draw(st.floats(0.0, 2.0))))
        elif action == "big_jump":
            clock.advance(float(data.draw(st.floats(50.0, 5000.0))))
        else:
            g = data.draw(st.sampled_from(groups))
            ex.set_setup_costs(g, float(data.draw(st.floats(0.0, 10.0))),
                               float(data.draw(st.floats(0.0, 10.0))))
        _assert_equiv(ex, groups, f"step {step} after {action} (prio)")

    for g in groups:
        for task in running[g]:
            ex.finish(task)
        while True:
            _assert_equiv(ex, groups, "drain (prio)")
            task = ex.pick_next(g)
            if task is None or not ex.try_start(task):
                break
            ex.finish(task)
            clock.advance(0.25)


def test_priority_ages_faster_but_never_starves():
    """A priority-4 job's requests overtake an equal-arrival default-tenant
    request, yet the default request still wins eventually over a LATER
    high-priority arrival (positive slope = starvation-freedom)."""
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, policy="hrrs")
    ex.submit(hrrs.Request(req_id=1, job_id="be", op="f", exec_time=2.0,
                           arrival_time=0.0, priority=1.0), 0)
    ex.submit(hrrs.Request(req_id=2, job_id="vip", op="f", exec_time=2.0,
                           arrival_time=0.0, priority=4.0), 0)
    clock.advance(1.0)
    assert _indexed_req(ex, 0) == _oracle_req(ex, 0) == 2  # vip ages 4x
    # a long-waiting default request beats a FRESH vip arrival: its line
    # kept climbing while the vip's starts back at its intercept
    clock.advance(1000.0)
    ex.submit(hrrs.Request(req_id=3, job_id="vip", op="f", exec_time=2.0,
                           arrival_time=clock.now(), priority=4.0), 0)
    t = ex.pick_next(0)
    ex.try_start(t)
    assert t.request.req_id == 2
    ex.finish(t)
    assert _indexed_req(ex, 0) == _oracle_req(ex, 0) == 1  # not starved


# ------------------------------------------------- scoring purity (hrrs)
def test_schedule_is_side_effect_free():
    """hrrs.schedule must not mutate its input Requests: the index and the
    oracle score the same pool objects without interference."""
    reqs = [hrrs.Request(req_id=i, job_id=f"j{i % 2}", op="f",
                         exec_time=1.0 + i, arrival_time=float(i),
                         score=123.456) for i in range(6)]
    snapshots = [(r.score, r.arrival_time, r.exec_time, r.running,
                  r.remaining_time) for r in reqs]
    hrrs.schedule(None, None, reqs, now=50.0, current_job="j0",
                  t_load=2.0, t_offload=1.0)
    after = [(r.score, r.arrival_time, r.exec_time, r.running,
              r.remaining_time) for r in reqs]
    assert after == snapshots
    # queued_score/score_request agree with the legacy formula
    for r in reqs:
        for cur in ("j0", "j1", None):
            setup = 3.0
            switch = r.job_id != cur
            t_req = max(r.exec_time + (setup if switch else 0.0), 1e-9)
            legacy = (max(0.0, 50.0 - r.arrival_time) + t_req) / t_req
            assert hrrs.score_request(r, 50.0, cur, setup) == legacy


def test_group_index_pick_empty_and_single():
    idx = GroupAdmissionIndex()
    assert idx.pick(0.0, None) is None
    idx.insert(7, "job", 0.0, 1.0, 0.0)
    assert idx.pick(1.0, None) == 7
    assert idx.remove(7, 1.0)
    assert not idx.remove(7, 1.0)
    assert idx.pick(2.0, None) is None
    assert len(idx) == 0


def test_certificates_are_finite_or_inf():
    """Degenerate geometry (identical lines, zero exec, huge arrivals) must
    not produce NaN certificates."""
    kt = KineticTournament(switch=True, setup=0.0)
    kt.insert(1, "a", 0.0, 0.0, 0.0)      # exec 0 -> clamped 1e-9 slope
    kt.insert(2, "a", 0.0, 0.0, 0.0)      # identical twin: pure tie-break
    kt.insert(3, "a", 1e12, 1e-9, 0.0)    # far-future arrival kink
    for t in (0.0, 1.0, 1e6, 1e12, 2e12):
        e = kt.peek(t)
        assert e is not None
        assert all(not math.isnan(x) for x in kt.exp)
    assert kt.peek(2e12).req_id in (1, 2, 3)
