"""Cluster simulator invariants + policy ordering (Fig. 8 semantics)."""
import numpy as np
import pytest

from repro.core.simulator import ClusterSim, SimJob, run_policy_comparison
from repro.core.traces import (PAPER_TABLE2, PhaseProfile, bubble_ratio,
                               paper_table2_trace, synthetic_job_mix)


def test_table2_bubble_ratios_match_paper():
    assert bubble_ratio(PAPER_TABLE2["7B"]) == pytest.approx(0.8010, abs=2e-3)
    assert bubble_ratio(PAPER_TABLE2["30B"]) == pytest.approx(0.7067, abs=2e-3)
    assert bubble_ratio(PAPER_TABLE2["235B"]) == pytest.approx(0.8111, abs=2e-3)


def test_paper_trace_segments_cover_active_phases():
    tr = paper_table2_trace("7B")
    total_active = sum(d for _, d in tr.segments)
    e = PAPER_TABLE2["7B"]
    assert total_active == pytest.approx(
        e["compute_log_prob"] + e["update_actor"] + e["sync_weight"])
    assert tr.duty() == pytest.approx(1 - bubble_ratio(e), abs=1e-6)


def _profiles(n=12, seed=0):
    return synthetic_job_mix(n, seed=seed)


def test_simulation_conservation():
    """Every job completes all its phases; busy time == sum of durations."""
    profs = _profiles(6)
    jobs = [SimJob(f"j{i}", p, 4, arrival=float(i * 50))
            for i, p in enumerate(profs)]
    sim = ClusterSim(total_nodes=32, group_size=8, policy="spread_backfill")
    res = sim.run(jobs)
    for j in res.jobs:
        assert j.t_done >= j.arrival
        assert j.step_idx == 4
        total = sum(sum(c.values()) for c in j.cycles)
        elapsed = j.t_done - j.arrival
        assert elapsed >= total - 1e-6          # can't run faster than ideal
        # busy split matches the cycle anatomy
        shared = sum(c["compute_log_prob"] + c["update_actor"]
                     + c["sync_weight"] for c in j.cycles)
        assert j.busy_shared >= shared - 1e-6


def test_isolated_has_heavier_tail_than_shared():
    res = run_policy_comparison(_profiles(20, seed=7), steps=6,
                                arrival_rate=1 / 120.0, seed=7)
    iso = np.percentile(res["isolated"].norm_delays(), 90)
    packed = np.percentile(res["pack"].norm_delays(), 90)
    sb = np.percentile(res["spread_backfill"].norm_delays(), 90)
    assert sb <= iso + 1e-9
    assert packed <= iso + 1e-9


def test_shared_policies_reduce_makespan():
    res = run_policy_comparison(_profiles(20, seed=3), steps=6,
                                arrival_rate=1 / 120.0, seed=3)
    assert res["spread_backfill"].makespan <= res["isolated"].makespan
    assert res["pack"].makespan <= res["isolated"].makespan


def test_backfill_no_worse_than_spread():
    res = run_policy_comparison(_profiles(24, seed=5), steps=6,
                                arrival_rate=1 / 60.0, seed=5,
                                policies=("spread", "spread_backfill"))
    assert (res["spread_backfill"].makespan
            <= res["spread"].makespan + 1e-6)


def test_switch_cost_charged():
    profs = _profiles(4, seed=1)
    jobs = [SimJob(f"j{i}", p, 3, arrival=0.0) for i, p in enumerate(profs)]
    sim = ClusterSim(total_nodes=8, group_size=8, policy="pack",
                     switch_cost=5.0)
    res = sim.run(jobs)
    assert sum(j.switch_overhead for j in res.jobs) > 0.0
