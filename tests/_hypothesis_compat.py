"""Vendored property-test shim used ONLY when `hypothesis` is absent.

Provides the tiny slice of the hypothesis API this suite uses — ``given``,
``settings`` and the ``strategies`` namespace — backed by seeded
``numpy.random`` draws so runs are deterministic (the per-test seed is
derived from the test function's qualified name). No shrinking, no
database: on failure the falsifying draw is printed and the original
exception re-raised.

Import pattern in test modules::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st
"""
from __future__ import annotations

import functools
import hashlib
import inspect
from typing import Callable, Sequence

import numpy as np

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw: Callable):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class _Data:
    """Interactive draws inside the test body (``st.data()``)."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: _Strategy):
        return strategy.draw(self._rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (the used subset)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        # hypothesis bounds are inclusive on both ends
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(elements: Sequence) -> _Strategy:
        pool = list(elements)
        return _Strategy(lambda rng: pool[int(rng.integers(0, len(pool)))])

    @staticmethod
    def tuples(*elems: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in elems))

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def data() -> _Strategy:
        return _Strategy(lambda rng: _Data(rng))


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator: stores the example budget on the ``given``-wrapped test."""
    def deco(fn):
        fn._hc_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hc_max_examples", _DEFAULT_MAX_EXAMPLES)
            base = int.from_bytes(
                hashlib.sha256(fn.__qualname__.encode()).digest()[:4], "big")
            for i in range(n):
                rng = np.random.default_rng(base + i)
                drawn = [s.draw(rng) for s in strats]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception:
                    print(f"Falsifying example ({fn.__name__}, "
                          f"example {i}): {drawn!r}")
                    raise

        # hide the drawn parameters from pytest's fixture resolution (the
        # real hypothesis rewrites the signature the same way)
        wrapper.__signature__ = inspect.Signature()
        del wrapper.__wrapped__
        return wrapper
    return deco
