"""The continuous reconciliation loop (control_plane/ package): plan/realize
architecture, drift-aware re-profiling, periodic repack, and batched live
migration.

Covers:
- ``PlacementPolicy.plan_repack``: non-mutating planning, predicted
  interference deltas, the migration-cost floor (below-floor moves are
  skipped unless they vacate a group), ``apply_repack`` adoption,
- ``Router.reassign_jobs``: dependency (vacate-before-fill) ordering and
  per-move failure isolation,
- the executor's per-group realized busy-window log and the reconciler's
  realized-vs-planned occupancy-drift detection,
- the ``ClusterPlan`` declarative snapshot (versioning, diff),
- the acceptance flows: a warm job whose rollout duration doubles mid-run
  is detected, re-profiled, re-fitted, and live-migrated — billing
  conserved bit-for-bit, decision sequence replaying bit-identically under
  VirtualClock — and a scripted 3-group pressure scenario where a batched
  repack consolidates (group retired) while queue pressure sheds a job
  onto a spawned spare,
- regression: a job stuck cold (degenerate cycles) keeps a bounded cycle
  history (the ``_fold`` trim previously skipped cold jobs).
"""
import numpy as np
import pytest

from repro.core import api
from repro.core.control_plane import (ClusterPlan, DirectorConfig,
                                      PlacementDirector, Reconciler,
                                      plan_from_policy)
from repro.core.router import Router
from repro.core.scheduler import hrrs
from repro.core.scheduler.executor import TaskExecutor, VirtualClock
from repro.core.scheduler.intervals import IntervalSet
from repro.core.scheduler.placement import (JobMove, JobTrace, NodeGroup,
                                            PlacementConfig, PlacementPolicy)
from test_control_plane import _grpo_cycle, _spec, _virtual_router


def _policy(n_groups=3, horizon=400.0):
    return PlacementPolicy(
        [NodeGroup(g, 1, IntervalSet([(0.0, horizon)]))
         for g in range(n_groups)],
        PlacementConfig(horizon=horizon))


# ------------------------------------------------------------ plan_repack
def test_plan_repack_is_non_mutating_and_apply_adopts():
    pol = _policy(3)
    # two phase-compatible period-8 jobs parked on separate groups
    a = pol.place_at("jobA", JobTrace(8.0, ((6.0, 2.0),)), 0, 0.0)
    b = pol.place_at("jobB", JobTrace(8.0, ((1.0, 3.0),)), 1, 0.0)
    assert a and b
    before = {j: (p.group_id, p.shift) for j, p in pol.placed.items()}
    plan = pol.plan_repack(origin=0.0)
    # planning must not have touched the live state
    assert {j: (p.group_id, p.shift) for j, p in pol.placed.items()} == before
    # the lower-duty job consolidates onto the other's group (pack-first
    # tie-break), vacating its own — kept regardless of the gain floor
    assert len(plan.moves) == 1
    mv = plan.moves[0]
    assert mv.vacates and mv.src_group != mv.dst_group
    pol.apply_repack(plan)
    moved = pol.placed[mv.job_id]
    assert moved.group_id == mv.dst_group
    # one group now hosts both, reservations disjoint
    g = pol.group(mv.dst_group)
    assert len(g.resident) == 2


def test_plan_repack_skips_below_floor_moves():
    pol = _policy(2)
    # "noisy" and "quiet" force-pinned onto the SAME group with overlapping
    # anchors (place_at skips feasibility — the scripted drifted state)
    pol.place_at("noisy", JobTrace(8.0, ((0.0, 4.0),)), 0, 0.0)
    pol.place_at("quiet", JobTrace(8.0, ((1.0, 2.0),)), 0, 0.0)
    # an infinite floor: the interference-reducing separation moves do not
    # vacate the group (the other job stays behind), so both are skipped
    plan = pol.plan_repack(origin=0.0, min_gain=float("inf"))
    assert not plan.moves
    assert plan.skipped and all(m.gain > 0.0 for m in plan.skipped)
    assert {p.group_id for p in pol.placed.values()} == {0}
    # with a zero floor the separation happens: the higher-duty job moves
    # to the empty group carrying its predicted interference delta
    plan = pol.plan_repack(origin=0.0, min_gain=0.0)
    assert len(plan.moves) == 1
    mv = plan.moves[0]
    assert mv.job_id == "noisy" and mv.dst_group == 1 and mv.gain > 0.0
    pol.apply_repack(plan)
    assert {p.group_id for p in pol.placed.values()} == {0, 1}


def test_repack_compat_wrapper_counts_changes():
    pol = _policy(2)
    pol.place_at("jobA", JobTrace(8.0, ((6.0, 2.0),)), 0, 0.0)
    pol.place_at("jobB", JobTrace(8.0, ((1.0, 3.0),)), 1, 0.0)
    moved = pol.repack(origin=0.0)
    assert moved >= 1 and len(pol.placed) == 2
    gids = {p.group_id for p in pol.placed.values()}
    assert len(gids) == 1              # consolidated


# ------------------------------------------------------------ cluster plan
def test_cluster_plan_snapshot_and_diff():
    pol = _policy(2)
    pol.place_at("jobA", JobTrace(8.0, ((6.0, 2.0),)), 0, 0.0)
    p1 = plan_from_policy(pol, 1, 0.0)
    assert p1.groups == (0, 1)
    assert p1.assignment("jobA").group_id == 0
    pol.repack(origin=0.0)
    pol.place_at("jobB", JobTrace(8.0, ((1.0, 3.0),)), 1, 0.0)
    p2 = plan_from_policy(pol, 2, 1.0)
    d = p1.diff(p2)
    assert "jobB" in d and d["jobB"][0] is None
    assert "jobA" not in d             # unmoved by that repack


def test_director_cluster_plan_versions_on_change():
    clock, router = _virtual_router()
    director = PlacementDirector(router, DirectorConfig(horizon=200.0),
                                 initial_groups=[0])
    p1 = director.cluster_plan()
    assert isinstance(p1, ClusterPlan)
    assert director.cluster_plan().version == p1.version   # cached
    director.assign("jobA")
    p2 = director.cluster_plan()
    assert p2.version > p1.version
    assert p2.assignment("jobA") is not None and p2.assignment("jobA").once


# -------------------------------------------------------- batched realize
def test_reassign_jobs_vacate_before_fill_order():
    clock, router = _virtual_router()
    depA = router.deploy(_spec("jobA"), group_id=0)
    depB = router.deploy(_spec("jobB", "jobB-train"), group_id=1)
    for g, dep in ((0, depA), (1, depB)):
        sm = router.state_managers[g]
        wpg = router.wpgs[dep.spec.deployment_id]
        sm.register(wpg.job_prefix, {"w": np.ones((4, 4), np.float32)})
    router.ensure_group(2)
    # A fills g1, which B must vacate first (B -> g2 before A -> g1)
    moves = [JobMove("jobA", 0, 1, 0.0), JobMove("jobB", 1, 2, 0.0)]
    results = router.reassign_jobs(moves)
    assert [r[0].job_id for r in results] == ["jobB", "jobA"]
    assert all(err is None for _, _, err in results)
    assert all(moved > 0 for _, moved, _ in results)
    assert router.group_of["jobA-train"] == 1
    assert router.group_of["jobB-train"] == 2


def test_reassign_jobs_swap_cycle_and_failure_isolation():
    clock, router = _virtual_router()
    router.deploy(_spec("jobA"), group_id=0)
    router.deploy(_spec("jobB", "jobB-train"), group_id=1)
    # a pure swap is a dependency cycle: broken deterministically, both
    # moves still execute
    res = router.reassign_jobs([JobMove("jobA", 0, 1, 0.0),
                                JobMove("jobB", 1, 0, 0.0)])
    assert [r[0].job_id for r in res] == ["jobA", "jobB"]
    assert router.group_of["jobA-train"] == 1
    assert router.group_of["jobB-train"] == 0
    # one failing move must not poison the rest of the batch
    orig = router.reassign_job

    def flaky(job_id, dst, timeout=120.0):
        if job_id == "jobA":
            raise TimeoutError("quiesce timeout")
        return orig(job_id, dst, timeout=timeout)

    router.reassign_job = flaky
    res = router.reassign_jobs([JobMove("jobA", 1, 0, 0.0),
                                JobMove("jobB", 0, 1, 0.0)])
    by_job = {r[0].job_id: r for r in res}
    assert isinstance(by_job["jobA"][2], TimeoutError)
    assert by_job["jobB"][2] is None
    assert router.group_of["jobB-train"] == 1
    assert router.group_of["jobA-train"] == 1   # untouched by the failure


# ------------------------------------------- realized busy-window telemetry
def test_executor_group_busy_log_and_cursor():
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, policy="hrrs", phase_window=8)
    for i in range(1, 13):
        t = ex.submit(hrrs.Request(req_id=i, job_id="j", op="forward",
                                   exec_time=1.0, arrival_time=clock.now()),
                      group_id=0)
        assert ex.try_start(t)
        clock.advance(2.0)
        ex.finish(t)
    log = ex.group_busy_since(0, 0)
    assert len(log) == 8                       # bounded by phase_window
    seq, job, t0, t1 = log[-1]
    assert job == "j" and t1 - t0 == 2.0
    assert ex.group_busy_since(0, seq) == []   # cursor consumed everything
    ex.drop_group(0)
    assert ex.group_busy_since(0, 0) == []


def test_occupancy_drift_detection():
    """Realized busy windows landing OUTSIDE the plan's predicted windows
    must flag the group as drifted; execution matching the plan must not."""
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, policy="hrrs")
    pol = _policy(1, horizon=400.0)
    # plan says: busy [6, 8) every 8s
    pol.place_at("jobA", JobTrace(8.0, ((6.0, 2.0),)), 0, 0.0)
    cfg = DirectorConfig(repack_interval_s=10.0, min_drift_busy_s=1.0,
                         plan_overlap_min=0.5)
    rec = Reconciler(pol, cfg)

    def run_op(start, dur):
        if start > clock.now():
            clock.advance(start - clock.now())
        t = ex.submit(hrrs.Request(req_id=len(ex.tasks) + 1, job_id="jobA",
                                   op="update_actor", exec_time=dur,
                                   arrival_time=clock.now()), group_id=0)
        assert ex.try_start(t)
        clock.advance(dur)
        ex.finish(t)

    # cycle 0+1 execute exactly as planned
    run_op(6.0, 2.0)
    run_op(14.0, 2.0)
    assert rec.due(clock.now()) is False       # unanchored: pure, never due
    assert rec.check(clock.now(), ex) is None  # first observation anchors
    assert rec.occupancy_drift(ex) == []
    # the realized schedule slips: execution lands in the planned gaps
    run_op(17.0, 2.0)
    run_op(25.0, 2.0)
    clock.advance(10.0)
    assert rec.due(clock.now())
    drifted = rec.occupancy_drift(ex)
    assert drifted and drifted[0]["group"] == 0
    assert drifted[0]["overlap_ratio"] < 0.5


def test_due_is_pure_and_forced_check_keeps_cadence():
    """Regression: ``due()`` used to MUTATE ``_last_repack_t`` (merely
    asking whether a pass was due silently re-anchored the cadence) and a
    forced ``check()`` also re-anchored it, so every manual reconcile
    pushed back the next scheduled one. ``due()`` is now a pure predicate
    and only SCHEDULED (due) passes advance the clock."""
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, policy="hrrs")
    pol = _policy(1, horizon=400.0)
    pol.place_at("jobA", JobTrace(8.0, ((6.0, 2.0),)), 0, 0.0)
    rec = Reconciler(pol, DirectorConfig(repack_interval_s=10.0))
    # pure: asking (repeatedly) leaves the unanchored cadence untouched
    assert rec.due(5.0) is False
    assert rec.due(5.0) is False
    assert rec._last_repack_t is None
    # the first observation anchors and plans nothing
    assert rec.check(0.0, ex) is None
    assert rec._last_repack_t == 0.0
    # a forced pass mid-interval runs...
    assert rec.check(5.0, ex, force=True) is not None
    # ...but must NOT re-anchor: the scheduled pass at t=10 still fires
    # (the old code would have re-anchored to 5.0, making due(10.0) False)
    assert rec._last_repack_t == 0.0
    assert rec.due(10.0) is True
    rec.check(10.0, ex)
    assert rec._last_repack_t == 10.0      # the scheduled pass re-anchors
    assert rec.due(19.0) is False and rec.due(20.0) is True


# -------------------------------------------------- cold-job fold trim
def test_fold_keeps_cold_job_cycles_bounded():
    """Regression: a job that never promotes (degenerate zero-duration
    cycles make ``trace_from_cycles`` return None) used to accumulate one
    cycle dict per step forever — cold jobs must be trimmed to the same
    bounded window as warm ones."""
    clock, router = _virtual_router()
    cfg = DirectorConfig(horizon=200.0, warmup_cycles=0, cold_cycles=1,
                         drift_window=4)
    director = PlacementDirector(router, cfg, initial_groups=[0, 1])
    gid = director.assign("jobA")
    dep = router.deploy(_spec("jobA"), group_id=gid)
    for _ in range(40):
        gen = dep.generate(np.zeros((1, 2), np.int32), exec_estimate=0.0)
        upd = dep.update_actor(0, exec_estimate=0.0, after=(gen,))
        router.drain()
        gen.result(), upd.result()
        director.on_job_step("jobA")
    js = director.job_state("jobA")
    assert js.phase == "cold"                  # degenerate: never promoted
    keep = cfg.warmup_cycles + cfg.cold_cycles + max(8, cfg.drift_window)
    assert len(js.cycles) <= keep
    assert js.cycles, "cycles must still fold (only the history is bounded)"


# ------------------------------------------------ acceptance: drift e2e
def _drift_flow():
    """Cold-profile two jobs, consolidate them warm onto one group, then
    DOUBLE jobA's rollout duration mid-run (its update grows with the
    longer responses too): the reconciler must detect the phase drift,
    re-profile, re-fit — the grown cycle no longer fits beside jobB's
    dense 4-phase cycle — spawn a group, and live-migrate, all
    deterministically under VirtualClock."""
    clock, router = _virtual_router()
    director = PlacementDirector(
        router, DirectorConfig(horizon=300.0, cold_reserve_s=40.0,
                               min_groups=1, warmup_cycles=0,
                               drift_window=2, drift_ratio=1.8,
                               repack_interval_s=1e9),
        initial_groups=[0])
    deps, ordinal = {}, {}

    def add(job):
        gid = director.assign(job)
        deps[job] = router.deploy(_spec(job, f"{job}-train"), group_id=gid)

    def track(*futs):
        for f in futs:
            ordinal[f.sources[0]] = len(ordinal)
        router.drain()
        for f in futs:
            f.result()

    def step_a(rollout, update):
        gen = deps["jobA"].generate(np.zeros((1, 2), np.int32),
                                    exec_estimate=rollout)
        upd = deps["jobA"].update_actor(0, exec_estimate=update,
                                        after=(gen,))
        track(gen, upd)
        director.on_job_step("jobA")

    def step_b():
        d = deps["jobB"]
        gen = d.generate(np.zeros((1, 2), np.int32), exec_estimate=1.0)
        fwd = d.forward(0, exec_estimate=2.0, after=(gen,))
        upd = d.update_actor(0, exec_estimate=2.0, after=(fwd,))
        syn = d.sync_weights(d, exec_estimate=1.0, after=(upd,))
        track(gen, fwd, upd, syn)
        director.on_job_step("jobB")

    add("jobA")
    add("jobB")
    for step in range(6):
        if step < 2:
            step_a(6.0, 2.0)
        else:
            step_a(12.0, 3.5)           # rollout DOUBLES mid-run
        step_b()
        clock.advance(0.25)
    events = [dict(e) for e in director.events]
    states = {j: (director.job_state(j).phase, director.job_state(j).group_id,
                  director.job_state(j).trace.period)
              for j in ("jobA", "jobB")}
    order = [ordinal[t.request.req_id]
             for t in sorted(router.executor.tasks.values(),
                             key=lambda t: t.t_started)
             if t.request.req_id in ordinal]
    exec_logs = {d: [tuple(x) for x in router.wpgs[d].exec_log]
                 for d in sorted(router.wpgs)}
    plan = director.cluster_plan()
    return events, states, order, exec_logs, plan


def test_drift_detect_reprofile_refit_migrate():
    events, states, _, exec_logs, plan = _drift_flow()
    kinds = [e["event"] for e in events]
    # the doubled rollout is DETECTED against the placed trace
    drifts = [e for e in events if e["event"] == "drift"]
    assert len(drifts) == 1 and drifts[0]["job"] == "jobA"
    assert drifts[0]["old_period"] == 8.0
    assert drifts[0]["new_period"] == 15.5
    assert drifts[0]["ratio"] == pytest.approx(15.5 / 8.0)
    # RE-PROFILED + re-fitted: the drift warm_place carries the new period
    refits = [e for e in events if e["event"] == "warm_place"
              and e.get("reason") == "drift"]
    assert len(refits) == 1 and refits[0]["period"] == 15.5
    # the grown trace cannot coexist with jobB -> a group is spawned for
    # it and the job is LIVE-MIGRATED off the shared group
    drift_i = events.index(drifts[0])
    later = [e["event"] for e in events[drift_i:]]
    assert "spawn_group" in later and "migrate" in later
    migrates = [e for e in events[drift_i:] if e["event"] == "migrate"]
    assert any(m["job"] == "jobA" for m in migrates)
    # final state: jobA warm on its own group with the re-profiled trace
    assert states["jobA"][0] == "warm" and states["jobA"][2] == 15.5
    assert states["jobA"][1] != states["jobB"][1]
    assert plan.assignment("jobA").group_id == states["jobA"][1]
    # billing source of truth conserved bit-for-bit across the migrations:
    # every executed op survives in exactly one exec log with exact costs
    all_ops = [op for log in exec_logs.values() for op in log]
    assert sorted(all_ops) == sorted(
        [("generate", 6.0), ("update_actor", 2.0)] * 2
        + [("generate", 12.0), ("update_actor", 3.5)] * 4
        + [("generate", 1.0), ("forward", 2.0), ("update_actor", 2.0),
           ("sync_weights", 1.0)] * 6)
    # the consolidation-era events are still the PR-4 contract
    assert kinds.count("cold_place") == 2
    assert "retire_group" in kinds


def test_drift_flow_bit_identical_replay():
    assert _drift_flow() == _drift_flow(), \
        "reconciliation replay diverged between runs"


# --------------------------------- acceptance: 3-group pressure scenario
def test_pressure_scenario_consolidates_and_spreads():
    """Scripted 3-group scenario: a forced reconcile pass plans a BATCHED
    repack that consolidates two compatible warm jobs onto one group (the
    vacated group is retired), then queue pressure on the packed group
    sheds its worst-interfering job onto a freshly spawned spare — every
    step visible in ``director.events``."""
    clock, router = _virtual_router()
    # cooldown off: this scripted scenario sheds a job IMMEDIATELY after
    # the consolidation migrated it (the hysteresis that prevents exactly
    # that in production is covered by test_cooldown_prevents_shed_ping_pong)
    director = PlacementDirector(
        router, DirectorConfig(horizon=400.0, min_groups=1,
                               spawn_queue_depth=4, warmup_cycles=0,
                               repack_interval_s=1e9,
                               migration_cooldown_s=0.0),
        initial_groups=[0, 1, 2])
    depA = router.deploy(_spec("jobA"), group_id=0)
    depB = router.deploy(_spec("jobB", "jobB-train"), group_id=1)
    for g, dep in ((0, depA), (1, depB)):
        sm = router.state_managers[g]
        wpg = router.wpgs[dep.spec.deployment_id]
        sm.register(wpg.job_prefix, {"w": np.ones((8, 8), np.float32)})
    # warm handoff: two phase-compatible period-8 jobs parked APART (the
    # scripted drifted state a one-shot placer would never revisit)
    director.adopt_warm("jobA", JobTrace(8.0, ((6.0, 2.0),)), 0)
    director.adopt_warm("jobB", JobTrace(8.0, ((1.0, 3.0),)), 1)
    assert len(director.policy.groups) == 3

    # --- consolidation: the reconcile pass plans + realizes a batched
    # repack; jobB joins jobA (pack-first tie-break), g1 and the idle g2
    # are retired
    moves = director.reconcile_now(force=True)
    assert len(moves) == 1 and moves[0].job_id == "jobB"
    assert moves[0].vacates
    events = director.events
    kinds = [e["event"] for e in events]
    assert "repack" in kinds
    repack = next(e for e in events if e["event"] == "repack")
    assert [(m[0], m[1], m[2]) for m in repack["moves"]] == [("jobB", 1, 0)]
    assert any(e["event"] == "migrate" and e["job"] == "jobB"
               and e["src"] == 1 and e["dst"] == 0 for e in events)
    assert kinds.count("retire_group") == 2          # g1 (vacated) + g2 (idle)
    assert router.group_of["jobB-train"] == 0
    assert [g.group_id for g in director.policy.groups] == [0]

    # --- spreading: queue pressure on the packed group sheds the worst-
    # interfering job onto a spawned spare
    queued = [depB.forward(i, exec_estimate=1.0) for i in range(5)]
    director.poll()
    kinds = [e["event"] for e in director.events]
    shed = next(e for e in director.events if e["event"] == "shed")
    assert shed["src"] == 0 and shed["queue_depth"] == 5
    spawn = next(e for e in director.events
                 if e["event"] == "spawn_group"
                 and e["reason"].startswith("shed:"))
    assert shed["dst"] == spawn["group"]
    assert any(e["event"] == "migrate" and e["job"] == shed["job"]
               for e in director.events)
    ja, jb = director.job_state("jobA"), director.job_state("jobB")
    assert {ja.group_id, jb.group_id} == {0, spawn["group"]}
    # the plane still drains and the plan matches reality
    router.drain()
    for f in queued:
        assert f.result()["req_id"] > 0
    plan = director.cluster_plan()
    assert plan.assignment("jobA").group_id == ja.group_id
    assert plan.assignment("jobB").group_id == jb.group_id


def test_cooldown_prevents_shed_ping_pong():
    """The migration-cooldown hysteresis: under sustained queue pressure
    on BOTH groups, each shed lands the victim on the other deep-queued
    group, which promptly sheds it back — with the cooldown OFF the job
    ping-pongs forever; with it ON a just-migrated job is pinned until the
    cooldown expires, then becomes sheddable again."""

    def build(cooldown):
        clock, router = _virtual_router()
        director = PlacementDirector(
            router, DirectorConfig(horizon=400.0, min_groups=1,
                                   spawn_queue_depth=4, warmup_cycles=0,
                                   repack_interval_s=1e9,
                                   migration_cooldown_s=cooldown),
            initial_groups=[0, 1])
        deps = {}
        for job, gid in (("jobA", 0), ("jobB", 0), ("jobC", 1)):
            dep = router.deploy(_spec(job, f"{job}-train"), group_id=gid)
            sm = router.state_managers[gid]
            wpg = router.wpgs[dep.spec.deployment_id]
            sm.register(wpg.job_prefix, {"w": np.ones((8, 8), np.float32)})
            deps[job] = dep
        # jobA/jobB are force-pinned overlapping on g0 (the scripted
        # drifted state); both score interference 2, so the job_id
        # tie-break makes jobA the deterministic shed victim — and its
        # 2s segment FITS the 4s gaps on either group, so each shed can
        # land it on the other deep-queued group
        director.adopt_warm("jobA", JobTrace(8.0, ((0.0, 2.0),)), 0)
        director.adopt_warm("jobB", JobTrace(8.0, ((0.0, 4.0),)), 0)
        director.adopt_warm("jobC", JobTrace(8.0, ((0.0, 2.0),)), 1)
        # sustained pressure on both groups (never drained)
        for i in range(5):
            deps["jobA"].forward(i, exec_estimate=1.0)
            deps["jobC"].forward(i, exec_estimate=1.0)
        return clock, director

    def sheds_of(director, job):
        return [(e["src"], e["dst"]) for e in director.events
                if e["event"] == "shed" and e["job"] == job]

    # --- control: cooldown off — jobA bounces g0 -> g1 -> g0
    clock, director = build(0.0)
    director.poll()                   # deep g0 sheds jobA onto g1
    assert sheds_of(director, "jobA") == [(0, 1)]
    director.poll()                   # deep g1 sheds the newcomer back
    assert sheds_of(director, "jobA") == [(0, 1), (1, 0)]

    # --- cooldown on: the just-migrated job is pinned
    clock, director = build(60.0)
    director.poll()
    assert sheds_of(director, "jobA") == [(0, 1)]
    director.poll()                   # g1 deep, but jobA is cooling down
    director.poll()
    assert sheds_of(director, "jobA") == [(0, 1)]
    assert director.job_state("jobA").group_id == 1
    # past the cooldown the pressure valve reopens
    clock.advance(61.0)
    director.poll()
    sheds = sheds_of(director, "jobA")
    assert len(sheds) == 2 and sheds[1][0] == 1
    assert director.job_state("jobA").group_id == sheds[1][1]


def test_adopt_warm_releases_previous_reservation():
    """Regression (review): adopting a warm placement for a job that was
    already cold-assigned must not leave a ghost reservation on the old
    group (which would block its retirement forever)."""
    clock, router = _virtual_router()
    director = PlacementDirector(router, DirectorConfig(horizon=200.0),
                                 initial_groups=[0, 1])
    gid = director.assign("jobA")
    assert gid == 0
    director.adopt_warm("jobA", JobTrace(8.0, ((6.0, 2.0),)), 1)
    g0 = director.policy.group(0)
    assert g0.resident == []               # old cold reservation released
    assert director.policy.placed["jobA"].group_id == 1
    assert director.job_state("jobA").phase == "warm"


# ------------------------------------------------- migration rollback
def test_failed_migration_rolls_back_placement():
    """A promotion migration that fails (e.g. quiesce timeout) must leave
    the job placed — and running — on its source group."""
    clock, router = _virtual_router()
    director = PlacementDirector(
        router, DirectorConfig(horizon=300.0, cold_reserve_s=40.0,
                               warmup_cycles=0, min_groups=1),
        initial_groups=[0])
    deps = {}

    def add(job):
        gid = director.assign(job)
        deps[job] = router.deploy(_spec(job, f"{job}-train"), group_id=gid)

    def run_step(job, rollout, update):
        tails = _grpo_cycle(deps[job], rollout=rollout, update=update)
        router.drain()
        for f in tails:
            f.result()
        director.on_job_step(job)

    add("jobA")
    add("jobB")

    def boom(moves, timeout=120.0):
        return [(m, 0, RuntimeError("quiesce timeout")) for m in moves]

    router.reassign_jobs = boom
    for _ in range(2):
        run_step("jobA", 6.0, 2.0)
        run_step("jobB", 5.0, 3.0)
    failed = [e for e in director.events if e["event"] == "migrate_failed"]
    assert failed, director.events
    job = failed[0]["job"]
    js = director.job_state(job)
    assert js.phase == "warm"
    assert js.group_id == failed[0]["src"]
    assert director.policy.placed[job].group_id == failed[0]["src"]
    # the job keeps making progress on its source group
    run_step(job, 6.0, 2.0)
    assert director.job_state(job).phase == "warm"
