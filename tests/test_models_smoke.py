"""Per-architecture smoke tests: reduced same-family configs, one forward
and one GRPO train step on CPU; output shapes + no NaNs; decode path where
the family has one."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, ShapeSpec, reduced_config
from repro.models.registry import build_model
from repro.rl import grpo
from repro.train import train_state as ts

SEQ, BATCH = 16, 4


@pytest.fixture(scope="module")
def built():
    cache = {}

    def _get(arch):
        if arch not in cache:
            cfg = reduced_config(arch)
            model = build_model(cfg)
            params = model.init_params(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return _get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch, built):
    cfg, model, params = built(arch)
    batch = model.dummy_batch(jax.random.PRNGKey(1),
                              ShapeSpec("t", "train", SEQ, BATCH),
                              rl_train=False)
    logits = jax.jit(lambda p, b: model.forward(p, b))(params, batch)[0]
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, built):
    cfg, model, params = built(arch)
    state = ts.TrainState(params, __import__(
        "repro.train.optimizer", fromlist=["init"]).init(params),
        jnp.zeros((), jnp.int32))
    batch = model.dummy_batch(jax.random.PRNGKey(2),
                              ShapeSpec("t", "train", SEQ, BATCH))
    step = jax.jit(grpo.make_update_actor(model))
    new_state, metrics = step(state, batch)
    assert int(new_state.opt_state.step) == 1
    assert not bool(jnp.isnan(metrics["loss"]))
    assert float(metrics["grad_norm"]) >= 0.0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + float(jnp.sum(jnp.abs(b[0].astype(jnp.float32)
                                               - b[1].astype(jnp.float32)))),
        jax.tree.map(lambda x, y: (x, y), new_state.params, state.params),
        0.0, is_leaf=lambda x: isinstance(x, tuple))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch, built):
    cfg, model, params = built(arch)
    batch = model.dummy_batch(jax.random.PRNGKey(3),
                              ShapeSpec("t", "prefill", SEQ, 2),
                              rl_train=False)
    logits, _, cache = jax.jit(
        lambda p, b: model.forward(p, b, return_cache=True))(params, batch)
    # grow self-attn cache and take one decode step
    grown = {}
    for k, v in cache.items():
        if k in ("k", "v", "attn_k", "attn_v") and hasattr(v, "ndim") \
                and v.ndim >= 4:
            ax = v.ndim - 3
            pad = [(0, 0)] * v.ndim
            pad[ax] = (0, 4)
            grown[k] = jnp.pad(v, pad)
        else:
            grown[k] = v
    nt = jnp.argmax(logits[:, -1:], -1)
    dl, new_cache = jax.jit(
        lambda p, c, t: model.decode_step(p, c, {"tokens": t}))(params, grown, nt)
    assert dl.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(dl).any())
    assert int(new_cache["pos"]) == SEQ + 1


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "zamba2-7b", "mamba2-2.7b",
                                  "granite-moe-3b-a800m"])
def test_decode_matches_forward(arch, built):
    """Teacher-forced forward and incremental decode agree on next-token
    logits (the strongest cache-correctness check)."""
    import numpy as np
    cfg, model, params = built(arch)
    batch = model.dummy_batch(jax.random.PRNGKey(4),
                              ShapeSpec("t", "prefill", SEQ, 2),
                              rl_train=False)
    logits, _, cache = jax.jit(
        lambda p, b: model.forward(p, b, return_cache=True))(params, batch)
    grown = {}
    for k, v in cache.items():
        if k in ("k", "v", "attn_k", "attn_v") and hasattr(v, "ndim") \
                and v.ndim >= 4:
            ax = v.ndim - 3
            pad = [(0, 0)] * v.ndim
            pad[ax] = (0, 4)
            grown[k] = jnp.pad(v, pad)
        else:
            grown[k] = v
    nt = jnp.argmax(logits[:, -1:], -1)
    dl, _ = jax.jit(
        lambda p, c, t: model.decode_step(p, c, {"tokens": t}))(params, grown, nt)
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], nt], 1)
    lf = jax.jit(lambda p, b: model.forward(p, b))(params, b2)[0]
    np.testing.assert_allclose(np.asarray(dl[:, 0]), np.asarray(lf[:, -1]),
                               rtol=3e-2, atol=3e-2)
