"""Scheduler data structures + policies: unit and hypothesis property tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # vendored fallback (seeded numpy)
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.scheduler import hrrs
from repro.core.scheduler.intervals import IntervalSet
from repro.core.scheduler.placement import (
    JobTrace, NodeGroup, PlacementConfig, PlacementPolicy, best_shift,
    scheduling_cost)
from repro.core.scheduler.ring import CapacityRing
from repro.core.scheduler.segment_tree import MinSegmentTree


# ------------------------------------------------------------ segment tree
@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 100), min_size=1, max_size=64),
       st.data())
def test_segment_tree_matches_naive(values, data):
    tree = MinSegmentTree(values)
    arr = np.array(values, float)
    for _ in range(8):
        n = len(values)
        l = data.draw(st.integers(0, n - 1))
        r = data.draw(st.integers(l + 1, n))
        if data.draw(st.booleans()):
            delta = data.draw(st.integers(-5, 5))
            tree.add(l, r, delta)
            arr[l:r] += delta
        assert tree.range_min(l, r) == pytest.approx(arr[l:r].min())


# ------------------------------------------------------------ capacity ring
def test_ring_reserve_release_roundtrip():
    ring = CapacityRing(16, slots=200, slot_seconds=1.0)
    assert ring.reserve(10, 50, 10)
    assert not ring.reserve(30, 5, 7)        # only 6 left
    assert ring.reserve(30, 5, 6)
    ring.release(30, 5, 6)
    ring.release(10, 50, 10)
    assert ring.min_free(0, 200) == 16


def test_ring_wraparound():
    ring = CapacityRing(4, slots=100, slot_seconds=1.0)
    assert ring.reserve(90, 20, 3)           # wraps over the ring edge
    assert ring.free_at(95) == 1
    assert ring.free_at(5) == 1
    assert ring.free_at(15) == 4


def test_ring_periodic_reservation_atomic():
    ring = CapacityRing(4, slots=100, slot_seconds=1.0)
    assert ring.reserve_periodic(0, 10, 3, period=50)     # 2 occurrences
    assert ring.free_at(5) == 1 and ring.free_at(55) == 1
    # an overlapping periodic job must be rejected atomically
    assert not ring.reserve_periodic(5, 10, 2, period=50)
    assert ring.free_at(5) == 1                            # unchanged


# -------------------------------------------------------------- intervals
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 90), st.integers(1, 10)),
                min_size=1, max_size=12))
def test_interval_allocate_free_roundtrip(allocs):
    iv = IntervalSet([(0.0, 200.0)])
    done = []
    for s, d in allocs:
        if iv.covers(s, s + d):
            assert iv.allocate(s, s + d)
            done.append((s, s + d))
    for s, e in reversed(done):
        iv.free(s, e)
    assert iv.intervals() == [(0.0, 200.0)]


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 50), st.floats(0.5, 10)), min_size=1,
                max_size=6),
       st.floats(0, 20))
def test_simulate_insert_consistent_with_covers(segs, shift):
    iv = IntervalSet([(0.0, 30.0), (40.0, 100.0)])
    expect = all(iv.covers(a + shift, a + shift + d) for a, d in segs)
    assert iv.simulate_insert(segs, shift) == expect


def test_next_fit():
    iv = IntervalSet([(0, 10), (20, 30)])
    assert iv.next_fit(0, 5) == 0
    assert iv.next_fit(7, 5) == 20
    assert iv.next_fit(26, 5) == float("inf")


# -------------------------------------------------------------------- HRRS
def _req(i, job, exec_time, arrival):
    return hrrs.Request(req_id=i, job_id=job, op="update_actor",
                        exec_time=exec_time, arrival_time=arrival)


def test_hrrs_batches_same_job_to_amortise_setup():
    # Same-age requests: HRRS should prefer the one NOT needing a switch.
    a = _req(1, "A", 10.0, 0.0)
    b = _req(2, "B", 10.0, 0.0)
    plan = hrrs.schedule(None, None, [a, b], now=5.0, current_job="B",
                         t_load=20.0, t_offload=20.0)
    assert plan[0].request.job_id == "B"
    assert not plan[0].switched and plan[1].switched


def test_hrrs_prevents_starvation_by_ageing():
    old = _req(1, "A", 10.0, 0.0)
    new = _req(2, "B", 10.0, 999.0)
    plan = hrrs.schedule(None, None, [old, new], now=1000.0,
                         current_job="B", t_load=5.0, t_offload=5.0)
    # A has waited 1000s: ratio dominates the switch penalty
    assert plan[0].request.job_id == "A"


def test_hrrs_plan_timeline_monotone_and_charged_switches():
    reqs = [_req(i, "A" if i % 2 else "B", 5.0, float(i)) for i in range(6)]
    plan = hrrs.schedule(None, None, reqs, now=10.0, current_job=None,
                         t_load=2.0, t_offload=1.0)
    t = 10.0
    for a in plan:
        assert a.t_start >= t
        dur = a.t_end - a.t_start
        assert dur == pytest.approx(a.request.exec_time)
        t = a.t_end
    # switch count >= 1 since jobs alternate somewhere
    assert hrrs.total_switches(plan) >= 1


@settings(max_examples=40, deadline=None)
@given(st.lists(st.sampled_from(["A", "B", "C"]), min_size=2, max_size=10),
       st.floats(1, 20), st.floats(0.5, 10), st.floats(0.5, 10))
def test_hrrs_resident_job_ranks_first_on_equal_waits(jobs, exec_time,
                                                      t_load, t_offload):
    """Alg. 1 guarantee: with equal waits AND equal service times, the
    resident job's requests all precede other jobs' (the switch penalty
    inflates foreign denominators). With unequal exec times HRRN's
    shortest-first pressure can legitimately override batching."""
    rs = [_req(i, j, exec_time, 0.0) for i, j in enumerate(jobs)]
    current = "A"
    plan = hrrs.schedule(None, None, rs, 50.0, current, t_load, t_offload)
    seen_other = False
    for a in plan:
        if a.request.job_id != current:
            seen_other = True
        else:
            assert not seen_other, "resident-job request after a foreign one"


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["A", "B", "C"]),
                          st.floats(1, 20), st.floats(0, 100)),
                min_size=1, max_size=10),
       st.floats(0, 10), st.floats(0, 10))
def test_hrrs_plan_conservation(reqs, t_load, t_offload):
    """Every request appears exactly once; makespan >= total exec time."""
    rs = [_req(i, j, e, a) for i, (j, e, a) in enumerate(reqs)]
    plan = hrrs.schedule(None, None, rs, 100.0, None, t_load, t_offload)
    assert sorted(a.request.req_id for a in plan) == sorted(
        r.req_id for r in rs)
    total_exec = sum(r.exec_time for r in rs)
    assert hrrs.makespan(plan) >= 100.0 + total_exec - 1e-6


# --------------------------------------------------------------- placement
def _group(gid=0, horizon=1000.0):
    return NodeGroup(gid, 8, IntervalSet([(0.0, horizon)]))


def test_best_shift_prefers_zero_when_feasible():
    trace = JobTrace(period=100.0, segments=((60.0, 20.0),))
    fit = best_shift(trace, IntervalSet([(0.0, 1000.0)]), PlacementConfig())
    assert fit is not None and fit[0] == 0.0


def test_best_shift_dodges_occupied_window():
    free = IntervalSet([(0.0, 55.0), (80.0, 1000.0)])   # busy 55..80
    trace = JobTrace(period=100.0, segments=((60.0, 20.0),))
    fit = best_shift(trace, free, PlacementConfig())
    assert fit is not None
    delta = fit[0]
    assert free.simulate_insert(trace.segments, delta)
    assert delta >= 20.0                                 # shifted past 80


def test_scheduling_cost_eq1_monotone_in_shift():
    trace = JobTrace(period=100.0, segments=((10.0, 20.0),))
    cfg = PlacementConfig()
    costs = [scheduling_cost(trace, d, cfg) for d in (0.0, 10.0, 30.0)]
    assert costs == sorted(costs)


def test_placement_cold_then_warm_and_interference_ranking():
    groups = [_group(0), _group(1)]
    pol = PlacementPolicy(groups, PlacementConfig(horizon=1000.0))
    # resident job on group 0 active at [60, 80) each 100s cycle
    resident = JobTrace(period=100.0, segments=((60.0, 20.0),), nodes=4)
    assert pol.place_warm("res", resident) is not None
    placed_group = pol.placed["res"].group_id
    # a new job with the SAME phase should prefer the other group
    newjob = JobTrace(period=100.0, segments=((60.0, 20.0),), nodes=4)
    p = pol.place_warm("new", newjob)
    assert p is not None and p.group_id != placed_group or p.shift > 0


def test_placement_repack_returns():
    pol = PlacementPolicy([_group(0), _group(1)],
                          PlacementConfig(horizon=400.0))
    for i in range(3):
        t = JobTrace(period=100.0, segments=(((i * 13.0) % 60, 15.0),), nodes=2)
        assert pol.place_warm(f"j{i}", t) is not None
    moved = pol.repack()
    assert moved >= 0 and len(pol.placed) == 3


# ---------------------------------------------------- placement vs brute force
@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 80), st.floats(1, 15)),
                min_size=1, max_size=4),
       st.lists(st.tuples(st.floats(0, 180), st.floats(5, 40)),
                min_size=1, max_size=4))
def test_best_shift_matches_bruteforce(segs, busy):
    """best_shift finds a feasible shift with cost <= a dense grid search."""
    period = 100.0
    trace = JobTrace(period=period, segments=tuple(segs))
    free = IntervalSet([(0.0, 400.0)])
    for s, d in busy:
        if free.covers(s, s + d):
            free.allocate(s, s + d)
    cfg = PlacementConfig()
    fit = best_shift(trace, free, cfg)
    # dense grid reference
    grid_best = None
    for i in range(0, 1001):
        delta = i * (cfg.alpha * period) / 1000.0
        if free.simulate_insert(trace.segments, delta):
            c = scheduling_cost(trace, delta, cfg)
            if grid_best is None or c < grid_best:
                grid_best = c
    if grid_best is None:
        assert fit is None or free.simulate_insert(trace.segments, fit[0])
    else:
        assert fit is not None
        # candidate-shift search must not be worse than the grid (within
        # grid resolution slack)
        assert fit[1] <= grid_best + 0.05
