"""Process plane (launch/proc_plane.py): per-group worker processes.

Covers the IPC dispatch protocol end to end — spawn + ready handshake,
execute round trips with the parent-side ExecLog mirror, remote errors vs
child death (poisoned dependents either way), the liveness heartbeat,
serve-mode attach, crash → capacity-adjuster respawn with billing
conservation (the PR's robustness satellite), the StateManager
export/import halves (inline + disk-spill), cross-process migration and
weight sync with REAL jax WPGs, and the compute-overlap acceptance (procs
beat GIL-bound threads; needs ≥ 2 cores, so it runs on CI's multi-core
runners and skips on single-core boxes where overlap is physically
impossible).

Stub children use ``repro.launch.stub_wpg`` (factories cross the spawn
boundary by NAME) and never import jax, so this module stays fast; the one
real-model test uses the same tiny overrides as test_system.py.
"""
import glob
import os
import signal
import time

import numpy as np
import pytest

from repro.core import api
from repro.core.cluster import BillingRecord, PlexCluster
from repro.core.router import Router
from repro.core.state_manager import StateManager, Tier
from repro.launch import shm_transport as shmt
from repro.launch.proc_plane import GroupProcessError

STUB = "repro.launch.stub_wpg:make_busy_wpg"
CRASH_STORE = "repro.launch.stub_wpg:make_crash_store_wpg"

needs_shm = pytest.mark.skipif(
    not shmt.shm_available(), reason="no usable shared memory on this host")


def my_shm_segments():
    """Live /dev/shm segments created by THIS parent process's plane."""
    prefix = f"pxl{os.getpid()}g"
    try:
        return sorted(n for n in os.listdir(shmt.SHM_DIR)
                      if n.startswith(prefix))
    except FileNotFoundError:
        return []


def make_proc_router(n_groups=2, factory=STUB):
    r = Router(process_plane=True, proc_wpg_factory=factory)
    specs = []
    for g in range(n_groups):
        spec = api.DeploymentSpec(deployment_id=f"dep{g}", job_id=f"job{g}",
                                  model_name="stub", role="train")
        r.create_deployment(spec, group_id=g)
        specs.append(spec)
    return r, specs


# ------------------------------------------------------------ dispatch
def test_execute_roundtrip_and_log_mirror():
    r, specs = make_proc_router(n_groups=2)
    try:
        futs = [r.submit_queued_operation(
            api.make_op(s, api.Op.FORWARD, 0)) for s in specs]
        assert r.run_until_idle(timeout=120) == 2
        pids = {f.result()["pid"] for f in futs}
        # each group's ops really ran in its own OS process (≠ parent)
        assert len(pids) == 2 and os.getpid() not in pids
        for s in specs:
            log = list(r.wpgs[s.deployment_id].exec_log)
            assert len(log) == 1 and log[0][0] == "forward"
        assert not r.pending
    finally:
        r.close_processes()


def test_remote_error_poisons_dependents_child_survives():
    r, specs = make_proc_router(n_groups=1)
    try:
        bad = api.make_op(specs[0], api.Op.FORWARD, 0, fail=True)
        dep = api.make_op(specs[0], api.Op.FORWARD, 1,
                          prerequisites=(bad.req_id,))
        f_bad = r.submit_queued_operation(bad)
        f_dep = r.submit_queued_operation(dep)
        r.run_until_idle(timeout=120)
        with pytest.raises(RuntimeError, match="asked to fail"):
            f_bad.result()
        with pytest.raises(RuntimeError, match="prerequisite"):
            f_dep.result()
        # an op ERROR is not a child DEATH: the process keeps serving
        assert r.process_health() == {0: True}
        f_ok = r.submit_queued_operation(
            api.make_op(specs[0], api.Op.FORWARD, 2))
        r.run_until_idle(timeout=120)
        assert f_ok.result()["op"] == "forward"
    finally:
        r.close_processes()


def test_heartbeat_and_health():
    r, _ = make_proc_router(n_groups=1)
    try:
        rtt = r.group_procs[0].ping(timeout=30.0)
        assert rtt is not None and 0.0 <= rtt < 30.0
        assert r.process_health() == {0: True}
        telem = r.group_telemetry()
        assert telem[0]["process_alive"] is True
    finally:
        r.close_processes()
    assert r.process_health() == {}


def test_serve_mode_attach():
    r, specs = make_proc_router(n_groups=1)
    try:
        with r:
            f = r.submit_queued_operation(
                api.make_op(specs[0], api.Op.FORWARD, 0))
            assert f.wait(timeout=120)
            assert f.result()["op"] == "forward"
            # dynamic attach on a NEW group spawns its worker process
            spec2 = api.DeploymentSpec(deployment_id="dep9", job_id="job9",
                                       model_name="stub", role="train")
            r.create_deployment(spec2, group_id=5)
            f2 = r.submit_queued_operation(
                api.make_op(spec2, api.Op.FORWARD, 0))
            assert f2.wait(timeout=120)
        assert set(r.process_health()) == {0, 5}
    finally:
        r.close_processes()


# --------------------------------------------- robustness: crash mid-op
def test_worker_process_crash_respawn_and_billing_conserved():
    """The PR's robustness satellite: a worker process dying mid-op fails
    the RUNNING op, poisons its dependents, is respawned by the capacity
    adjuster on the next director poll, and billing for ops completed
    BEFORE the crash is conserved (the ExecLog mirror lives parent-side)."""
    c = PlexCluster(n_groups=1, process_plane=True, proc_wpg_factory=STUB)
    r = c.router
    spec = api.DeploymentSpec(deployment_id="dep0", job_id="job0",
                              model_name="stub", role="train")
    r.create_deployment(spec, group_id=0)
    c.billing["job0"] = BillingRecord(job_id="job0")
    try:
        ok = r.submit_queued_operation(
            api.make_op(spec, api.Op.FORWARD, 0, sleep_s=0.01))
        bad = api.make_op(spec, api.Op.FORWARD, 1, crash=True)
        f_bad = r.submit_queued_operation(bad)
        f_dep = r.submit_queued_operation(
            api.make_op(spec, api.Op.FORWARD, 2,
                        prerequisites=(bad.req_id,)))
        r.run_until_idle(timeout=120)
        assert ok.result()["seconds"] >= 0.01
        with pytest.raises(RuntimeError, match="worker process died"):
            f_bad.result()
        with pytest.raises(RuntimeError, match="prerequisite"):
            f_dep.result()
        assert r.process_health() == {0: False}
        # billing for the COMPLETED op survives the crash (mirror log)
        c._bill_from_logs()
        assert c.billing["job0"].busy_seconds >= 0.01
        billed_before = c.billing["job0"].busy_seconds
        # the capacity adjuster is the supervisor: poll respawns the group
        c.director.poll()
        assert [e for e in c.director.events
                if e["event"] == "respawn_group" and e["group"] == 0]
        assert r.process_health() == {0: True}
        # the replayed deployment serves again, and billing keeps flowing
        f2 = r.submit_queued_operation(
            api.make_op(spec, api.Op.FORWARD, 3, sleep_s=0.01))
        r.run_until_idle(timeout=120)
        assert f2.result()["op"] == "forward"
        c._bill_from_logs()
        assert c.billing["job0"].busy_seconds > billed_before
    finally:
        r.close_processes()


# -------------------------------------------------- migration transport
def test_export_import_roundtrip_with_disk_spill(tmp_path):
    """The migrate-export/import halves in isolation (no processes): host
    staging, PartitionSpec/bf16 wire encoding, and the disk-tier fallback
    for entries above max_inline_bytes (spill files consumed on import)."""
    src = StateManager(node_id="src", disk_dir=str(tmp_path / "src"))
    dst = StateManager(node_id="dst", disk_dir=str(tmp_path / "dst"))
    big = np.arange(4096, dtype=np.float32).reshape(64, 64)
    small = np.ones(8, np.float32)
    src.register("jobA:dep0", {"w": big, "b": small}, Tier.HOST)
    payload = src.export_state("jobA:dep0", max_inline_bytes=1024)
    spilled = [e for e in payload["entries"] if e["path"] is not None]
    inline = [e for e in payload["entries"] if e["data"] is not None]
    assert len(spilled) == 1 and len(inline) == 1   # big spills, small rides
    assert os.path.exists(spilled[0]["path"])
    moved = dst.import_state(payload)
    assert moved == payload["bytes"] == big.nbytes + small.nbytes
    got = dst.gather("jobA:dep0", {"w": big, "b": small})
    np.testing.assert_array_equal(np.asarray(got["w"]), big)
    np.testing.assert_array_equal(np.asarray(got["b"]), small)
    assert not os.path.exists(spilled[0]["path"])   # spill consumed
    assert dst.last_migrate["keys"] == 2
    # transactional import: a corrupt payload rolls back staged entries
    bad = {"entries": [
        {"key": "jobB:dep0/params/x", "nbytes": 8, "version": 0,
         "tier": int(Tier.HOST), "is_bf16": False, "spec": None,
         "path": None, "data": np.ones(2, np.float32)},
        {"key": "jobB:dep0/params/y", "nbytes": 8, "version": 0,
         "tier": int(Tier.HOST), "is_bf16": False, "spec": None,
         "path": str(tmp_path / "missing.npy"), "data": None}]}
    with pytest.raises(Exception):
        dst.import_state(bad)
    assert dst.keys_for("jobB:dep0") == []


def test_real_wpg_cross_process_sync_and_migration():
    """Real jax WPGs in child processes: INIT in two groups, cross-process
    weight sync (host-staged params over the pipe, device_put on the
    target's shardings), GENERATE in the child, then a live cross-process
    migration (export → import → rehome) after which the plane still
    serves."""
    tiny = (("num_layers", 2), ("d_model", 32), ("num_heads", 4),
            ("num_kv_heads", 2), ("head_dim", 8), ("d_ff", 64),
            ("vocab_size", 64), ("tie_embeddings", True))
    r = Router(process_plane=True)      # default factory: real WPG
    train = api.DeploymentSpec(deployment_id="train0", job_id="jobA",
                               model_name="qwen2-0.5b", role="train",
                               overrides=tiny)
    roll = api.DeploymentSpec(deployment_id="roll0", job_id="jobA",
                              model_name="qwen2-0.5b", role="rollout",
                              overrides=tiny)
    try:
        r.create_deployment(train, group_id=0)
        r.create_deployment(roll, group_id=1)
        d_train, d_roll = api.Deployment(train, r), api.Deployment(roll, r)
        f_a = r.submit_queued_operation(api.make_op(train, api.Op.INIT, 0))
        f_b = r.submit_queued_operation(api.make_op(roll, api.Op.INIT, 0))
        r.run_until_idle(timeout=280)
        assert f_a.result()["params"] == f_b.result()["params"] > 0
        f_sync = d_train.sync_weights(d_roll)
        r.run_until_idle(timeout=280)
        assert f_sync.result()["synced_bytes"] > 0
        f_gen = r.submit_queued_operation(
            api.make_op(roll, api.Op.GENERATE, [[1, 2, 3]],
                        max_new_tokens=4))
        r.run_until_idle(timeout=280)
        toks = f_gen.result()["tokens"]
        assert isinstance(toks, np.ndarray) and toks.shape == (1, 4)
        # live migration of the whole job onto a fresh third group/process
        moved = r.reassign_job("jobA", 2, timeout=280)
        assert moved > 0
        assert r.state_managers[2].job_bytes("jobA:train0") > 0
        assert r.group_of["train0"] == r.group_of["roll0"] == 2
        f_gen2 = r.submit_queued_operation(
            api.make_op(roll, api.Op.GENERATE, [[1, 2, 3]],
                        max_new_tokens=4))
        r.run_until_idle(timeout=280)
        assert f_gen2.result()["tokens"].shape == (1, 4)
    finally:
        r.close_processes()


# ---------------------------------------------------- overlap acceptance
def _overlap_wall(process_plane: bool, n_groups=2, ops=3, busy_s=0.06):
    if process_plane:
        r = Router(process_plane=True, proc_wpg_factory=STUB)
    else:
        from repro.launch.stub_wpg import make_busy_wpg
        r = Router(wpg_factory=make_busy_wpg)
    try:
        specs = []
        for g in range(n_groups):
            s = api.DeploymentSpec(deployment_id=f"dep{g}",
                                   job_id=f"job{g}", model_name="stub",
                                   role="train")
            r.create_deployment(s, group_id=g)
            specs.append(s)
        for s in specs:     # warm: spawn + handshake outside timed region
            r.submit_queued_operation(api.make_op(s, api.Op.FORWARD, 0))
        r.run_until_idle(timeout=120)
        t0 = time.monotonic()
        for s in specs:
            for i in range(ops):
                r.submit_queued_operation(
                    api.make_op(s, api.Op.FORWARD, i, busy_s=busy_s))
        r.run_until_idle(timeout=120)
        return time.monotonic() - t0
    finally:
        if process_plane:
            r.close_processes()


@pytest.mark.skipif(len(os.sched_getaffinity(0)) < 2,
                    reason="compute overlap needs >= 2 CPU cores")
def test_process_plane_overlaps_compute_bound_groups():
    """The PR's acceptance criterion: on a 2-group compute-bound workload
    (GIL-holding spin per op), the process plane's wall clock is <= 0.6x
    the serialized cost, while threads stay GIL-bound near 1.0x."""
    n_groups, ops, busy = 2, 3, 0.06
    serial = n_groups * ops * busy
    w_threads = _overlap_wall(False, n_groups, ops, busy)
    w_procs = _overlap_wall(True, n_groups, ops, busy)
    assert w_threads >= 0.85 * serial       # threads really are GIL-bound
    assert w_procs <= 0.6 * serial, (
        f"process plane {w_procs:.3f}s vs serial {serial:.3f}s "
        f"(threads {w_threads:.3f}s)")


# ------------------------------------------------- shared-memory transport
@needs_shm
def test_shm_execute_reply_roundtrip_and_no_residue():
    """A large execute result rides shm descriptors, not the pipe: the
    decoded value is bit-identical, the parent really saw child-pool
    segments, and a closed plane leaves /dev/shm spotless."""
    r = Router(process_plane=True, proc_wpg_factory=STUB)
    spec = api.DeploymentSpec(deployment_id="dep0", job_id="job0",
                              model_name="stub", role="train")
    r.create_deployment(spec, group_id=0)
    try:
        futs = [r.submit_queued_operation(
            api.make_op(spec, api.Op.FORWARD, i, payload_mb=4))
            for i in range(3)]
        r.run_until_idle(timeout=120)
        want = np.arange((4 << 20) // 8, dtype=np.float64)
        for f in futs:
            got = f.result()["data"]
            assert got.base is None           # an owning copy, not a view
            np.testing.assert_array_equal(got, want)
        # the replies actually used the descriptor path…
        assert r.group_procs[0]._seen_child_segs
        # …and pooling kept it to one segment across the repeats
        assert len(r.group_procs[0]._seen_child_segs) == 1
    finally:
        r.close_processes()
    assert my_shm_segments() == []


@needs_shm
def test_shm_cross_child_sync_checksum():
    """Cross-child sync_weights as a descriptor relay: source child writes
    its params once into ITS pool, the target child consumes the views —
    the parent never touches the bytes — and the landed params checksum
    exactly."""
    mb = 4
    r = Router(process_plane=True, proc_wpg_factory=STUB)
    src = api.DeploymentSpec(deployment_id="src0", job_id="jobS",
                             model_name="stub", role="train",
                             overrides=(("sync_mb", mb),))
    dst = api.DeploymentSpec(deployment_id="dst0", job_id="jobS",
                             model_name="stub", role="rollout")
    try:
        r.create_deployment(src, group_id=0)
        r.create_deployment(dst, group_id=1)
        d_src, d_dst = api.Deployment(src, r), api.Deployment(dst, r)
        f_sync = d_src.sync_weights(d_dst)
        r.run_until_idle(timeout=120)
        f_sync.result()
        f_sum = r.submit_queued_operation(
            api.make_op(dst, api.Op.FORWARD, 0, stored_sum=True))
        r.run_until_idle(timeout=120)
        n = (mb << 20) // 4
        assert f_sum.result()["stored_sum"] == float(n * (n - 1) // 2)
    finally:
        r.close_processes()
    assert my_shm_segments() == []


@needs_shm
def test_child_crash_mid_sync_with_shm_in_flight():
    """The robustness satellite, shm edition: the TARGET child dies inside
    ``_store`` while the source child's descriptors are in flight. The
    sync op fails, its dependents poison, the source group keeps serving,
    the completed-op billing mirror survives, and respawn leaves zero
    /dev/shm residue from the dead incarnation."""
    r = Router(process_plane=True, proc_wpg_factory=CRASH_STORE)
    src = api.DeploymentSpec(deployment_id="src0", job_id="jobS",
                             model_name="stub", role="train",
                             overrides=(("sync_mb", 4),))
    dst = api.DeploymentSpec(deployment_id="dst0", job_id="jobS",
                             model_name="stub", role="rollout")
    try:
        r.create_deployment(src, group_id=0)
        r.create_deployment(dst, group_id=1)
        # a completed op on the doomed group: its billing must survive
        f_pre = r.submit_queued_operation(
            api.make_op(dst, api.Op.FORWARD, 0, sleep_s=0.01))
        r.run_until_idle(timeout=120)
        assert f_pre.result()["seconds"] >= 0.01
        pre_log = list(r.wpgs["dst0"].exec_log)
        d_src, d_dst = api.Deployment(src, r), api.Deployment(dst, r)
        f_sync = d_src.sync_weights(d_dst)
        f_dep = r.submit_queued_operation(
            api.make_op(dst, api.Op.FORWARD, 1,
                        prerequisites=(f_sync,)))
        r.run_until_idle(timeout=120)
        with pytest.raises((RuntimeError, GroupProcessError),
                           match="worker process died"):
            f_sync.result()
        with pytest.raises(RuntimeError, match="prerequisite"):
            f_dep.result()
        assert r.process_health() == {0: True, 1: False}
        assert list(r.wpgs["dst0"].exec_log) == pre_log   # billing conserved
        # the source group survived its peer's death and still serves
        f_ok = r.submit_queued_operation(
            api.make_op(src, api.Op.FORWARD, 2))
        r.run_until_idle(timeout=120)
        assert f_ok.result()["op"] == "forward"
        dead_prefix = f"pxl{os.getpid()}g1s1"
        assert r.respawn_dead_groups() == [1]
        # the dead incarnation left nothing behind in /dev/shm
        assert not [n for n in my_shm_segments()
                    if n.startswith(dead_prefix)]
        f2 = r.submit_queued_operation(
            api.make_op(dst, api.Op.FORWARD, 3))
        r.run_until_idle(timeout=120)
        assert f2.result()["op"] == "forward"
    finally:
        r.close_processes()
    assert my_shm_segments() == []


@needs_shm
def test_migrate_importer_death_cleans_spills_and_segments():
    """Killing the importing child mid-migrate with shm descriptors (and
    forced spill files) in flight: the op raises, the source keeps sole
    ownership of the state, the transfer's ``export__`` spills are
    deleted, and teardown leaves no /dev/shm residue."""
    tiny = (("num_layers", 2), ("d_model", 32), ("num_heads", 4),
            ("num_kv_heads", 2), ("head_dim", 8), ("d_ff", 64),
            ("vocab_size", 64), ("tie_embeddings", True))
    r = Router(process_plane=True, shm_threshold=1024)
    train = api.DeploymentSpec(deployment_id="train0", job_id="jobA",
                               model_name="qwen2-0.5b", role="train",
                               overrides=tiny)
    other = api.DeploymentSpec(deployment_id="other0", job_id="jobB",
                               model_name="qwen2-0.5b", role="train",
                               overrides=tiny)
    try:
        r.create_deployment(train, group_id=0)
        r.create_deployment(other, group_id=1)
        f = r.submit_queued_operation(api.make_op(train, api.Op.INIT, 0))
        r.run_until_idle(timeout=280)
        assert f.result()["params"] > 0
        bytes_before = r.state_managers[0].job_bytes("jobA:train0")
        assert bytes_before > 0
        os.kill(r.group_procs[1].pid(), signal.SIGKILL)
        r.group_procs[1]._proc.join(timeout=30)
        with pytest.raises(GroupProcessError, match="worker process died"):
            # tiny max_inline forces the SPILL tier: its cleanup path
            r.state_managers[0].migrate("jobA:train0", r.state_managers[1],
                                        max_inline_bytes=2048)
        # source still owns the state, transfer spills are gone
        assert r.state_managers[0].job_bytes("jobA:train0") == bytes_before
        src_node = r.group_procs[0].node_id
        assert glob.glob(f"/tmp/plexrl_{src_node}/export__*") == []
        with pytest.raises(GroupProcessError, match="worker process died"):
            # default path: everything inline as shm DESCRIPTORS in flight
            r.state_managers[0].migrate("jobA:train0", r.state_managers[1])
        assert r.state_managers[0].job_bytes("jobA:train0") == bytes_before
        # the export really rode the source child's segment pool (released
        # segments persist in its free list until the child exits)
        assert [n for n in my_shm_segments()
                if n.startswith(f"pxl{os.getpid()}g0s1c")]
        assert r.respawn_dead_groups() == [1]
        assert r.process_health() == {0: True, 1: True}
    finally:
        r.close_processes()
    assert my_shm_segments() == []


def test_respawn_sweeps_orphaned_spill_files():
    """A crash between export and import orphans the transaction's spill
    files; respawn's sweep removes them — and ONLY them (regular
    disk-tier state files are untouched)."""
    r, specs = make_proc_router(n_groups=1)
    spill_dir = f"/tmp/plexrl_{r.group_procs[0].node_id}"
    os.makedirs(spill_dir, exist_ok=True)
    orphan = os.path.join(spill_dir, "export__deadbeef__jobX__w.npy")
    keeper = os.path.join(spill_dir, "jobX__w.npy")
    try:
        for p in (orphan, keeper):
            with open(p, "wb") as fh:
                fh.write(b"\x93NUMPY")
        f_bad = r.submit_queued_operation(
            api.make_op(specs[0], api.Op.FORWARD, 0, crash=True))
        r.run_until_idle(timeout=120)
        with pytest.raises(RuntimeError, match="worker process died"):
            f_bad.result()
        assert r.respawn_dead_groups() == [0]
        assert not os.path.exists(orphan)     # transaction orphan swept
        assert os.path.exists(keeper)         # real disk-tier state kept
    finally:
        r.close_processes()
        for p in (orphan, keeper):
            if os.path.exists(p):
                os.unlink(p)


def test_import_rollback_unlinks_spills(tmp_path):
    """Satellite bugfix: a failing import_state deletes the transfer's
    spill files during rollback instead of leaking them (the transfer is
    over either way — nobody will read them again)."""
    src = StateManager(node_id="src", disk_dir=str(tmp_path / "src"))
    dst = StateManager(node_id="dst", disk_dir=str(tmp_path / "dst"))
    big = np.arange(4096, dtype=np.float32)
    src.register("jobA:dep0", {"w": big, "v": big * 2}, Tier.HOST)
    payload = src.export_state("jobA:dep0", max_inline_bytes=1024)
    assert len(payload["spills"]) == 2
    # spill names are transaction-scoped: two exports never collide
    payload2 = src.export_state("jobA:dep0", max_inline_bytes=1024)
    assert set(payload["spills"]).isdisjoint(payload2["spills"])
    for p in payload["spills"] + payload2["spills"]:
        assert os.path.exists(p)
    # corrupt the tail of the payload so the import fails mid-stage
    payload["entries"].append(
        {"key": "jobA:dep0/params/ghost", "nbytes": 8, "version": 0,
         "tier": int(Tier.HOST), "is_bf16": False, "spec": None,
         "path": str(tmp_path / "missing.npy"), "data": None})
    with pytest.raises(Exception):
        dst.import_state(payload)
    assert dst.keys_for("jobA:dep0") == []            # rolled back
    for p in payload["spills"]:
        assert not os.path.exists(p)                  # …and spills gone
    # the untouched second export still imports cleanly
    assert dst.import_state(payload2) == payload2["bytes"]
    for p in payload2["spills"]:
        assert not os.path.exists(p)
