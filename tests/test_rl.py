"""RL substrate: GRPO math, logprob alignment, rollout, rewards, data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # vendored fallback (seeded numpy)
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import ModelConfig
from repro.models.registry import build_model
from repro.rl import data, grpo, reward, rollout


def _tiny_model():
    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                      vocab_size=64, tie_embeddings=True)
    m = build_model(cfg)
    return m, m.init_params(jax.random.PRNGKey(0))


# ------------------------------------------------------------------- GRPO
@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(2, 8))
def test_group_relative_advantages_zero_mean(n_groups, g):
    rng = np.random.default_rng(n_groups * 10 + g)
    r = jnp.asarray(rng.normal(size=n_groups * g).astype(np.float32))
    adv = grpo.group_relative_advantages(r, g)
    grouped = np.asarray(adv).reshape(n_groups, g)
    np.testing.assert_allclose(grouped.mean(1), 0.0, atol=1e-5)


def test_group_advantages_constant_reward_is_zero():
    r = jnp.ones((8,))
    adv = grpo.group_relative_advantages(r, 4)
    np.testing.assert_allclose(np.asarray(adv), 0.0, atol=1e-4)


def test_token_logprobs_alignment():
    """token_logprobs[:, j] must be log p(tokens[:, j+1] | prefix)."""
    logits = jnp.zeros((1, 3, 4)).at[0, 0, 2].set(10.0)  # peak on token 2
    tokens = jnp.asarray([[0, 2, 1]])
    lp = grpo.token_logprobs(logits, tokens)
    assert lp.shape == (1, 2)
    assert float(lp[0, 0]) > -1e-3          # predicted token 2 at pos 1
    assert float(lp[0, 1]) < -1.0           # uniform elsewhere


def test_grpo_loss_zero_when_on_policy_and_zero_adv():
    m, params = _tiny_model()
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 64)
    logits, _ = m.forward(params, {"tokens": toks})
    lp = grpo.token_logprobs(logits, toks)
    batch = {
        "tokens": toks,
        "behavior_logprobs": jnp.pad(lp, ((0, 0), (1, 0))),
        "advantages": jnp.zeros((4,)),
        "loss_mask": jnp.ones((4, 8)),
    }
    loss, metrics = grpo.grpo_loss(params, m, batch, grpo.GRPOConfig(aux_coef=0.0))
    assert abs(float(loss)) < 1e-5
    assert abs(float(metrics["ratio_mean"]) - 1.0) < 1e-3
    assert abs(float(metrics["kl"])) < 1e-5


def test_grad_accum_matches_full_batch():
    m, params = _tiny_model()
    model_batch = m.dummy_batch(jax.random.PRNGKey(2),
                                __import__("repro.configs",
                                           fromlist=["ShapeSpec"]
                                           ).ShapeSpec("t", "train", 8, 4))
    g1, m1 = grpo.compute_grads(params, m, model_batch, grpo.GRPOConfig(),
                                None, grad_accum=1)
    g2, m2 = grpo.compute_grads(params, m, model_batch, grpo.GRPOConfig(),
                                None, grad_accum=2)
    # losses are means over microbatches; grads averaged — should be close
    # (not exact: the loss normalises by per-microbatch mask sums)
    n1 = float(jax.tree.reduce(
        lambda a, b: a + jnp.sum(jnp.abs(b.astype(jnp.float32))), g1, 0.0))
    n2 = float(jax.tree.reduce(
        lambda a, b: a + jnp.sum(jnp.abs(b.astype(jnp.float32))), g2, 0.0))
    assert n1 > 0 and n2 > 0
    assert abs(n1 - n2) / max(n1, n2) < 0.35


# ----------------------------------------------------------------- rollout
def test_rollout_shapes_and_greedy_determinism():
    m, params = _tiny_model()
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 3, 64)
    cfg = rollout.RolloutConfig(max_new_tokens=5, greedy=True)
    t1, l1, a1 = rollout.rollout(m, params, prompts, jax.random.PRNGKey(4), cfg)
    t2, l2, a2 = rollout.rollout(m, params, prompts, jax.random.PRNGKey(9), cfg)
    assert t1.shape == (2, 5) and l1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))  # greedy
    assert bool((l1 <= 0).all())


# ------------------------------------------------------------------ reward
def test_verifiable_reward_math():
    assert reward.verify("the answer is 42", 42) == 1.0
    assert reward.verify("i think 41", 42) == 0.0
    assert reward.verify("no numbers here", 42) == 0.0
    assert reward.extract_answer("12 + 3 = 15") == 15


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 5), st.integers(0, 2 ** 31 - 1))
def test_problem_generation_verifiable(difficulty, seed):
    rng = np.random.default_rng(seed)
    p = data.sample_problem(rng, difficulty)
    # the answer string, formatted into a completion, must verify
    assert reward.verify(f"... = {p.answer}", p.answer) == 1.0
    # tokenizer roundtrip preserves the prompt
    assert data.decode(data.encode(p.prompt)) == p.prompt


def test_pack_rollout_batch_alignment():
    prompts = np.full((4, 3), 5, np.int32)
    comps = np.arange(8, dtype=np.int32).reshape(4, 2) + 3
    logps = np.full((4, 2), -0.5, np.float32)
    rewards = np.array([1, 0, 1, 0], np.float32)
    b = data.pack_rollout_batch(prompts, comps, logps, rewards,
                                group_size=2, seq_len=8)
    assert b["tokens"].shape == (4, 8)
    np.testing.assert_array_equal(b["tokens"][:, :3], prompts)
    np.testing.assert_array_equal(b["tokens"][:, 3:5], comps)
    np.testing.assert_array_equal(b["loss_mask"][:, 3:5], 1.0)
    assert b["loss_mask"][:, :3].sum() == 0
    np.testing.assert_allclose(b["behavior_logprobs"][:, 3:5], -0.5)
