"""The dataflow client API + persistent serve-mode dispatch plane.

Covers the §4.1/§4.2 redesign:
- chainable futures: ``.then``, ``api.gather``, error propagation through
  transforms into dependent operations (poisoning),
- future-valued op arguments: auto-registered prerequisites + dispatch-time
  value splicing (no manual req_id wiring),
- ``Router.serve()``/``shutdown()``: workers park indefinitely while idle,
  jobs attach to new groups mid-serve, ``teardown`` cancels a departing
  deployment's queued ops and drops its queue,
- the acceptance scenario: GRPO + PPO jobs against ``PlexCluster.serve()``
  where the PPO job attaches AFTER the plane started, completes all steps,
  and is billed — plus ``remove_job`` detaching a long job mid-flight,
- serial ``drain()`` replay of a dataflow-chained workload under a
  VirtualClock staying bit-identical across runs.

Fast tests use the sleep-stub WPGs from test_dispatch; the acceptance test
runs real (tiny) models end-to-end.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import api
from repro.core.cluster import PlexCluster
from repro.core.controller import JobConfig
from repro.core.router import Router
from repro.core.scheduler.executor import State, VirtualClock
from test_dispatch import StubWPG, make_router

TINY = (("num_layers", 2), ("d_model", 32), ("num_heads", 4),
        ("num_kv_heads", 2), ("head_dim", 8), ("d_ff", 64),
        ("vocab_size", 64), ("tie_embeddings", True))


def _serve_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("serve-") and t.is_alive()]


def _deploy(router, dep_id="d0", job_id="j0", group_id=0) -> api.Deployment:
    spec = api.DeploymentSpec(deployment_id=dep_id, job_id=job_id,
                              model_name="stub", role="train")
    return router.deploy(spec, group_id=group_id)


# ----------------------------------------------------------- future algebra
def test_then_chains_and_propagates_errors():
    f = api.Future(sources=(7,))
    g = f.then(lambda x: x + 1).then(lambda x: x * 10)
    assert g.sources == (7,)          # provenance survives chaining
    f.set_result(4)
    assert g.result() == 50

    h = api.Future()
    bad = h.then(lambda x: 1 / x)
    tail = bad.then(lambda x: x + 1)  # never runs: error skips transforms
    h.set_result(0)
    with pytest.raises(ZeroDivisionError):
        tail.result()


def test_gather_joins_results_and_first_error_wins():
    a, b = api.Future(sources=(1,)), api.Future(sources=(2, 3))
    j = api.gather(a, b)
    assert j.sources == (1, 2, 3)
    b.set_result("B")
    assert not j.done()
    a.set_result("A")
    assert j.result() == ["A", "B"]   # argument order, not resolution order

    c, d = api.Future(), api.Future()
    j2 = api.gather(c, d)
    c.set_error(RuntimeError("boom"))
    with pytest.raises(RuntimeError, match="boom"):
        j2.result()
    d.set_result("late")              # late success after error: ignored
    assert api.gather().result() == []


# ------------------------------------------------- dataflow op arguments
def test_future_arg_becomes_prerequisite_and_splices():
    r, _, _ = make_router(n_groups=1, duration=0.0)
    dep = api.Deployment(r.deployments["dep0"], r)
    first = dep.forward({"x": 1})
    derived = first.then(lambda res: {"from_first": res["req_id"]})
    second = dep.forward(derived)     # future as argument
    (req2,) = second.sources
    task = r.executor.tasks[req2]
    assert task.prerequisites == first.sources  # auto-registered edge
    r.drain()
    # the spliced value reached the WPG: its qop args held the dict
    assert second.result()["req_id"] == req2
    qop_args = r.executor.tasks[req2]           # task retained for telemetry
    assert qop_args.state == State.COMPLETED


def test_spliced_value_visible_to_execution():
    """The executed op must see the RESOLVED value, not the Future."""
    seen = {}

    class RecordingWPG(StubWPG):
        def execute(self, qop):
            seen[qop.req_id] = qop.args
            return super().execute(qop)

    trace = []
    r = Router(wpg_factory=lambda spec, sm: RecordingWPG(spec, sm, 0.0,
                                                         trace))
    dep = _deploy(r)
    f1 = dep.forward("payload")
    f2 = dep.forward(f1.then(lambda res: ("derived", res["req_id"])))
    r.drain()
    (req2,) = f2.sources
    assert seen[req2] == (("derived", f1.sources[0]),)


def test_deep_nested_future_arg_gets_prereq_and_splices():
    """The prerequisite scan and the dispatch splice must reach the SAME
    depth: a future nested dict->list->list below an argument still gets
    its dependency edge and its value substituted."""
    seen = {}

    class RecordingWPG(StubWPG):
        def execute(self, qop):
            seen[qop.req_id] = qop.args
            return super().execute(qop)

    trace = []
    r = Router(wpg_factory=lambda spec, sm: RecordingWPG(spec, sm, 0.0,
                                                         trace))
    dep = _deploy(r)
    f1 = dep.forward(0)
    f2 = dep.forward({"a": [[f1.then(lambda res: res["req_id"])]]})
    (req2,) = f2.sources
    assert r.executor.tasks[req2].prerequisites == f1.sources
    r.drain()
    assert seen[req2] == ({"a": [[f1.sources[0]]]},)


def test_then_transform_error_poisons_dependent_op():
    """A raising .then transform fails the dependent op (and its own
    dependents), and every driver still terminates."""
    r, _, _ = make_router(n_groups=1, duration=0.0)
    dep = api.Deployment(r.deployments["dep0"], r)
    gen = dep.forward(0)
    bad_batch = gen.then(lambda res: 1 / 0)
    upd = dep.update_actor(bad_batch)
    tail = dep.forward(upd)           # transitively poisoned
    r.run_until_idle(timeout=30.0)
    assert gen.result()["req_id"] > 0
    with pytest.raises(ZeroDivisionError):
        bad_batch.result()
    with pytest.raises(ZeroDivisionError):
        upd.result()
    with pytest.raises(RuntimeError, match="prerequisite"):
        tail.result()
    assert not r.pending


def test_sourceless_unresolved_future_arg_rejected():
    """A hand-made unresolved future in op args has nothing to gate on —
    dispatch would stall a group's lock waiting for it — so submission
    refuses it loudly. A RESOLVED one is plain data and splices fine."""
    r, _, _ = make_router(n_groups=1, duration=0.0)
    dep = api.Deployment(r.deployments["dep0"], r)
    with pytest.raises(ValueError, match="no source"):
        dep.forward(api.Future())
    with pytest.raises(ValueError, match="no source"):
        dep.forward(0, after=(api.Future(),))
    done = api.Future()
    done.set_result(41)
    ok = dep.forward(done)
    r.drain()
    assert ok.result()["req_id"] > 0


def test_after_edge_orders_without_payload():
    """`after=` is the pure-ordering dataflow edge (async-staleness gate)."""
    r, _, trace = make_router(n_groups=1, duration=0.005)
    dep = api.Deployment(r.deployments["dep0"], r)
    first = dep.forward(0)
    second = dep.forward(1, after=(first,))
    (req2,) = second.sources
    assert r.executor.tasks[req2].prerequisites == first.sources
    r.run_until_idle(timeout=30.0)
    executed = [req_id for _, req_id, _, _ in trace]
    assert executed == [first.sources[0], req2]


# ------------------------------------------------------------ serve plane
def test_serve_admits_work_submitted_while_parked():
    r, _, _ = make_router(n_groups=1, duration=0.0)
    dep = api.Deployment(r.deployments["dep0"], r)
    with r:                           # Router is a serve context manager
        assert r.serving
        f1 = dep.forward(0)
        assert f1.wait(timeout=10.0)["req_id"] > 0
        time.sleep(0.05)              # plane fully idle, worker parked
        f2 = dep.forward(1)
        assert f2.wait(timeout=10.0)["req_id"] > 0
    assert not r.serving
    assert not _serve_threads(), "serve workers leaked after shutdown"
    assert r.serve_executed() == 2


def test_attach_new_group_mid_serve_spawns_worker():
    r, _, _ = make_router(n_groups=1, duration=0.0)
    with r:
        assert len(_serve_threads()) == 1
        dep_new = _deploy(r, dep_id="late", job_id="late-job", group_id=5)
        assert len(_serve_threads()) == 2
        assert dep_new.forward(0).wait(timeout=10.0)["req_id"] > 0
    assert not _serve_threads()


def test_teardown_cancels_queued_ops_and_drops_queue():
    # duration keeps the first op RUNNING while the rest queue behind it
    r, _, _ = make_router(n_groups=1, duration=0.15)
    dep = _deploy(r, dep_id="victim", job_id="vjob", group_id=1)
    with r:
        running = dep.forward(0)
        queued = [dep.forward(i) for i in range(1, 4)]
        time.sleep(0.05)              # let the first op start executing
        r.teardown("victim")
        # in-flight op resolves (result), queued ops poison (error)
        assert running.wait(timeout=10.0)["req_id"] > 0
        for q in queued:
            with pytest.raises(RuntimeError, match="torn down"):
                q.wait(timeout=10.0)
        r.wait_idle(timeout=10.0)
    assert "vjob" not in r.request_queues     # queue dropped with the job
    assert not r.pending
    assert all(lock.holder is None for lock in r.executor.locks.values())


def test_teardown_poisons_cross_deployment_dependents():
    r, _, _ = make_router(n_groups=2, duration=0.1)
    dep0 = api.Deployment(r.deployments["dep0"], r)
    victim = _deploy(r, dep_id="victim", job_id="vjob", group_id=1)
    with r:
        blocker = victim.forward(0)   # occupies the victim's group
        vf = victim.forward(1)        # queued behind it
        downstream = dep0.forward(vf) # other deployment depends on it
        time.sleep(0.03)
        r.teardown("victim")
        with pytest.raises(RuntimeError, match="torn down"):
            vf.wait(timeout=10.0)
        with pytest.raises(RuntimeError):
            downstream.wait(timeout=10.0)   # poisoned transitively
        r.wait_idle(timeout=10.0)
        blocker.wait(timeout=10.0)


def test_serial_driver_guarded_while_serving():
    r, _, _ = make_router(n_groups=1, duration=0.0)
    with r:
        with pytest.raises(RuntimeError, match="serve"):
            r.step()
        with pytest.raises(RuntimeError, match="serve"):
            r.run_until_idle()
    r.drain()                         # available again after shutdown


def test_submit_to_torn_down_deployment_raises():
    r, _, _ = make_router(n_groups=1, duration=0.0)
    dep = api.Deployment(r.deployments["dep0"], r)
    r.teardown("dep0")
    with pytest.raises(RuntimeError, match="unknown deployment"):
        dep.forward(0)


# ----------------------------------------- VirtualClock dataflow replay
def _virtual_dataflow_run():
    """A GRPO/PPO-shaped chained workload (generate -> transform ->
    future-arg update, interleaved across two jobs) driven by serial
    drain() under a VirtualClock; returns admission order as submission
    ordinals (req_ids differ across runs: the api counter is global)."""
    clock = VirtualClock()
    trace = []
    router = Router(now=clock,
                    wpg_factory=lambda spec, sm: StubWPG(spec, sm, 0.0,
                                                         trace))
    deps = [_deploy(router, dep_id=f"dep{j}", job_id=f"job{j}", group_id=0)
            for j in range(2)]
    ordinal, prev = {}, {0: None, 1: None}
    for step in range(6):
        for j, dep in enumerate(deps):
            gate = (prev[j],) if prev[j] is not None else ()
            gen = dep.generate(np.zeros((2, 4), np.int32),
                               max_new_tokens=4,
                               exec_estimate=0.5 + (step * 5 + j) % 7,
                               after=gate)
            batch = gen.then(lambda res: {"packed": res["req_id"]})
            upd = dep.update_actor(batch,
                                   exec_estimate=1.0 + (step * 3 + j) % 5)
            prev[j] = upd
            ordinal[gen.sources[0]] = len(ordinal)
            ordinal[upd.sources[0]] = len(ordinal)
            clock.advance(0.25)
    router.drain()
    assert not router.pending
    return [ordinal[req_id] for _, req_id, _, _ in trace]


def test_dataflow_chain_replay_bit_identical_under_virtual_clock():
    first = _virtual_dataflow_run()
    second = _virtual_dataflow_run()
    assert len(first) == 2 * 2 * 6    # gen + update, 2 jobs, 6 steps
    assert first == second, "dataflow replay diverged between runs"


# ------------------------------------------------- acceptance: GRPO + PPO
def _tiny_job(job_id, seed, steps=2, staleness=0):
    return JobConfig(job_id=job_id, model_name="qwen2-0.5b", steps=steps,
                     batch_size=4, group_size=2, max_new_tokens=4,
                     seq_len=24, overrides=TINY, seed=seed,
                     async_staleness=staleness)


def test_serve_grpo_then_ppo_attach_complete_and_bill():
    """Acceptance: a GRPO job starts under a live serve() plane; a PPO job
    attaches AFTER serving began (on a NEW group, spawning its dispatch
    worker dynamically); a long third job detaches mid-flight. Both
    surviving jobs complete all steps and are billed."""
    c = PlexCluster(n_groups=1)
    c.add_job(_tiny_job("grpo-job", seed=1, steps=2))
    with c.serve():
        # wait until the pre-registered job makes real progress
        deadline = time.monotonic() + 240
        while not c.controllers["grpo-job"].reward_log:
            assert time.monotonic() < deadline, "grpo job made no progress"
            time.sleep(0.05)
        # NOW attach the PPO job to a brand-new group, mid-serve
        c.add_job(_tiny_job("ppo-job", seed=2, steps=2), group_id=1,
                  algo="ppo")
        # and a long-running job that will be detached mid-flight
        c.add_job(_tiny_job("doomed", seed=3, steps=50), group_id=0)
        deadline = time.monotonic() + 240
        while c.controllers["doomed"].steps_completed < 1:
            assert time.monotonic() < deadline, "doomed job made no progress"
            time.sleep(0.05)
        removed = c.remove_job("doomed")
        assert removed.steps_completed >= 1
    # serve() exit joined the client threads: everything completed
    for job, algo_steps in (("grpo-job", 2), ("ppo-job", 2)):
        ctl = c.controllers[job]
        assert ctl.steps_completed == algo_steps, job
        assert len(ctl.metrics_log) == algo_steps, job
        assert len(ctl.reward_log) == algo_steps, job
        for m in ctl.metrics_log:
            assert not np.isnan(m["loss"]), (job, m)
        rec = c.billing[job]
        assert rec.steps == algo_steps
        assert rec.busy_seconds > 0.0, f"{job} not billed"
    # the detached job was billed for the work it consumed
    rec = c.billing["doomed"]
    assert rec.steps >= 1 and rec.busy_seconds > 0.0
    # PPO actually trained through the split-op chain
    ppo = c.controllers["ppo-job"]
    assert all("pg_loss" in m and "step" in m for m in ppo.metrics_log)
    # plane fully torn down
    assert not _serve_threads()
    assert not c.router.pending


def test_serve_body_exception_detaches_clients_and_stays_clean():
    """An exception in the serve() block must not orphan client threads:
    still-driving jobs detach (their futures poison, billing keeps the
    consumed work), the plane shuts down, the body's exception propagates,
    and a LATER serve session does not resurrect removed/completed jobs."""
    c = PlexCluster(n_groups=1)
    c.add_job(_tiny_job("longjob", seed=4, steps=50))
    with pytest.raises(ValueError, match="user abort"):
        with c.serve():
            deadline = time.monotonic() + 240
            while c.controllers["longjob"].steps_completed < 1:
                assert time.monotonic() < deadline, "job made no progress"
                time.sleep(0.05)
            raise ValueError("user abort")
    assert not _serve_threads()
    assert not [t for t in threading.enumerate()
                if t.name == "client-longjob" and t.is_alive()]
    rec = c.billing["longjob"]
    assert rec.steps >= 1 and rec.busy_seconds > 0.0
    steps_before = c.controllers["longjob"].steps_completed
    with c.serve():                     # removed job must NOT relaunch
        time.sleep(0.2)
    assert c.controllers["longjob"].steps_completed == steps_before
    assert not _serve_threads()
