"""Tests for the period-boundary interference fix and the incremental
repack planner (ISSUE 7).

- ``phase_interference`` regressions: a segment crossing the cycle edge
  must contribute its wrapped tail (the old code clipped it away), the
  score must be invariant under cyclic rotation of origin/shift, and the
  mixed-period fold onto the RESIDENT's circle must be invariant under
  whole-resident-period rotations of the candidate.
- ``RepackIndex``: dirty tracking, oracle agreement in exact mode
  (bit-identical decisions vs ``plan_repack`` on an all-dirty state),
  and bounded-gain soundness of pruned/capped plans (every emitted move,
  replayed in plan order onto the live state, realizes its claimed gain
  and clears the floor) under randomized add/remove/drift/repack
  sequences.
"""
import itertools

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

import pytest

from repro.core.scheduler.intervals import IntervalSet
from repro.core.scheduler.placement import (JobTrace, NodeGroup, Placed,
                                            PlacementConfig, PlacementPolicy,
                                            phase_interference, wrapped_arcs)
from repro.core.scheduler.repack_index import RepackIndex, union_busy
from test_repack_property import _check_invariants, _random_trace

HORIZON = 400.0


def _group_with(residents, horizon=HORIZON):
    g = NodeGroup(0, 1, IntervalSet([(0.0, horizon)]))
    for i, (trace, shift) in enumerate(residents):
        g.resident.append(Placed(f"r{i}", trace, 0, shift))
    return g


# ---------------------------------------------- period-boundary regression
def test_interference_wraps_at_period_boundary():
    """A resident active over [7, 9) on an 8s cycle is busy [7,8) AND
    [0,1) of every period — a candidate active [0, 1) fully collides with
    the wrapped tail. The pre-fix code clipped the overlap to the linear
    span [7, 9) and scored 0.0 (it fails on this exact assertion)."""
    g = _group_with([(JobTrace(8.0, ((7.0, 2.0),)), 0.0)])
    cand = JobTrace(8.0, ((0.0, 1.0),))
    assert phase_interference(cand, 0.0, g) == pytest.approx(1.0)
    # symmetric case: the CANDIDATE's shifted segment wraps instead
    g2 = _group_with([(JobTrace(8.0, ((0.0, 1.0),)), 0.0)])
    cand2 = JobTrace(8.0, ((7.0, 2.0),))
    assert phase_interference(cand2, 0.0, g2) == pytest.approx(1.0)


def test_interference_rotation_counterexample():
    """Deterministic witness of the old bias: resident busy [0,3) and a
    candidate busy [2,4) on an 8s cycle overlap for 1s; rotating BOTH by
    +6 (a relabeling of the cycle origin) must not change that. The old
    code scored the rotated pair 0.0."""
    cand = JobTrace(8.0, ((0.0, 2.0),))
    base = phase_interference(
        cand, 2.0, _group_with([(JobTrace(8.0, ((0.0, 3.0),)), 0.0)]))
    rotated = phase_interference(
        cand, 8.0, _group_with([(JobTrace(8.0, ((0.0, 3.0),)), 6.0)]))
    assert base == pytest.approx(1.0)
    assert rotated == pytest.approx(base)


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_interference_invariant_under_cyclic_rotation(data):
    """Same-period ensemble: rotating every anchor (residents' shifts and
    the candidate's shift) by an arbitrary theta — including theta that
    pushes segments across the period boundary — is a relabeling of the
    cycle origin and must leave the interference score unchanged."""
    period = data.draw(st.floats(6.0, 24.0))
    n_res = data.draw(st.integers(1, 3))
    residents = []
    for _ in range(n_res):
        a = data.draw(st.floats(0.0, period))
        d = data.draw(st.floats(0.5, period * 0.8))
        shift = data.draw(st.floats(0.0, period))
        residents.append((JobTrace(period, ((a, d),)), shift))
    g = _group_with(residents)
    ca = data.draw(st.floats(0.0, period))
    cd = data.draw(st.floats(0.5, period * 0.8))
    cand = JobTrace(period, ((ca, cd),))
    shift0 = data.draw(st.floats(0.0, period))
    base = phase_interference(cand, shift0, g)
    theta = data.draw(st.floats(0.0, 3.0 * period))
    g_rot = _group_with([(t, s + theta) for t, s in residents])
    assert phase_interference(cand, shift0 + theta, g_rot) == \
        pytest.approx(base, abs=1e-6)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_interference_mixed_period_resident_circle(data):
    """Mixed periods fold the candidate onto the RESIDENT's circle: the
    audit of that approximation is that shifting the candidate by a whole
    resident period (any multiple) must not change the score, regardless
    of the candidate's own period."""
    rp = data.draw(st.floats(6.0, 20.0))
    g = _group_with([(JobTrace(rp, ((data.draw(st.floats(0.0, rp)),
                                     data.draw(st.floats(0.5, rp * 0.8))),)),
                      data.draw(st.floats(0.0, rp)))])
    cand = _random_trace(data)
    shift = data.draw(st.floats(0.0, cand.period))
    base = phase_interference(cand, shift, g)
    k = data.draw(st.integers(1, 4))
    assert phase_interference(cand, shift + k * rp, g) == \
        pytest.approx(base, abs=1e-6)


def test_interference_scale_multiplies():
    g = _group_with([(JobTrace(8.0, ((0.0, 3.0),)), 0.0)])
    cand = JobTrace(8.0, ((0.0, 2.0),))
    base = phase_interference(cand, 2.0, g)
    g.interference_scale = 1.5
    assert phase_interference(cand, 2.0, g) == pytest.approx(1.5 * base)


def test_wrapped_arcs_and_union_busy():
    assert wrapped_arcs(7.0, 2.0, 8.0) == ((7.0, 8.0), (0.0, 1.0))
    assert wrapped_arcs(2.0, 3.0, 8.0) == ((2.0, 5.0),)
    assert wrapped_arcs(1.0, 9.0, 8.0) == ((0.0, 8.0),)   # covers the circle
    # union measure is rotation-invariant (the pigeonhole bound relies on it)
    segs = ((0.0, 2.0), (5.0, 4.0))
    assert union_busy(segs, 0.0, 8.0) == pytest.approx(
        union_busy(segs, 3.3, 8.0))


# ----------------------------------------------------------- dirty tracking
def _fresh_policy(n_groups=3, horizon=HORIZON):
    return PlacementPolicy(
        [NodeGroup(g, 1, IntervalSet([(0.0, horizon)]))
         for g in range(n_groups)],
        PlacementConfig(horizon=horizon))


def test_index_dirty_tracking_and_convergence():
    pol = _fresh_policy(3)
    idx = RepackIndex(pol)
    pol.place_warm("a", JobTrace(8.0, ((6.0, 2.0),)), origin=0.0)
    pol.place_warm("b", JobTrace(8.0, ((1.0, 3.0),)), origin=0.0)
    assert idx.dirty_groups() != []
    idx.plan(origin=0.0)
    # planned-against groups are clean: the next pass has no candidates
    assert idx.dirty_groups() == []
    plan = idx.plan(origin=0.0)
    assert idx.last_stats["candidates"] == 0
    assert not plan.moves and not plan.reshifts
    # a resident change re-dirties exactly the touched group
    pol.place_warm("c", JobTrace(10.0, ((5.0, 2.0),)), origin=0.0)
    touched = pol.placed["c"].group_id
    assert idx.dirty_groups() == [touched]
    # drift marking forces a clean group back in
    other = next(g.group_id for g in pol.groups if g.group_id != touched)
    idx.mark_dirty(other)
    assert sorted(idx.dirty_groups()) == sorted({touched, other})


def test_incremental_plan_does_not_mutate_live_state():
    pol = _fresh_policy(3)
    for i, (p, a, d) in enumerate([(8.0, 6.0, 2.0), (8.0, 1.0, 3.0),
                                   (12.0, 4.0, 5.0), (10.0, 2.0, 4.0)]):
        pol.place_warm(f"j{i}", JobTrace(p, ((a, d),)), origin=0.0)
    snap_placed = {j: (p.group_id, p.shift, p.origin)
                   for j, p in pol.placed.items()}
    snap_free = {g.group_id: g.free.intervals() for g in pol.groups}
    RepackIndex(pol).plan(origin=0.0, min_gain=0.001)
    assert {j: (p.group_id, p.shift, p.origin)
            for j, p in pol.placed.items()} == snap_placed
    assert {g.group_id: g.free.intervals()
            for g in pol.groups} == snap_free


# -------------------------------------------------- oracle agreement
def _plan_sig(plan):
    return ([(m.job_id, m.src_group, m.dst_group, m.shift, m.vacates)
             for m in plan.moves], list(plan.reshifts))


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_incremental_plan_matches_oracle_exact_mode(data):
    """On an all-dirty state with no floor, no destination cap and no
    pruning, the index's decisions must be BIT-IDENTICAL to the full
    planner's: same moves (job, src, dst, shift, vacates flag) in the
    same order, same reshift set."""
    n_groups = data.draw(st.integers(2, 4))
    pol = _fresh_policy(n_groups)
    counter = itertools.count()
    alive = []
    now = 0.0
    for _ in range(data.draw(st.integers(4, 14))):
        op = data.draw(st.sampled_from(["add", "add", "add", "remove",
                                        "advance"]))
        if op == "add":
            job = f"j{next(counter)}"
            if pol.place_warm(job, _random_trace(data),
                              origin=now) is not None:
                alive.append(job)
        elif op == "remove" and alive:
            pol.remove(alive.pop(data.draw(st.integers(0, len(alive) - 1))))
        elif op == "advance":
            now += data.draw(st.floats(0.0, 20.0))
            for g in pol.groups:
                g.advance_to(now)
                g.extend_to(now + HORIZON)
    oracle = pol.plan_repack(origin=now, min_gain=0.0)
    inc = RepackIndex(pol).plan(origin=now, min_gain=0.0,
                                max_dest_search=None, prune_dests=False)
    assert _plan_sig(inc) == _plan_sig(oracle)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_incremental_apply_sound_gains_and_invariants(data):
    """Randomized add/remove/drift/plan/apply sequences through the index
    with pruning and destination caps ON: every emitted cross-group move
    must clear the migration-cost floor (or vacate its source), its
    claimed gain must be realized when the deltas are replayed in plan
    order onto the live state, and the placement invariants (single
    reservation, no cycle-0 double-booking, reserved∩free empty) must
    hold after every apply."""
    floor = 0.001
    n_groups = data.draw(st.integers(2, 4))
    pol = _fresh_policy(n_groups)
    idx = RepackIndex(pol)
    counter = itertools.count()
    alive = []
    now = 0.0
    for _ in range(data.draw(st.integers(6, 20))):
        op = data.draw(st.sampled_from(
            ["add", "add", "add", "remove", "advance", "drift", "plan"]))
        if op == "add":
            job = f"j{next(counter)}"
            if pol.place_warm(job, _random_trace(data),
                              origin=now) is not None:
                alive.append(job)
        elif op == "remove" and alive:
            pol.remove(alive.pop(data.draw(st.integers(0, len(alive) - 1))))
        elif op == "advance":
            now += data.draw(st.floats(0.0, 20.0))
            for g in pol.groups:
                g.advance_to(now)
                g.extend_to(now + HORIZON)
        elif op == "drift" and pol.groups:
            gids = sorted(g.group_id for g in pol.groups)
            idx.mark_dirty(gids[data.draw(st.integers(0, len(gids) - 1))])
        elif op == "plan":
            cap = data.draw(st.sampled_from([None, 1, 3]))
            plan = idx.plan(origin=now, min_gain=floor,
                            max_dest_search=cap)
            for m in plan.moves:
                assert m.vacates or m.gain >= floor
            # replay deltas exactly like apply_repack and pin each claimed
            # gain against the live state at its decision point
            for m in plan.deltas:
                cur = pol.placed.get(m.job_id)
                assert cur is not None and cur.group_id == m.src_group
                before = phase_interference(
                    cur.trace, cur.shift, pol.group(cur.group_id),
                    cur.origin, exclude=m.job_id)
                pol.remove(m.job_id)
                pol.place_at(m.job_id, cur.trace, m.dst_group, m.shift,
                             origin=m.origin, n_cycles=m.n_cycles)
                if m.src_group != m.dst_group:
                    after = phase_interference(
                        cur.trace, m.shift, pol.group(m.dst_group),
                        m.origin, exclude=m.job_id)
                    assert before - after == pytest.approx(m.gain, abs=1e-6)
        _check_invariants(pol, alive)
