"""StateManager: residency tiers, canonical dedup, materialisation, host
optimizer, migration."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.state_manager import StateManager, Tier
from repro.train import optimizer as opt


def _tree(seed=0, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    return {
        "w1": jax.random.normal(k, (8, 16), dtype),
        "nested": {"w2": jnp.ones((4,), dtype)},
    }


def test_register_offload_prefetch_roundtrip(tmp_path):
    sm = StateManager(disk_dir=str(tmp_path))
    tree = _tree()
    keys = sm.register("job", tree)
    assert sm.usage()["DEVICE"] > 0
    sm.offload(keys, Tier.HOST)
    assert sm.usage()["DEVICE"] == 0 and sm.usage()["HOST"] > 0
    sm.offload(keys, Tier.DISK)
    assert sm.usage()["HOST"] == 0 and sm.usage()["DISK"] > 0
    sm.prefetch(keys)
    assert sm.usage()["DEVICE"] > 0
    out = sm.gather("job", jax.tree.map(lambda x: x, tree))
    np.testing.assert_allclose(np.asarray(out["w1"]), np.asarray(tree["w1"]))


def test_bf16_disk_roundtrip(tmp_path):
    sm = StateManager(disk_dir=str(tmp_path))
    tree = _tree(dtype=jnp.bfloat16)
    keys = sm.register("job", tree)
    sm.offload(keys, Tier.DISK)
    sm.prefetch(keys)
    out = sm.gather("job", tree)
    assert out["w1"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["w1"], np.float32), np.asarray(tree["w1"], np.float32))


def test_canonical_dedup_refcount(tmp_path):
    sm = StateManager(disk_dir=str(tmp_path))
    tree = _tree()
    k1 = sm.register("job", tree)            # replica 1
    bytes_once = sm.usage()["DEVICE"]
    k2 = sm.register("job", tree)            # data-parallel replica 2
    assert k1 == k2
    assert sm.usage()["DEVICE"] == bytes_once    # deduplicated (§4.5.2)
    sm.unregister(k2)
    assert sm.usage()["DEVICE"] == bytes_once    # still referenced
    sm.unregister(k1)
    assert sm.usage()["DEVICE"] == 0


def test_capacity_eviction_lru(tmp_path):
    tree = {"a": jnp.ones((1024,), jnp.float32),
            "b": jnp.ones((1024,), jnp.float32)}
    sm = StateManager(disk_dir=str(tmp_path), device_capacity=5000)
    sm.register("job", tree)
    # 8KB registered > 5000B capacity -> one entry must have been evicted
    assert sm.usage()["DEVICE"] <= 5000
    assert sm.usage()["HOST"] > 0


def test_materialize_checkpoint_from_offloaded(tmp_path):
    sm = StateManager(disk_dir=str(tmp_path / "disk"))
    tree = _tree()
    keys = sm.register("job", tree)
    sm.offload(keys, Tier.HOST)               # checkpoint despite offload
    path = sm.materialize_checkpoint("job", tree, str(tmp_path / "ckpt"))
    from repro.train import checkpoint as ckpt
    restored, meta = ckpt.restore(path, tree)
    np.testing.assert_allclose(np.asarray(restored["w1"]),
                               np.asarray(tree["w1"]))
    assert meta["job_id"] == "job"


def test_host_optimizer_matches_device_adamw(tmp_path):
    """§4.5.4 CPU optimizer == the jitted AdamW (same hyperparams, no wd)."""
    sm = StateManager(disk_dir=str(tmp_path))
    params = _tree(seed=1)
    grads = jax.tree.map(lambda p: jnp.full_like(p, 0.1), params)
    sm.register("job", params)
    sm.host_optimizer_step("job", grads, params, lr=1e-2)
    host_out = sm.gather("job", params)

    cfg = opt.AdamWConfig(lr=1e-2, grad_clip=0.0, warmup_steps=0,
                          weight_decay=0.0)
    state = opt.init(params, cfg)
    dev_out, _, _ = opt.update(grads, state, params, cfg)
    for k in ("w1",):
        np.testing.assert_allclose(np.asarray(host_out[k]),
                                   np.asarray(dev_out[k]), rtol=1e-5,
                                   atol=1e-6)


def test_migration_moves_all_state(tmp_path):
    src = StateManager(node_id="src", disk_dir=str(tmp_path / "a"))
    dst = StateManager(node_id="dst", disk_dir=str(tmp_path / "b"))
    tree = _tree()
    src.register("job", tree)
    moved = src.migrate("job", dst)
    assert moved > 0
    assert not src.keys_for("job")
    out = dst.gather("job", tree)
    np.testing.assert_allclose(np.asarray(out["nested"]["w2"]),
                               np.asarray(tree["nested"]["w2"]))


def test_sync_weights_resharding_cast(tmp_path):
    sm = StateManager(disk_dir=str(tmp_path))
    tree = _tree()
    sm.register("job", tree)
    synced = sm.sync_weights("job", tree, dtype=jnp.bfloat16)
    assert synced["w1"].dtype == jnp.bfloat16
