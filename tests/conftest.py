import os

# Keep tests on the single real CPU device (the 512-device flag is ONLY for
# the dry-run process — see src/repro/launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
