"""Property tests for the repack planner (ISSUE 5 satellite): after ANY
randomized add / remove / advance / repack sequence,

- every surviving job retains exactly ONE reservation (one ``Placed`` in
  ``policy.placed``, listed once in exactly one group's resident list,
  group ids consistent), and
- no group's reserved windows double-book: the feasibility-checked
  cycle-0 anatomy of any two residents of a group never overlaps, and a
  resident's cycle-0 windows are never simultaneously marked free.

(Only the aligned first cycle is feasibility-checked by design — later
cycles of differently-periodic jobs are blind-subtracted so the window
ends up busy either way; the predicted cost of that approximation is what
``phase_interference`` scores. The invariants here are exactly the ones
``place_warm`` / ``remove`` / ``plan_repack`` / ``apply_repack`` promise.)
"""
import itertools

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.scheduler.intervals import IntervalSet
from repro.core.scheduler.placement import (JobTrace, NodeGroup,
                                            PlacementConfig, PlacementPolicy)

HORIZON = 400.0
EPS = 1e-9


def _random_trace(data) -> JobTrace:
    period = data.draw(st.floats(6.0, 24.0))
    rollout = period * data.draw(st.floats(0.3, 0.7))
    budget = period - rollout
    n_segs = data.draw(st.integers(1, 2))
    segs, t = [], rollout
    for i in range(n_segs):
        d = budget / n_segs * data.draw(st.floats(0.4, 1.0))
        segs.append((t, d))
        t += d
    return JobTrace(period=period, segments=tuple(segs))


def _cycle0_windows(p):
    return [(p.origin + p.shift + a, p.origin + p.shift + a + d)
            for a, d in p.trace.segments]


def _check_invariants(pol: PlacementPolicy, alive):
    assert sorted(pol.placed) == sorted(alive)
    seen = {}
    for g in pol.groups:
        for p in g.resident:
            assert p.job_id not in seen, \
                f"{p.job_id} holds reservations on {seen[p.job_id]} AND " \
                f"{g.group_id}"
            seen[p.job_id] = g.group_id
            assert pol.placed.get(p.job_id) is p
            assert p.group_id == g.group_id
    assert set(seen) == set(pol.placed), "orphaned reservation"
    for g in pol.groups:
        booked = []
        for p in sorted(g.resident, key=lambda p: p.job_id):
            for s, e in _cycle0_windows(p):
                for s2, e2, other in booked:
                    assert min(e, e2) - max(s, s2) <= EPS, \
                        f"group {g.group_id}: {p.job_id} cycle-0 window " \
                        f"[{s}, {e}) double-books {other}'s [{s2}, {e2})"
                # a reserved window must not simultaneously be free
                for fs, fe in g.free.intervals():
                    assert min(e, fe) - max(s, fs) <= EPS, \
                        f"group {g.group_id}: reserved [{s}, {e}) of " \
                        f"{p.job_id} overlaps free [{fs}, {fe})"
                booked.append((s, e, p.job_id))


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_repack_sequences_never_double_book(data):
    n_groups = data.draw(st.integers(2, 4))
    pol = PlacementPolicy(
        [NodeGroup(g, 1, IntervalSet([(0.0, HORIZON)]))
         for g in range(n_groups)],
        PlacementConfig(horizon=HORIZON))
    counter = itertools.count()
    alive = []
    now = 0.0
    for _ in range(data.draw(st.integers(6, 24))):
        op = data.draw(st.sampled_from(
            ["add", "add", "add", "cold", "remove", "repack", "advance"]))
        if op == "add":
            job = f"j{next(counter)}"
            if pol.place_warm(job, _random_trace(data),
                              origin=now) is not None:
                alive.append(job)
        elif op == "cold":
            job = f"c{next(counter)}"
            dur = data.draw(st.floats(10.0, 60.0))
            if pol.place_cold(job, 1, dur, origin=now) is not None:
                alive.append(job)
        elif op == "remove" and alive:
            job = alive.pop(data.draw(st.integers(0, len(alive) - 1)))
            pol.remove(job)
        elif op == "repack":
            min_gain = data.draw(st.sampled_from([0.0, 0.001,
                                                  float("inf")]))
            pol.repack(origin=now, min_gain=min_gain)
        elif op == "advance":
            now += data.draw(st.floats(0.0, 30.0))
            for g in pol.groups:
                g.advance_to(now)
                g.extend_to(now + HORIZON)
        _check_invariants(pol, alive)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_plan_repack_never_mutates_live_state(data):
    """plan_repack must be a pure function of the state: planning twice is
    idempotent and leaves every reservation and free window untouched."""
    pol = PlacementPolicy(
        [NodeGroup(g, 1, IntervalSet([(0.0, HORIZON)])) for g in range(3)],
        PlacementConfig(horizon=HORIZON))
    for i in range(data.draw(st.integers(1, 6))):
        pol.place_warm(f"j{i}", _random_trace(data), origin=0.0)
    snap_placed = {j: (p.group_id, p.shift, p.origin)
                   for j, p in pol.placed.items()}
    snap_free = {g.group_id: g.free.intervals() for g in pol.groups}
    plan1 = pol.plan_repack(origin=0.0)
    plan2 = pol.plan_repack(origin=0.0)
    assert {j: (p.group_id, p.shift, p.origin)
            for j, p in pol.placed.items()} == snap_placed
    assert {g.group_id: g.free.intervals()
            for g in pol.groups} == snap_free
    assert [(m.job_id, m.src_group, m.dst_group, m.shift)
            for m in plan1.moves] == \
        [(m.job_id, m.src_group, m.dst_group, m.shift)
         for m in plan2.moves]
