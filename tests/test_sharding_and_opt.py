"""Sharding rule resolution properties + optimizer correctness + checkpoint
roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # vendored fallback (seeded numpy)
    from _hypothesis_compat import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.models import sharding as shd
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt


def _mesh(data=2, model=1):
    # only 1 real device in tests: use trivial mesh but exercise the logic
    return jax.make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Shape-only stand-in so divisibility logic can be tested for the
    production sizes without 512 devices."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(self.shape)


@pytest.mark.parametrize("axes,shape,expect", [
    (("vocab", "embed"), (151_936, 2560), P("model", "data")),
    (("vocab", "embed"), (50_280, 2560), P(None, "data")),        # 50280 % 16 != 0
    (("embed", "heads", "head_dim"), (2560, 32, 128), P("data", "model")),
    (("embed", "heads", "head_dim"), (7168, 56, 128), P("data",)),  # 56 % 16
    (("experts", "embed", "expert_mlp"), (128, 7168, 4864), P("model", "data")),
    (("experts", "embed", "expert_mlp"), (40, 1536, 512), P(None, "data")),
])
def test_resolve_best_effort_divisibility(axes, shape, expect):
    mesh = _FakeMesh({"data": 16, "model": 16})
    got = shd.resolve(axes, mesh, shd.RULES_FSDP_TP, shape=shape)
    assert got == expect


def test_resolve_cache_hd_fallback():
    mesh = _FakeMesh({"data": 16, "model": 16})
    axes = ("layers", "cache_batch", "cache_seq", "kv_heads", "cache_hd")
    # kv divisible (gemma kv=16): kv takes model, head_dim unsharded
    got = shd.resolve(axes, mesh, shd.RULES_TP, shape=(46, 128, 32768, 16, 128))
    assert got == P(None, "data", None, "model")
    # kv NOT divisible (qwen3 kv=8): the fallback gives head_dim the model
    # axis (NOT seq — a decode-time dynamic-update-slice on a seq-sharded
    # buffer forces SPMD rematerialisation)
    got = shd.resolve(axes, mesh, shd.RULES_TP, shape=(36, 128, 32768, 8, 128))
    assert got == P(None, "data", None, None, "model")
    # prefill OUTPUT layout: seq-sharded over model
    axes_out = ("layers", "cache_batch", "cache_seq_out", "kv_heads", None)
    got = shd.resolve(axes_out, mesh, shd.RULES_TP,
                      shape=(36, 32, 32768, 8, 128))
    assert got == P(None, "data", "model")


def test_resolve_never_reuses_mesh_axis():
    mesh = _FakeMesh({"data": 16, "model": 16})
    got = shd.resolve(("vocab", "mlp"), mesh, shd.RULES_TP, shape=(160, 160))
    flat = [a for e in got for a in (e if isinstance(e, tuple) else (e,)) if a]
    assert len(flat) == len(set(flat))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(["embed", "vocab", "heads", "mlp", None]),
                min_size=1, max_size=4),
       st.lists(st.integers(1, 4096), min_size=4, max_size=4))
def test_resolve_divisibility_property(axes, dims):
    mesh = _FakeMesh({"data": 16, "model": 16})
    shape = tuple(dims[:len(axes)])
    spec = shd.resolve(tuple(axes), mesh, shd.RULES_FSDP_TP, shape=shape)
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        axes_t = entry if isinstance(entry, tuple) else (entry,)
        total = int(np.prod([mesh.shape[a] for a in axes_t]))
        assert shape[i] % total == 0


# -------------------------------------------------------------- optimizer
def test_adamw_first_step_matches_analytic():
    cfg = opt.AdamWConfig(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8,
                          grad_clip=0.0, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 0.5)}
    state = opt.init(params, cfg)
    new_p, new_s, metrics = opt.update(grads, state, params, cfg)
    # after bias correction, first-step delta = lr * g/|g| = lr
    np.testing.assert_allclose(np.asarray(new_p["w"]), 1.0 - 1e-2, rtol=1e-5)
    assert int(new_s.step) == 1


def test_grad_clip_scales_large_grads():
    cfg = opt.AdamWConfig(grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 100.0)}
    state = opt.init(params, cfg)
    _, _, metrics = opt.update(grads, state, params, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_zero_moment_spec_adds_data_axis():
    mesh = _FakeMesh({"data": 16, "model": 16})
    got = opt.zero_moment_spec(P(None, "model"), (2560, 9728), mesh)
    assert got == P("data", "model")
    # already data-sharded param: unchanged
    got = opt.zero_moment_spec(P("data", "model"), (2560, 9728), mesh)
    assert got == P("data", "model")
    # nothing divisible: unchanged
    got = opt.zero_moment_spec(P(), (7,), mesh)
    assert got == P()


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.float32) * 3}}
    path = ckpt.save(str(tmp_path / "step_1"), tree, step=7)
    restored, meta = ckpt.restore(path, tree)
    assert meta["step"] == 7
    assert restored["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    assert ckpt.latest(str(tmp_path)) == path
