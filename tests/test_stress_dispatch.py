"""Stress/soak lane for the dispatch plane (``pytest -m slow``).

Deep queues through the concurrent plane: 8 node groups x 200 zero-cost ops
must drain through ``run_until_idle`` without leaking dispatcher threads and
within a bounded wall clock (the incremental admission index keeps per-op
control overhead flat at this depth), and the serial ``drain()`` replay of
the same deep workload under a ``VirtualClock`` must produce a bit-identical
admission order across two runs.

A churn round soaks the persistent serve plane: jobs attach on rotating
groups, chain dataflow ops, and detach mid-flight; every future must settle
and the plane must shut down clean.

Tier-1 (`python -m pytest -x -q`) deselects this module via the ``slow``
marker registered in pytest.ini.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import api
from repro.core.cluster import BillingRecord, PlexCluster
from repro.core.control_plane import DirectorConfig
from repro.core.router import Router
from repro.core.scheduler.executor import State, VirtualClock
from test_dispatch import StubWPG, make_router, submit_batch

pytestmark = pytest.mark.slow

N_GROUPS = 8
OPS_PER_GROUP = 200


def _dispatcher_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("dispatch-") and t.is_alive()]


def test_deep_queue_soak_no_leaked_dispatchers():
    assert not _dispatcher_threads(), "stale dispatchers from another test"
    r, specs, trace = make_router(n_groups=N_GROUPS, duration=0.0)
    for s in specs:
        submit_batch(r, s, OPS_PER_GROUP)
    t0 = time.monotonic()
    n = r.run_until_idle(timeout=120.0)
    wall = time.monotonic() - t0
    assert n == N_GROUPS * OPS_PER_GROUP
    assert len(trace) == N_GROUPS * OPS_PER_GROUP
    # bounded wall clock: deep queues must not regress to the full-rescore
    # O(n^2) control plane (1600 zero-cost ops in well under a minute)
    assert wall < 60.0, f"dispatch plane took {wall:.1f}s for {n} ops"
    # teardown is complete by the time run_until_idle returns: every
    # worker thread joined, no 50 ms stragglers
    assert not _dispatcher_threads(), "leaked dispatcher threads"
    assert not r.pending
    assert all(t.state == State.COMPLETED
               for t in r.executor.tasks.values())
    assert all(lock.holder is None for lock in r.executor.locks.values())


def test_repeated_soak_rounds_reuse_clean_plane():
    """Back-to-back run_until_idle rounds on one Router: thread count must
    not creep (each round tears down fully before returning)."""
    r, specs, trace = make_router(n_groups=4, duration=0.0)
    for round_no in range(3):
        for s in specs:
            submit_batch(r, s, 50)
        n = r.run_until_idle(timeout=60.0)
        assert n == 4 * 50, f"round {round_no}"
        assert not _dispatcher_threads(), f"round {round_no} leaked"
    assert len(trace) == 3 * 4 * 50


def _virtual_deep_run():
    """Serial drain of the deep workload under a VirtualClock; returns the
    admission order as submission ordinals (req_ids differ across runs
    because api.make_op's counter is global)."""
    clock = VirtualClock()
    trace = []
    router = Router(now=clock,
                    wpg_factory=lambda spec, sm: StubWPG(spec, sm, 0.0,
                                                         trace))
    specs = []
    for g in range(N_GROUPS):
        spec = api.DeploymentSpec(deployment_id=f"dep{g}",
                                  job_id=f"job{g % 3}", model_name="stub",
                                  role="train")
        router.create_deployment(spec, group_id=g)
        specs.append(spec)
    ordinal = {}
    for i in range(OPS_PER_GROUP):
        for spec in specs:
            qop = api.make_op(spec, api.Op.FORWARD, i,
                              exec_estimate=0.5 + (i * 7 + 3) % 11)
            router.submit_queued_operation(qop)
            ordinal[qop.req_id] = len(ordinal)
            clock.advance(0.125)     # exact in binary: no float drift
    router.drain()
    assert not router.pending
    return [ordinal[req_id] for _, req_id, _, _ in trace]


def test_serial_replay_bit_identical_admission_order():
    first = _virtual_deep_run()
    second = _virtual_deep_run()
    assert len(first) == N_GROUPS * OPS_PER_GROUP
    assert first == second, "virtual-clock replay diverged between runs"


def _serve_worker_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("serve-") and t.is_alive()]


class _GenHeavyStub:
    """Stub backend with a rollout-heavy phase profile (low training duty),
    so profiled jobs genuinely pack onto shared groups."""

    def __init__(self, spec, sm):
        self.spec = spec
        self.sm = sm
        self.exec_log = []

    @property
    def job_prefix(self):
        return f"{self.spec.job_id}:{self.spec.deployment_id}"

    def resident(self):
        return False

    def ensure_resident(self):
        return 0.0

    def offload(self, to=None):
        return 0.0

    def execute(self, qop):
        t0 = time.monotonic()
        time.sleep(0.02 if qop.op is api.Op.GENERATE else 0.002)
        self.exec_log.append((qop.op.value, time.monotonic() - t0))
        return {"req_id": qop.req_id}


def test_control_plane_churn_soak():
    """Soak the live control plane with add/remove/autoscale churn for 14
    rounds: jobs arrive through the director (cold profiling groups spawn),
    get warm-fitted and migrated onto shared groups, and detach. Invariants
    per round: the serve-worker thread set matches the router's registry
    (retire tears workers down, nothing leaks), and every group hosting a
    deployment is tracked by the director's placement policy (no orphaned
    groups). At the end: billing totals reconcile exactly against the
    per-WPG exec logs ACROSS all migrations, and the fleet shrinks back to
    ``min_groups``."""
    c = PlexCluster(
        n_groups=1, wpg_factory=lambda spec, sm: _GenHeavyStub(spec, sm),
        director_cfg=DirectorConfig(horizon=120.0, cold_reserve_s=10.0,
                                    warmup_cycles=0, min_groups=1))
    r = c.router
    wpgs_ever = {}
    live = {}
    migrations = 0
    with r:
        for round_no in range(14):
            job = f"soak{round_no}"
            gid = c.director.assign(job)
            spec = api.DeploymentSpec(deployment_id=f"{job}-train",
                                      job_id=job, model_name="stub",
                                      role="train")
            dep = r.deploy(spec, group_id=gid)
            wpgs_ever[spec.deployment_id] = r.wpgs[spec.deployment_id]
            c.billing.setdefault(job, BillingRecord(job))
            live[job] = spec.deployment_id
            for _ in range(2):        # two profiled GRPO-shaped cycles
                gen = dep.generate(np.zeros((1, 2), np.int32),
                                   exec_estimate=2.0)
                upd = dep.update_actor(0, exec_estimate=0.2, after=(gen,))
                upd.wait(timeout=60.0)
                c.director.on_job_step(job)
            migrations = sum(e["event"] == "migrate"
                             for e in c.director.events)
            if round_no % 2 == 0:     # detach every other job mid-churn
                r.wait_idle(timeout=60.0)
                with c._bill_lock:
                    c._bill_from_logs()
                r.teardown(live.pop(job))
                c.director.on_job_removed(job)
            # ---- per-round invariants
            r.wait_idle(timeout=60.0)
            workers = {t.name for t in threading.enumerate()
                       if t.name.startswith("serve-") and t.is_alive()}
            assert workers == {f"serve-g{g}" for g in r._serve_threads}, \
                f"round {round_no}: leaked/missing serve workers"
            policy_groups = {g.group_id for g in c.director.policy.groups}
            hosted = set(r.group_of.values())
            assert hosted <= policy_groups, \
                f"round {round_no}: orphaned groups {hosted - policy_groups}"
        # the flow actually exercised migration (warm consolidation)
        assert migrations >= 3, f"only {migrations} migrations in 14 rounds"
        # drain the survivors
        r.wait_idle(timeout=60.0)
        with c._bill_lock:
            c._bill_from_logs()
        for job, dep_id in list(live.items()):
            r.teardown(dep_id)
            c.director.on_job_removed(job)
        assert len(c.director.policy.groups) == 1   # shrunk to min_groups
    assert not _serve_worker_threads(), "leaked serve workers"
    assert not _dispatcher_threads(), "leaked dispatcher threads"
    # ---- billing reconciles bit-for-bit across every migration
    for job_id, rec in c.billing.items():
        logged = sum(dt for dep_id, w in wpgs_ever.items()
                     if w.spec.job_id == job_id for _, dt in w.exec_log)
        assert rec.busy_seconds == pytest.approx(logged, rel=1e-9), job_id
        assert rec.busy_seconds > 0.0, job_id
    assert not r.pending


def test_job_churn_against_live_serve_plane():
    """Soak the persistent plane with attach/detach churn: jobs join on
    rotating groups, submit chained dataflow ops, and half detach with work
    still queued. Every future must settle (result or teardown/poison
    error), queues must drop with their jobs, and shutdown must leave no
    dispatcher threads."""
    assert not _serve_worker_threads(), "stale serve workers"
    trace = []
    router = Router(wpg_factory=lambda spec, sm: StubWPG(spec, sm, 0.001,
                                                         trace))
    settled, survivors = [], []
    with router:
        for round_no in range(12):
            deps = []
            for j in range(4):
                spec = api.DeploymentSpec(
                    deployment_id=f"r{round_no}-d{j}",
                    job_id=f"r{round_no}-job{j}", model_name="stub",
                    role="train")
                deps.append(router.deploy(spec, group_id=j % 3))
            for dep in deps:
                first = dep.forward(0)
                chained = dep.update_actor(
                    first.then(lambda res: {"from": res["req_id"]}))
                settled.extend([first, chained])
            # detach half the round's jobs with ops still in flight
            for dep in deps[::2]:
                router.teardown(dep.deployment_id)
            # the others run to completion before the next round piles on
            for dep in deps[1::2]:
                survivors.append(dep.forward(1))
        router.wait_idle(timeout=120.0)
    resolved = errored = 0
    for f in settled:
        assert f.done(), "future never settled under churn"
        try:
            f.result()
            resolved += 1
        except RuntimeError:
            errored += 1
    assert resolved and errored, (resolved, errored)
    for f in survivors:
        assert f.result()["req_id"] > 0   # surviving jobs kept progressing
    # detached jobs' queues dropped; surviving jobs' queues drained empty
    assert all(not q for q in router.request_queues.values())
    assert not router.pending
    assert not _serve_worker_threads(), "leaked serve workers"
    assert all(lock.holder is None
               for lock in router.executor.locks.values())
