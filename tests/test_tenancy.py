"""The multi-tenant service layer (core/tenancy/ + its wiring through the
cluster, router, scheduler, and director planes).

Covers:
- ``TenantSpec`` validation and the default tenant's identity guarantee,
- ``TenantLedger``: nearest-rank p95, SLO-breach predicate (GUARANTEED
  only, min-samples gated), accounting snapshot,
- quota admission through ``PlexCluster.add_job``: typed ``AdmissionDenied``
  for group/gpu quota, unknown tenants (always a hard denial), and
  no-feasible-placement; ``queue_on_deny`` parking + the priority-ordered
  drain on ``remove_job``,
- ``PlacementDirector.placement_feasible``: duty-slack based, never spawns,
- the SLO trigger end-to-end under VirtualClock: breach -> preempt (shed
  onto a spawned group via the existing migrate machinery) and breach ->
  admission hold when the fleet is at max size, with recovery releasing the
  hold -- plus bit-identical replay of the two-tenant preemption scenario,
- ``Router.wait_idle`` timeout regression and ``tenant_telemetry``,
- preemption-vs-teardown race: detaching a BEST_EFFORT job whose op is
  RUNNING bills its gpu-seconds to its tenant and leaves the GUARANTEED
  job's futures unpoisoned,
- slow lane: the two-tenant soak -- a greedy BEST_EFFORT tenant cannot
  push a GUARANTEED tenant's p95 past its SLO while still getting >0
  throughput itself.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import api, tenancy
from repro.core.cluster import PlexCluster
from repro.core.control_plane import DirectorConfig, PlacementDirector
from repro.core.control_plane.plan import JobTrace
from repro.core.controller import JobConfig
from repro.core.scheduler.executor import VirtualClock
from test_control_plane import _spec, _virtual_router
from test_dispatch import StubWPG

TINY = (("num_layers", 2), ("d_model", 32), ("num_heads", 4),
        ("num_kv_heads", 2), ("head_dim", 8), ("d_ff", 64),
        ("vocab_size", 64), ("tie_embeddings", True))

GUARANTEED = tenancy.TenantClass.GUARANTEED


def _stub_cluster(n_groups=1, **kw):
    trace = []
    return PlexCluster(
        n_groups=n_groups,
        wpg_factory=lambda spec, sm: StubWPG(spec, sm, 0.0, trace), **kw)


def _cfg(job_id, tenant="default", steps=1):
    return JobConfig(job_id=job_id, model_name="stub", steps=steps,
                     tenant=tenant)


# --------------------------------------------------------------- model
def test_tenant_spec_validation():
    with pytest.raises(ValueError, match="non-empty"):
        tenancy.TenantSpec(tenant_id="")
    with pytest.raises(ValueError, match="priority"):
        tenancy.TenantSpec(tenant_id="t", priority=0.0)
    with pytest.raises(ValueError, match="priority"):
        tenancy.TenantSpec(tenant_id="t", priority=-1.0)
    with pytest.raises(ValueError, match="quota_groups"):
        tenancy.TenantSpec(tenant_id="t", quota_groups=-1)
    with pytest.raises(ValueError, match="quota_gpu_s"):
        tenancy.TenantSpec(tenant_id="t", quota_gpu_s=-0.5)


def test_default_tenant_is_identity():
    reg = tenancy.TenantRegistry()
    spec = reg.get(tenancy.DEFAULT_TENANT)
    assert spec.priority == 1.0                  # multiplicative identity
    assert spec.class_ == tenancy.TenantClass.BEST_EFFORT
    assert spec.quota_groups is None and spec.quota_gpu_s is None
    assert spec.slo_step_latency_s is None
    assert not reg.known("ghost")


# ---------------------------------------------------------- accounting
def test_p95_nearest_rank():
    assert tenancy.p95([]) is None
    assert tenancy.p95([3.0]) == 3.0
    assert tenancy.p95([1.0, 2.0, 3.0, 4.0]) == 4.0      # ceil(3.8)-1 = 3
    assert tenancy.p95(list(range(1, 21))) == 19         # ceil(19)-1 = 18
    assert tenancy.p95([5.0, 1.0, 9.0]) == 9.0           # order-free


def test_ledger_slo_breach_predicate():
    reg = tenancy.TenantRegistry()
    reg.register(tenancy.TenantSpec("gold", class_=GUARANTEED,
                                    slo_step_latency_s=2.0))
    reg.register(tenancy.TenantSpec("scav", slo_step_latency_s=2.0))
    led = tenancy.TenantLedger(reg, slo_window=4, slo_min_samples=2)
    led.bind_job("g1", "gold")
    led.bind_job("b1", "scav")
    led.record_step("g1", 9.0)
    assert not led.slo_breach("g1"), "one sample must never trigger"
    led.record_step("g1", 9.0)
    assert led.step_p95("gold") == 9.0
    assert led.slo_breach("g1")
    # BEST_EFFORT tenants never breach, SLO set or not
    led.record_step("b1", 9.0)
    led.record_step("b1", 9.0)
    assert not led.slo_breach("b1")
    # unbound jobs fall back to the (SLO-free) default tenant
    assert not led.slo_breach("nobody")
    # the window rolls: four fast steps flush the slow ones out
    for _ in range(4):
        led.record_step("g1", 1.0)
    assert led.step_p95("gold") == 1.0 and not led.slo_breach("g1")
    snap = led.snapshot()
    assert snap["gold"]["steps_total"] == 6
    assert snap["gold"]["slo_attainment"] == pytest.approx(4 / 6)
    assert snap["scav"]["slo_attainment"] == 0.0


# ----------------------------------------------------- quota admission
def test_group_quota_denies_queues_and_drains_on_remove():
    c = _stub_cluster()
    c.register_tenant(tenancy.TenantSpec("acme", priority=2.0,
                                         quota_groups=1))
    assert c.add_job(_cfg("a1", "acme")) is not None
    with pytest.raises(tenancy.AdmissionDenied) as ei:
        c.add_job(_cfg("a2", "acme"))
    assert ei.value.reason == tenancy.REASON_GROUP_QUOTA
    assert ei.value.tenant_id == "acme" and ei.value.job_id == "a2"
    # queue_on_deny parks instead of raising; telemetry shows the depth
    assert c.add_job(_cfg("a3", "acme"), queue_on_deny=True) is None
    assert "a3" not in c.controllers
    assert c.admission.pending_depth("acme") == 1
    assert c.router.tenant_telemetry()["acme"]["pending_jobs"] == 1
    # releasing the quota replays the pending queue FIFO
    c.remove_job("a1")
    assert "a3" in c.controllers
    assert c.admission.pending_depth("acme") == 0
    assert c.admission.active_count("acme") == 1
    # the drained job is fully wired: tenant bound, priority stamped
    assert c.tenant_ledger.tenant_of("a3") == "acme"
    assert c.router.job_priority["a3"] == 2.0


def test_gpu_quota_is_an_admission_gate():
    c = _stub_cluster()
    c.register_tenant(tenancy.TenantSpec("acme", quota_gpu_s=10.0))
    assert c.add_job(_cfg("a1", "acme")) is not None
    c.tenant_ledger.add_gpu_seconds("acme", 10.5)    # budget consumed
    with pytest.raises(tenancy.AdmissionDenied) as ei:
        c.add_job(_cfg("a2", "acme"))
    assert ei.value.reason == tenancy.REASON_GPU_QUOTA
    # the running job is NOT killed for it (admission-time only)
    assert "a1" in c.controllers


def test_unknown_tenant_is_always_a_hard_denial():
    c = _stub_cluster()
    with pytest.raises(tenancy.AdmissionDenied) as ei:
        c.add_job(_cfg("x1", "ghost"), queue_on_deny=True)
    assert ei.value.reason == tenancy.REASON_UNKNOWN_TENANT
    assert c.admission.pending_depth("ghost") == 0


def test_no_feasible_placement_denial_and_drain(monkeypatch):
    c = _stub_cluster()
    c.register_tenant(tenancy.TenantSpec("acme"))
    assert c.add_job(_cfg("d1")) is not None     # default tenant, admitted
    monkeypatch.setattr(c.director, "placement_feasible", lambda: False)
    with pytest.raises(tenancy.AdmissionDenied) as ei:
        c.add_job(_cfg("a1", "acme"))
    assert ei.value.reason == tenancy.REASON_NO_PLACEMENT
    assert c.add_job(_cfg("a2", "acme"), queue_on_deny=True) is None
    monkeypatch.undo()
    # capacity reappears: remove_job's drain admits the parked submission
    c.remove_job("d1")
    assert "a2" in c.controllers


def test_drain_order_priority_desc_then_fifo():
    reg = tenancy.TenantRegistry()
    reg.register(tenancy.TenantSpec("lo", priority=1.0))
    reg.register(tenancy.TenantSpec("hi", priority=4.0))
    led = tenancy.TenantLedger(reg)
    adm = tenancy.AdmissionController(reg, led)

    def pend(tenant, job):
        adm.enqueue(tenant, tenancy.PendingJob(
            cfg=_cfg(job, tenant), group_id=0, algo="grpo", enqueued_t=0.0))

    pend("lo", "l1")
    pend("hi", "h1")
    pend("hi", "h2")
    ready = adm.drain(lambda: True)
    assert [p.cfg.job_id for p in ready] == ["h1", "h2", "l1"]
    assert adm.active_count("hi") == 2           # drain reserved the quota
    # a failing head blocks ITS queue only (FIFO preserved, no jumping)
    reg.register(tenancy.TenantSpec("hi", priority=4.0, quota_groups=2))
    pend("hi", "h3")
    pend("lo", "l2")
    ready = adm.drain(lambda: True)
    assert [p.cfg.job_id for p in ready] == ["l2"]
    assert adm.pending_depth("hi") == 1


def test_placement_feasible_duty_slack():
    _, router = _virtual_router()
    director = PlacementDirector(
        router, DirectorConfig(horizon=100.0, max_groups=1),
        initial_groups=[0])
    assert director.placement_feasible()
    # a duty-1.0 job saturates the only group; max_groups forbids spawning
    director.adopt_warm("hog", JobTrace(8.0, ((0.0, 8.0),)), 0)
    assert not director.placement_feasible()
    director.on_job_removed("hog")
    assert director.placement_feasible()


# ------------------------------------------- SLO trigger (VirtualClock)
def _slo_setup(max_groups, slo=4.0, slo_hold_s=1e9):
    """Two warm tenants pinned on group 0: 'gold' (GUARANTEED, tight SLO)
    and 'scav' (BEST_EFFORT with long rollouts)."""
    clock, router = _virtual_router()
    reg = tenancy.TenantRegistry()
    reg.register(tenancy.TenantSpec("gold", priority=4.0, class_=GUARANTEED,
                                    slo_step_latency_s=slo))
    reg.register(tenancy.TenantSpec("scav", priority=1.0))
    ledger = tenancy.TenantLedger(reg, slo_window=4, slo_min_samples=2)
    director = PlacementDirector(
        router,
        DirectorConfig(horizon=300.0, warmup_cycles=0, max_groups=max_groups,
                       drift_ratio=100.0, repack_interval_s=1e9,
                       spawn_queue_depth=999, slo_window=4,
                       slo_min_samples=2, slo_hold_s=slo_hold_s),
        initial_groups=[0], tenancy=ledger)
    ledger.bind_job("gA", "gold")
    ledger.bind_job("bE", "scav")
    router.register_job_tenant("gA", "gold", priority=4.0)
    router.register_job_tenant("bE", "scav", priority=1.0)
    director.adopt_warm("gA", JobTrace(3.0, ((2.0, 1.0),)), 0)
    director.adopt_warm("bE", JobTrace(9.0, ((8.0, 1.0),)), 0)
    deps = {job: router.deploy(_spec(job, f"{job}-train"), group_id=0)
            for job in ("gA", "bE")}
    return clock, router, director, ledger, deps


def _slo_round(clock, router, director, deps, futs):
    """One service round. The gold client is two-phase (rollout fetched,
    then the update submitted) so a long best-effort rollout admitted into
    the gap lands INSIDE gold's step wall — the interference the SLO
    trigger exists to stop."""
    d = deps["gA"]
    futs.append(d.generate(np.zeros((1, 2), np.int32), exec_estimate=2.0))
    b = deps["bE"]
    bg = b.generate(np.zeros((1, 2), np.int32), exec_estimate=8.0)
    futs += [bg, b.update_actor(0, exec_estimate=1.0, after=(bg,))]
    router.drain()
    futs.append(d.update_actor(0, exec_estimate=1.0))
    router.drain()
    director.on_job_step("gA")
    director.on_job_step("bE")
    clock.advance(0.25)


def _slo_preempt_flow():
    clock, router, director, ledger, deps = _slo_setup(max_groups=2)
    futs = []
    for _ in range(4):
        _slo_round(clock, router, director, deps, futs)
    router.drain()
    for f in futs:
        f.result()
    events = [dict(e) for e in director.events]
    snap = ledger.snapshot()
    exec_logs = {d: [tuple(x) for x in router.wpgs[d].exec_log]
                 for d in sorted(router.wpgs)}
    states = {j: (director.job_state(j).phase, director.job_state(j).group_id)
              for j in ("gA", "bE")}
    return events, snap, exec_logs, states


def test_slo_breach_preempts_best_effort_onto_spawned_group():
    events, snap, exec_logs, states = _slo_preempt_flow()
    kinds = [e["event"] for e in events]
    breach = next(e for e in events if e["event"] == "slo_breach")
    assert breach["job"] == "gA" and breach["tenant"] == "gold"
    assert breach["p95"] > breach["slo"] == 4.0
    # the victim is the BEST_EFFORT job, shed via the standard machinery:
    # spawn (reason slo:<guard>) -> slo_preempt -> realized migrate
    spawn = next(e for e in events if e["event"] == "spawn_group")
    assert spawn["reason"] == "slo:gA"
    pre = next(e for e in events if e["event"] == "slo_preempt")
    assert pre["job"] == "bE" and pre["guard"] == "gA"
    assert pre["src"] == 0 and pre["dst"] == spawn["group"]
    assert "migrate" in kinds
    assert states["bE"][1] == spawn["group"] and states["gA"][1] == 0
    # GUARANTEED work never moved or paused; best-effort work CONTINUED
    assert "slo_hold" not in kinds
    assert all(e.get("job") != "gA" for e in events
               if e["event"] in ("migrate", "slo_preempt"))
    be_ops = [op for log in exec_logs.values() for op in log
              if op == ("generate", 8.0)]
    assert len(be_ops) == 4, "every best-effort rollout still executed"
    assert snap["scav"]["steps_total"] == 4


def test_slo_two_tenant_flow_replays_bit_identical():
    assert _slo_preempt_flow() == _slo_preempt_flow(), \
        "SLO preemption decision sequence diverged between runs"


def test_slo_breach_holds_victim_at_max_fleet_and_recovers():
    """max_groups=1: nowhere to shed, so the victim is admission-HELD; its
    queued ops stop dispatching, gold's walls recover, and recovery
    releases the hold (reason 'recovered') -- the backlog then executes,
    so best-effort work is delayed, never lost."""
    clock, router, director, ledger, deps = _slo_setup(max_groups=1)
    futs = []
    for _ in range(6):
        _slo_round(clock, router, director, deps, futs)
    # held rounds ran gold alone: its p95 recovered BEFORE the backlog is
    # flushed (the flush below re-inflates one wall — that's the bounded
    # cost of work conservation, not a broken trigger)
    assert ledger.step_p95("gold") <= 4.0
    router.drain()                  # released backlog executes here
    for f in futs:
        f.result()                  # nothing poisoned, nothing stranded
    kinds = [e["event"] for e in director.events]
    assert "spawn_group" not in kinds and "slo_preempt" not in kinds
    hold = next(e for e in director.events if e["event"] == "slo_hold")
    assert hold["job"] == "bE" and hold["guard"] == "gA"
    rel = next(e for e in director.events if e["event"] == "slo_release")
    assert rel["job"] == "bE" and rel["reason"] == "recovered"
    assert "slo_recovered" in kinds
    assert kinds.index("slo_hold") < kinds.index("slo_release")
    # work conservation: all 6 best-effort rollouts eventually executed
    be = sum(1 for log in [router.wpgs[d].exec_log for d in router.wpgs]
             for op in log if tuple(op) == ("generate", 8.0))
    assert be == 6


def test_slo_hold_releases_on_timeout():
    clock, router, director, ledger, deps = _slo_setup(max_groups=1,
                                                       slo_hold_s=0.0)
    futs = []
    for _ in range(3):
        _slo_round(clock, router, director, deps, futs)
    router.drain()
    for f in futs:
        f.result()
    rels = [e for e in director.events if e["event"] == "slo_release"]
    assert rels and rels[0]["reason"] == "timeout"
    # cooldown keeps the released victim from being re-held the same step
    holds = [e for e in director.events if e["event"] == "slo_hold"]
    assert len(holds) == 1


# --------------------------------------------------- router service API
def test_wait_idle_returns_false_on_timeout_true_on_quiesce():
    trace = []
    from repro.core.router import Router
    router = Router(wpg_factory=lambda spec, sm: StubWPG(spec, sm, 0.30,
                                                         trace))
    dep = router.deploy(api.DeploymentSpec(deployment_id="d0", job_id="j0",
                                           model_name="stub", role="train"),
                        group_id=0)
    with router:
        f = dep.forward(0, exec_estimate=1.0)
        assert router.wait_idle(timeout=0.02) is False, \
            "a 0.3s op cannot quiesce in 20ms"
        assert router.wait_idle(timeout=30.0) is True
        assert f.result()["req_id"] == f.sources[0]
    # idle plane: an immediate True, not a hang
    assert router.wait_idle(timeout=0.01) is True


def test_tenant_telemetry_groups_jobs_by_tenant():
    c = _stub_cluster(n_groups=2)
    c.register_tenant(tenancy.TenantSpec("acme", priority=2.0))
    c.add_job(_cfg("a1", "acme"))
    c.add_job(_cfg("d1"), group_id=1)
    tel = c.router.tenant_telemetry()
    assert tel["acme"]["jobs"] == ["a1"] and tel["acme"]["groups"] == [0]
    assert tel["default"]["jobs"] == ["d1"] and tel["default"]["groups"] == [1]
    assert tel["acme"]["queue_depth"] == 0 and tel["acme"]["running"] == 0
    # ledger keys merged in (cluster wires the ledger onto the router)
    assert tel["acme"]["gpu_seconds"] == 0.0
    assert tel["acme"]["pending_jobs"] == 0


# ---------------------------------------- preemption-vs-teardown race
def _tiny_job(job_id, seed, steps=2, tenant="default"):
    return JobConfig(job_id=job_id, model_name="qwen2-0.5b", steps=steps,
                     batch_size=4, group_size=2, max_new_tokens=4,
                     seq_len=24, overrides=TINY, seed=seed, tenant=tenant)


def test_teardown_of_running_best_effort_bills_and_spares_guaranteed():
    """Detaching a BEST_EFFORT job while it has a RUNNING op (the teardown
    half of preemption) must bill that op's gpu-seconds to ITS tenant and
    must not poison the co-resident GUARANTEED job's futures."""
    c = PlexCluster(n_groups=1)
    c.register_tenant(tenancy.TenantSpec("gold", priority=4.0,
                                         class_=GUARANTEED))
    c.register_tenant(tenancy.TenantSpec("scav", priority=1.0))
    c.add_job(_tiny_job("g-job", seed=1, steps=2, tenant="gold"))
    with c.serve():
        deadline = time.monotonic() + 240
        while not c.controllers["g-job"].reward_log:
            assert time.monotonic() < deadline, "gold job made no progress"
            time.sleep(0.05)
        c.add_job(_tiny_job("b-job", seed=2, steps=50, tenant="scav"))
        deadline = time.monotonic() + 240
        while c.controllers["b-job"].steps_completed < 1:
            assert time.monotonic() < deadline, "be job made no progress"
            time.sleep(0.05)
        # detach while the best-effort job is mid-flight (ops RUNNING or
        # queued); serve() exit re-raises any poisoned gold future
        c.remove_job("b-job")
    gold = c.controllers["g-job"]
    assert gold.steps_completed == 2
    assert all(not np.isnan(m["loss"]) for m in gold.metrics_log)
    # the preempted tenant was billed for everything it consumed...
    assert c.billing["b-job"].busy_seconds > 0.0
    assert c.tenant_ledger.gpu_seconds("scav") > 0.0
    # ...and the ledgers agree with the per-job invoices per tenant
    for tenant, jobs in (("gold", ["g-job"]), ("scav", ["b-job"])):
        invoiced = sum(c.billing[j].busy_seconds + c.billing[j].switch_seconds
                      for j in jobs)
        assert c.tenant_ledger.gpu_seconds(tenant) == pytest.approx(invoiced)
    # quota reservation released, binding dropped
    assert c.admission.active_count("scav") == 0
    assert c.tenant_ledger.tenant_of("b-job") == "default"


# ------------------------------------------------------ slow-lane soak
@pytest.mark.slow
def test_soak_greedy_best_effort_cannot_break_guaranteed_slo():
    """Acceptance (slow lane): a greedy BEST_EFFORT tenant shares the plane
    with a GUARANTEED tenant whose SLO is calibrated from an isolated run.
    The SLO trigger must keep the guaranteed p95 under the objective while
    the best-effort tenant still makes real progress."""
    # calibrate: the gold job's isolated step wall on this machine (the
    # first run carries JIT compile time, which the shared run pays once
    # too, so the generous 4x multiple absorbs it)
    t0 = time.monotonic()
    iso = PlexCluster(n_groups=1)
    iso.add_job(_tiny_job("calib", seed=1, steps=2, tenant="default"))
    with iso.serve():
        pass
    step_wall = (time.monotonic() - t0) / 2
    slo = max(4.0 * step_wall, 2.0)

    c = PlexCluster(
        n_groups=1,
        director_cfg=DirectorConfig(warmup_cycles=0, max_groups=3,
                                    repack_interval_s=1e9,
                                    slo_window=6, slo_min_samples=3))
    c.register_tenant(tenancy.TenantSpec("gold", priority=4.0,
                                         class_=GUARANTEED,
                                         slo_step_latency_s=slo))
    c.register_tenant(tenancy.TenantSpec("scav", priority=0.5))
    with c.serve():
        c.add_job(_tiny_job("g-job", seed=1, steps=10, tenant="gold"),
                  group_id=None)
        # the greedy tenant: bigger batches, long rollouts, many steps
        greedy = JobConfig(job_id="b-job", model_name="qwen2-0.5b",
                           steps=40, batch_size=8, group_size=2,
                           max_new_tokens=16, seq_len=32, overrides=TINY,
                           seed=2, tenant="scav")
        c.add_job(greedy, group_id=None)
        deadline = time.monotonic() + 600
        while c.controllers["g-job"].steps_completed < 10:
            assert time.monotonic() < deadline, "gold job starved"
            time.sleep(0.2)
        c.remove_job("b-job")       # stop the greedy tenant; serve exits
    snap = c.tenant_ledger.snapshot()
    p95 = snap["gold"]["step_p95_s"]
    assert p95 is not None and p95 <= slo, \
        f"guaranteed p95 {p95:.2f}s exceeded SLO {slo:.2f}s"
    # work conservation: the best-effort tenant still got real throughput
    assert c.billing["b-job"].busy_seconds > 0.0
    assert snap["scav"]["gpu_seconds"] > 0.0
