"""The live cluster control plane (core/control_plane.py + the layers under
it): online trace profiling, cold→warm placement with realized migration,
capacity adjustment, and the supporting executor/router mechanics.

Covers:
- the online profiler: a driven GRPO-shaped job under VirtualClock yields a
  JobTrace whose phase durations match the executor's task records EXACTLY,
  and ``place_warm`` on that trace agrees with the simulator's placement for
  the same trace (time-translated free windows),
- bounded ``executor.tasks`` retention under a long churn loop (ROADMAP
  open item: a week-long serve plane must not grow memory without bound),
- admission hold / release / rehome (the drain half of elastic
  re-placement) and ``Router.reassign_job`` billing continuity,
- ``Router.retire_group`` symmetric to the dynamic serve-worker spawn,
- incremental NodeGroup free-window maintenance (note_busy / advance_to /
  extend_to) and runtime add/remove of groups,
- the acceptance flow: ``PlexCluster.serve()`` with ``group_id=None`` jobs
  cold-profiled, warm-re-placed onto a SHARED group by micro-shift fitting,
  a third arrival triggering a capacity-adjustment spawn, billing conserved
  across profiling→migration→steady-state,
- bit-identical director decision replay under VirtualClock.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import api
from repro.core.cluster import BillingRecord, PlexCluster
from repro.core.control_plane import (DirectorConfig, PlacementDirector,
                                      trace_from_cycles)
from repro.core.controller import JobConfig
from repro.core.router import Router
from repro.core.scheduler.executor import (State, TaskExecutor, VirtualClock)
from repro.core.scheduler.intervals import IntervalSet
from repro.core.scheduler.placement import (JobTrace, NodeGroup,
                                            PlacementConfig, PlacementPolicy)
from repro.core.scheduler import hrrs
from test_dispatch import StubWPG

TINY = (("num_layers", 2), ("d_model", 32), ("num_heads", 4),
        ("num_kv_heads", 2), ("head_dim", 8), ("d_ff", 64),
        ("vocab_size", 64), ("tie_embeddings", True))


class ClockWPG:
    """Deterministic execution backend: advances the shared VirtualClock by
    the op's exec_estimate, so task-record durations are exact."""

    def __init__(self, spec, sm, clock):
        self.spec = spec
        self.sm = sm
        self.clock = clock
        self.exec_log = []

    @property
    def job_prefix(self):
        return f"{self.spec.job_id}:{self.spec.deployment_id}"

    def resident(self):
        return False

    def ensure_resident(self):
        return 0.0

    def offload(self, to=None):
        return 0.0

    def execute(self, qop):
        self.clock.advance(qop.exec_estimate)
        self.exec_log.append((qop.op.value, qop.exec_estimate))
        return {"req_id": qop.req_id}


def _spec(job_id, dep_id=None, role="train"):
    return api.DeploymentSpec(deployment_id=dep_id or f"{job_id}-train",
                              job_id=job_id, model_name="stub", role=role)


def _virtual_router():
    clock = VirtualClock()
    router = Router(now=clock,
                    wpg_factory=lambda spec, sm: ClockWPG(spec, sm, clock))
    return clock, router


def _grpo_cycle(dep, rollout=6.0, logprob=1.0, update=3.0, sync=0.5):
    """One GRPO-shaped cycle as a strict chain (generate -> forward ->
    update_actor -> sync_weights) with exact-binary estimates."""
    gen = dep.generate(np.zeros((1, 2), np.int32), exec_estimate=rollout)
    fwd = dep.forward(0, exec_estimate=logprob, after=(gen,))
    upd = dep.update_actor(0, exec_estimate=update, after=(fwd,))
    syn = dep.sync_weights(dep, exec_estimate=sync, after=(upd,))
    return [gen, fwd, upd, syn]


# ------------------------------------------------------- online profiler
def test_profiler_trace_matches_task_records_exactly():
    """The folded JobTrace's phase durations must equal the executor's task
    records bit-for-bit under VirtualClock."""
    clock, router = _virtual_router()
    director = PlacementDirector(
        router, DirectorConfig(horizon=200.0, cold_reserve_s=50.0,
                               warmup_cycles=0),
        initial_groups=[0, 1])
    gid = director.assign("jobA")
    assert gid == 0                      # first empty group, cold-dedicated
    dep = router.deploy(_spec("jobA"), group_id=gid)
    tails = _grpo_cycle(dep)
    router.drain()
    for f in tails:
        f.result()

    # records exported by the executor: op -> exact duration
    recs = router.executor.phase_records_since("jobA", 0)
    durs = {r.op: r.duration for r in recs}
    assert durs == {"generate": 6.0, "forward": 1.0,
                    "update_actor": 3.0, "sync_weights": 0.5}

    director.on_job_step("jobA")
    trace = director.profiled_trace("jobA")
    assert trace is not None
    # the trace's anatomy equals the records EXACTLY: rollout gap, then
    # logprob/update/sync back-to-back
    assert trace.period == 6.0 + 1.0 + 3.0 + 0.5
    assert trace.segments == ((6.0, 1.0), (7.0, 3.0), (10.0, 0.5))
    js = director.job_state("jobA")
    assert js.phase == "warm"
    assert js.cycles[0] == {"rollout": 6.0, "compute_log_prob": 1.0,
                            "update_actor": 3.0, "sync_weight": 0.5}


def test_profiled_trace_placement_agrees_with_simulator():
    """place_warm on the live (time-translated) free windows must pick the
    same group and shift as the simulator's origin-0 placement of the same
    trace."""
    trace = JobTrace(period=10.5, segments=((6.0, 1.0), (7.0, 3.0),
                                            (10.0, 0.5)))
    resident = JobTrace(period=10.5, segments=((6.0, 2.0),))
    cfg = PlacementConfig(horizon=105.0)

    sim = PlacementPolicy([NodeGroup(0, 1, IntervalSet([(0.0, 105.0)])),
                           NodeGroup(1, 1, IntervalSet([(0.0, 105.0)]))], cfg)
    assert sim.place_warm("res", resident) is not None
    p_sim = sim.place_warm("new", trace)

    t0 = 1000.0                          # live plane: windows start at "now"
    live = PlacementPolicy(
        [NodeGroup(0, 1, IntervalSet([(t0, t0 + 105.0)])),
         NodeGroup(1, 1, IntervalSet([(t0, t0 + 105.0)]))], cfg)
    assert live.place_warm("res", resident, origin=t0) is not None
    p_live = live.place_warm("new", trace, origin=t0)

    assert p_sim is not None and p_live is not None
    assert (p_live.group_id, p_live.shift) == (p_sim.group_id, p_sim.shift)


def test_trace_from_cycles_means_multiple_cycles():
    cycles = [{"rollout": 4.0, "update_actor": 2.0},
              {"rollout": 6.0, "update_actor": 4.0}]
    t = trace_from_cycles(cycles)
    assert t.period == 5.0 + 3.0
    assert t.segments == ((5.0, 3.0),)
    assert trace_from_cycles([{"rollout": 1.0}]) is None  # no update phase


# ----------------------------------------------- bounded task retention
def test_executor_tasks_bounded_under_churn():
    """ROADMAP open item: settled Task records must age out. A long churn
    loop (submit/admit/finish) must keep ``executor.tasks`` bounded by the
    retention cap plus open tasks, and the per-job phase log by its
    window."""
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, policy="hrrs", max_settled_tasks=100,
                      phase_window=32)
    for i in range(1, 1201):
        req = hrrs.Request(req_id=i, job_id=f"job{i % 3}", op="update_actor",
                           exec_time=1.0, arrival_time=clock.now())
        ex.submit(req, group_id=0)
        task = ex.pick_next(0)
        assert task is not None and ex.try_start(task)
        clock.advance(0.25)
        ex.finish(task)
    assert len(ex.tasks) <= 100
    assert len(ex._settled) <= 100
    for log in ex.phase_log.values():
        assert len(log) <= 32
    assert ex.outstanding() == 0
    # group telemetry survived the churn
    assert ex.group_busy[0] == pytest.approx(1200 * 0.25)
    assert ex.queued_count[0] == 0


def test_failed_records_outlive_completed_churn():
    """A FAILED record is pinned while poison_dirty is set, then moves to
    the failed ring: COMPLETED churn can no longer evict it (a late
    dependent must still see the error), and only further FAILURES beyond
    the failed ring's own capacity age it out."""
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, policy="hrrs", max_settled_tasks=2)

    def settle(req_id, error=None):
        t = ex.submit(hrrs.Request(req_id=req_id, job_id="j", op="forward",
                                   exec_time=1.0, arrival_time=0.0), 0)
        ex.try_start(t)
        ex.finish(t, error=error)

    settle(1, error="boom")              # FAILED, sets poison_dirty
    assert ex.poison_dirty
    for i in range(2, 5):
        settle(i)
    assert 1 in ex.tasks                 # pinned at the ring's head
    ex.poison_dirty = False              # router's sweep reached fixpoint
    for i in range(5, 20):
        settle(i)                        # heavy COMPLETED churn
    assert 1 in ex.tasks                 # failed record survives it
    assert sum(1 for t in ex.tasks.values()
               if t.state == State.COMPLETED) <= 2
    for i in range(20, 24):              # but failures do age it out
        settle(i, error="boom")
        ex.poison_dirty = False
        settle(100 + i)                  # trigger a prune pass
    assert 1 not in ex.tasks
    assert len(ex.tasks) <= 5


# ------------------------------------------------- hold / release / rehome
def test_hold_release_gates_admission():
    clock, router = _virtual_router()
    depA = router.deploy(_spec("jobA"), group_id=0)
    depB = router.deploy(_spec("jobB", "jobB-train"), group_id=0)
    ex = router.executor
    fa = depA.forward(0, exec_estimate=1.0)
    fb = depB.forward(0, exec_estimate=1.0)
    ex.hold_job("jobA")
    task = ex.pick_next(0)
    assert task is not None and task.request.job_id == "jobB"
    router.step(max_ops=10)
    assert fb.done() and not fa.done()   # held job made no progress
    ex.release_job("jobA")
    router.drain()
    assert fa.result()["req_id"] > 0


def test_rehome_moves_queued_tasks_and_counters():
    clock, router = _virtual_router()
    dep = router.deploy(_spec("jobA"), group_id=0)
    futs = [dep.forward(i, exec_estimate=1.0) for i in range(3)]
    ex = router.executor
    assert ex.queued_count[0] == 3
    router.ensure_group(7)
    ex.rehome_job("jobA", 7)
    assert ex.queued_count[0] == 0 and ex.queued_count[7] == 3
    # ops now execute on group 7's lock (the deployment mapping moved too)
    router.group_of["jobA-train"] = 7
    router.drain()
    for f in futs:
        assert f.result()["req_id"] > 0
    assert all(t.group_id == 7 for t in ex.tasks.values())


def test_reassign_job_migrates_state_and_queued_ops():
    """reassign_job: hold -> quiesce -> migrate state -> rehome queued ->
    release, with exec logs (billing source) surviving intact."""
    clock, router = _virtual_router()
    dep = router.deploy(_spec("jobA"), group_id=0)
    sm0 = router.state_managers[0]
    wpg = router.wpgs["jobA-train"]
    sm0.register(wpg.job_prefix, {"w": np.ones((8, 8), np.float32)})
    done = [dep.forward(i, exec_estimate=1.0) for i in range(2)]
    router.drain()
    queued = [dep.forward(i, exec_estimate=1.0) for i in range(3)]
    moved = router.reassign_job("jobA", 3)
    assert moved > 0                     # state bytes migrated
    assert router.group_of["jobA-train"] == 3
    assert not sm0.keys_for(wpg.job_prefix)
    assert router.state_managers[3].keys_for(wpg.job_prefix)
    assert router.executor.queued_count.get(0, 0) == 0
    router.drain()
    for f in done + queued:
        assert f.result()["req_id"] > 0
    # billing source of truth survived: all 5 ops are in the ONE exec log
    assert len(wpg.exec_log) == 5


# --------------------------------------------------------- group lifecycle
def _serve_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("serve-") and t.is_alive()]


def test_retire_group_tears_down_worker_and_state():
    trace = []
    router = Router(wpg_factory=lambda spec, sm: StubWPG(spec, sm, 0.0,
                                                         trace))
    dep0 = router.deploy(_spec("j0"), group_id=0)
    dep5 = router.deploy(_spec("j5", "j5-train"), group_id=5)
    with router:
        assert len(_serve_threads()) == 2
        with pytest.raises(RuntimeError, match="still hosts"):
            router.retire_group(5)
        assert dep5.forward(0).wait(timeout=10.0)["req_id"] > 0
        router.teardown("j5-train")
        router.retire_group(5)
        assert len(_serve_threads()) == 1
        assert 5 not in router.executor.locks
        assert 5 not in router.state_managers
        # the surviving group still serves
        assert dep0.forward(0).wait(timeout=10.0)["req_id"] > 0
        # and a later ensure_group re-spawns a worker dynamically
        router.ensure_group(5)
        assert len(_serve_threads()) == 2
    assert not _serve_threads()


def test_group_telemetry_reports_depth_and_occupancy():
    clock, router = _virtual_router()
    dep = router.deploy(_spec("jobA"), group_id=0)
    router.ensure_group(2)
    for i in range(4):
        dep.forward(i, exec_estimate=1.0)
    t = router.group_telemetry()
    assert t[0]["queue_depth"] == 4
    assert t[0]["deployments"] == ["jobA-train"]
    assert t[2]["queue_depth"] == 0 and not t[2]["deployments"]
    router.drain()
    t = router.group_telemetry()
    assert t[0]["queue_depth"] == 0
    assert t[0]["busy_seconds"] == pytest.approx(4.0)


# ------------------------------------------- incremental NodeGroup windows
def test_nodegroup_incremental_updates():
    g = NodeGroup(0, 1, IntervalSet([(0.0, 100.0)]))
    assert g.horizon_end == 100.0
    g.note_busy(10.0, 20.0)              # live completion carves capacity
    g.note_busy(15.0, 30.0)              # overlapping carve is safe
    assert g.free.intervals() == [(0.0, 10.0), (30.0, 100.0)]
    g.advance_to(40.0)                   # the past is spent
    assert g.free.intervals() == [(40.0, 100.0)]
    # a resident periodic job is projected into the extended horizon
    from repro.core.scheduler.placement import Placed
    g.resident.append(Placed("j", JobTrace(50.0, ((0.0, 10.0),)), 0, 0.0,
                             origin=40.0))
    g.extend_to(200.0)
    assert g.horizon_end == 200.0
    free = g.free.intervals()
    # projected segments at [140, 150) and [190, 200) are NOT free
    assert not g.free.covers(140.0, 150.0)
    assert not g.free.covers(190.0, 200.0)
    assert g.free.covers(150.0, 190.0)
    assert free[0][0] == 40.0


def test_policy_add_remove_group_runtime():
    pol = PlacementPolicy([NodeGroup(0, 1, IntervalSet([(0.0, 100.0)]))],
                          PlacementConfig(horizon=100.0))
    g1 = pol.add_group(NodeGroup(1, 1, IntervalSet([(0.0, 100.0)])))
    assert pol.group(1) is g1
    p = pol.place_cold("j", 1, 10.0)
    assert p is not None and p.group_id == 0
    with pytest.raises(RuntimeError, match="hosts"):
        pol.remove_group(0)
    pol.remove("j")
    pol.remove_group(0)
    assert pol.group(0) is None and len(pol.groups) == 1


# ------------------------------------------------ director decision replay
def _director_flow(n_steps=2):
    """The full control-plane flow (cold x2 -> warm consolidation ->
    migration -> retire -> third arrival spawn) on a VirtualClock; returns
    the decision log with every op's admission order."""
    clock, router = _virtual_router()
    director = PlacementDirector(
        router, DirectorConfig(horizon=300.0, cold_reserve_s=40.0,
                               min_groups=1, warmup_cycles=0),
        initial_groups=[0])
    deps, ordinal, order = {}, {}, []

    def submit_cycle(job, rollout, update):
        gen = deps[job].generate(np.zeros((1, 2), np.int32),
                                 exec_estimate=rollout)
        upd = deps[job].update_actor(0, exec_estimate=update, after=(gen,))
        for f, name in ((gen, "gen"), (upd, "upd")):
            ordinal[f.sources[0]] = len(ordinal)
        return [gen, upd]

    def add(job, rollout, update):
        gid = director.assign(job)
        deps[job] = router.deploy(_spec(job, f"{job}-train"), group_id=gid)
        return gid

    g_a = add("jobA", 6.0, 2.0)
    g_b = add("jobB", 5.0, 3.0)
    assert g_a != g_b                    # cold jobs get dedicated groups
    for step in range(n_steps):
        for job, (r, u) in (("jobA", (6.0, 2.0)), ("jobB", (5.0, 3.0))):
            tails = submit_cycle(job, r, u)
            router.drain()
            for f in tails:
                f.result()
            director.on_job_step(job)
        clock.advance(0.5)
    g_c = add("jobC", 4.0, 1.0)
    events = [dict(e) for e in director.events]
    states = {j: (director.job_state(j).phase, director.job_state(j).group_id)
              for j in ("jobA", "jobB", "jobC")}
    # admission order in submission ordinals (req_ids differ across runs)
    for tasks in [router.executor.tasks]:
        order = [ordinal[t.request.req_id]
                 for t in sorted(tasks.values(), key=lambda t: t.t_started)
                 if t.request.req_id in ordinal]
    return events, states, order, g_c


def test_director_flow_consolidates_and_spawns():
    events, states, _, g_c = _director_flow()
    kinds = [e["event"] for e in events]
    assert kinds.count("cold_place") == 3       # A, B, C
    assert kinds.count("warm_place") == 2       # A and B re-fitted
    assert kinds.count("migrate") == 1          # one consolidation move
    assert "retire_group" in kinds              # drained profiling group
    assert "spawn_group" in kinds               # capacity adjustment
    # A and B share one group after warm placement
    assert states["jobA"][0] == states["jobB"][0] == "warm"
    assert states["jobA"][1] == states["jobB"][1]
    # C's arrival found no empty group -> the spawn served its cold place
    assert states["jobC"][0] == "cold"
    assert states["jobC"][1] == g_c != states["jobA"][1]
    spawn = [e for e in events if e["event"] == "spawn_group"][-1]
    assert spawn["reason"].startswith("cold:jobC")


def test_director_flow_bit_identical_replay():
    first = _director_flow()
    second = _director_flow()
    assert first == second, "control-plane replay diverged between runs"


# --------------------------------------------- capacity adjuster triggers
def test_queue_depth_triggers_spawn():
    clock, router = _virtual_router()
    director = PlacementDirector(
        router, DirectorConfig(spawn_queue_depth=4, horizon=100.0),
        initial_groups=[0])
    director.assign("jobA")
    dep = router.deploy(_spec("jobA"), group_id=0)
    for i in range(6):
        dep.forward(i, exec_estimate=1.0)
    n_groups = len(director.policy.groups)
    director.poll()
    assert len(director.policy.groups) == n_groups + 1
    assert any(e["event"] == "spawn_group"
               and e["reason"].startswith("queue_depth")
               for e in director.events)
    director.poll()                      # spare group exists: no growth
    assert len(director.policy.groups) == n_groups + 1


# -------------------------------------------------- acceptance: serve e2e
def _tiny(job_id, seed, steps=2):
    return JobConfig(job_id=job_id, model_name="qwen2-0.5b", steps=steps,
                     batch_size=4, group_size=2, max_new_tokens=4,
                     seq_len=24, overrides=TINY, seed=seed)


def test_serve_auto_placement_end_to_end():
    """Acceptance: two jobs added with ``group_id=None`` are cold-profiled
    on dedicated groups, warm-re-placed onto a SHARED group by micro-shift
    fitting (one of them migrating live), the drained profiling group is
    retired, and a third arrival triggers a capacity-adjustment spawn —
    with per-job billing (busy + switch seconds) conserved across the
    profiling → migration → steady-state transitions."""
    c = PlexCluster(n_groups=1,
                    director_cfg=DirectorConfig(horizon=240.0,
                                                cold_reserve_s=30.0,
                                                min_groups=1))
    with c.serve():
        c.add_job(_tiny("auto-a", seed=1, steps=3), group_id=None)
        c.add_job(_tiny("auto-b", seed=2, steps=3), group_id=None)
        deadline = time.monotonic() + 240
        while not (c.director.job_state("auto-a").phase == "warm"
                   and c.director.job_state("auto-b").phase == "warm"):
            assert time.monotonic() < deadline, \
                f"no warm promotion; events={c.director.events}"
            assert not c.client_errors, c.client_errors
            time.sleep(0.05)
        # both warm jobs share one group (micro-shift consolidation)
        ga = c.director.job_state("auto-a").group_id
        gb = c.director.job_state("auto-b").group_id
        assert ga == gb, c.director.events
        # wait for the drained profiling group to retire before the next
        # arrival: retire runs on the director's poll cadence, and adding
        # auto-c first would legitimately reuse the still-live free group
        # instead of spawning (a race, not the behavior under test)
        while not any(e["event"] == "retire_group"
                      for e in c.director.events):
            assert time.monotonic() < deadline, \
                f"profiling group never retired; events={c.director.events}"
            time.sleep(0.05)
        # the third arrival must spawn a fresh group for clean profiling
        spawns_before = sum(e["event"] == "spawn_group"
                            for e in c.director.events)
        c.add_job(_tiny("auto-c", seed=3, steps=2), group_id=None)
        spawns_after = sum(e["event"] == "spawn_group"
                           for e in c.director.events)
        assert spawns_after == spawns_before + 1, c.director.events
        assert c.director.job_state("auto-c").group_id != ga
    kinds = [e["event"] for e in c.director.events]
    assert kinds.count("migrate") >= 1
    assert "retire_group" in kinds
    # every job completed and billing is CONSERVED: busy time equals the
    # sum of its deployments' exec logs (the logs survive migration)
    for job in ("auto-a", "auto-b", "auto-c"):
        ctl = c.controllers[job]
        assert ctl.steps_completed == ctl.cfg.steps, job
        rec = c.billing[job]
        assert rec.steps == ctl.cfg.steps
        logged = sum(dt for d, w in c.router.wpgs.items()
                     if w.spec.job_id == job for _, dt in w.exec_log)
        assert rec.busy_seconds == pytest.approx(logged), job
        assert rec.busy_seconds > 0.0
        assert rec.switch_seconds >= 0.0
    assert not c.router.pending
    assert not _serve_threads()
