"""The concurrent event-driven dispatch plane (Router.run_until_idle).

Covers the §5.1/§5.2 runtime properties the serial loop could not provide:
- cross-group wall-clock overlap (measured against the serial driver on the
  SAME admission path),
- per-group mutual exclusion + prerequisite ordering under concurrency,
- thread-safe Future semantics (wait timeout, error propagation, poisoned
  dependents),
- deterministic HRRS admission under a VirtualClock,
- pending-table cleanup and incremental cluster billing.

Worker-process groups are replaced by sleep-based stubs (time.sleep releases
the GIL, so overlap measurements are real) injected through the Router's
``wpg_factory`` — no model build, so this module stays fast.
"""
import threading
import time
from types import SimpleNamespace

import pytest

from repro.core import api
from repro.core.cluster import BillingRecord, PlexCluster
from repro.core.router import Router
from repro.core.scheduler.executor import State, VirtualClock


class StubWPG:
    """Minimal execution backend: records (deployment, req_id, t0, t1) into a
    shared trace; ops with kwargs {'fail': True} raise."""

    def __init__(self, spec, sm, duration, trace):
        self.spec = spec
        self.sm = sm
        self.exec_log = []
        self._duration = duration
        self._trace = trace

    @property
    def job_prefix(self):
        return f"{self.spec.job_id}:{self.spec.deployment_id}"

    def resident(self):
        return False

    def ensure_resident(self):
        return 0.0

    def offload(self, to=None):
        return 0.0

    def execute(self, qop):
        t0 = time.monotonic()
        if self._duration:
            time.sleep(self._duration)
        if qop.kwargs.get("fail"):
            raise RuntimeError(f"op {qop.req_id} failed (injected)")
        t1 = time.monotonic()
        self._trace.append((self.spec.deployment_id, qop.req_id, t0, t1))
        self.exec_log.append((qop.op.value, t1 - t0))
        return {"req_id": qop.req_id}


def make_router(n_groups=2, duration=0.03, now=time.monotonic,
                policy="hrrs"):
    trace = []
    router = Router(now=now, policy=policy,
                    wpg_factory=lambda spec, sm: StubWPG(spec, sm, duration,
                                                         trace))
    specs = []
    for g in range(n_groups):
        spec = api.DeploymentSpec(deployment_id=f"dep{g}",
                                  job_id=f"job{g}", model_name="stub",
                                  role="train")
        router.create_deployment(spec, group_id=g)
        specs.append(spec)
    return router, specs, trace


def submit_batch(router, spec, n, **kwargs):
    return [router.submit_queued_operation(
        api.make_op(spec, api.Op.FORWARD, i, **kwargs)) for i in range(n)]


# --------------------------------------------------------------- overlap
def test_two_groups_overlap_beats_serial_wall_clock():
    """Acceptance: two jobs on two groups under the concurrent plane finish
    in < 0.9x the wall-clock of the identical workload on the serial
    driver."""
    ops_per_group, dur = 4, 0.05

    r1, specs1, _ = make_router(n_groups=2, duration=dur)
    for s in specs1:
        submit_batch(r1, s, ops_per_group)
    t0 = time.monotonic()
    n_serial = r1.drain()
    serial_wall = time.monotonic() - t0

    r2, specs2, trace2 = make_router(n_groups=2, duration=dur)
    for s in specs2:
        submit_batch(r2, s, ops_per_group)
    t0 = time.monotonic()
    n_conc = r2.run_until_idle(timeout=30.0)
    conc_wall = time.monotonic() - t0

    assert n_serial == n_conc == 2 * ops_per_group
    assert conc_wall < 0.9 * serial_wall, (conc_wall, serial_wall)
    # measured overlap: some dep0 interval intersects some dep1 interval
    by_dep = {}
    for dep, _, a, b in trace2:
        by_dep.setdefault(dep, []).append((a, b))
    overlaps = any(a0 < b1 and a1 < b0
                   for a0, b0 in by_dep["dep0"]
                   for a1, b1 in by_dep["dep1"])
    assert overlaps, "no cross-group wall-clock overlap observed"


# -------------------------------------------------- per-group exclusivity
def test_per_group_serial_ordering_under_concurrency():
    r, specs, trace = make_router(n_groups=2, duration=0.01)
    futs = [submit_batch(r, s, 5) for s in specs]
    r.run_until_idle(timeout=30.0)
    for group_futs in futs:
        for f in group_futs:
            assert f.done() and f.result()["req_id"] > 0
    # within one deployment (== one group lock) intervals never overlap
    by_dep = {}
    for dep, req_id, a, b in trace:
        by_dep.setdefault(dep, []).append((a, b))
    for dep, spans in by_dep.items():
        assert len(spans) == 5
        spans.sort()
        for (a0, b0), (a1, b1) in zip(spans, spans[1:]):
            assert b0 <= a1 + 1e-6, f"{dep}: ops overlapped on one group"
    # executor left clean: everything completed, locks free
    assert all(t.state == State.COMPLETED
               for t in r.executor.tasks.values())
    assert all(lock.holder is None for lock in r.executor.locks.values())


def test_prerequisite_chain_order_preserved_concurrently():
    r, specs, trace = make_router(n_groups=1, duration=0.005)
    spec = specs[0]
    prev, chain = (), []
    for i in range(6):
        qop = api.make_op(spec, api.Op.FORWARD, i, prerequisites=prev)
        r.submit_queued_operation(qop)
        chain.append(qop.req_id)
        prev = (qop.req_id,)
    r.run_until_idle(timeout=30.0)
    executed = [req_id for _, req_id, _, _ in trace]
    assert executed == chain


# ------------------------------------------------- callback resubmission
def test_callback_submitted_followups_keep_plane_alive():
    """A future callback submitting follow-up work (the controller's
    generate -> update chain) must be executed before run_until_idle
    declares the cluster idle."""
    r, specs, trace = make_router(n_groups=2, duration=0.01)
    seen = []

    def chain(spec, depth):
        def on_done(fut):
            seen.append(fut.result()["req_id"])
            if depth > 0:
                f2 = r.submit_queued_operation(
                    api.make_op(spec, api.Op.FORWARD, depth))
                f2.add_done_callback(chain(spec, depth - 1))
        return on_done

    for s in specs:
        f = r.submit_queued_operation(api.make_op(s, api.Op.FORWARD, 0))
        f.add_done_callback(chain(s, 3))
    n = r.run_until_idle(timeout=30.0)
    assert n == 2 * 4                 # initial op + 3 chained per group
    assert len(seen) == 2 * 4
    assert not r.pending


# ------------------------------------------------------- future semantics
def test_future_wait_timeout_then_resolution():
    f = api.Future()
    with pytest.raises(TimeoutError):
        f.wait(timeout=0.05)
    threading.Timer(0.05, lambda: f.set_result(42)).start()
    assert f.wait(timeout=5.0) == 42
    # late callback registration fires immediately
    fired = []
    f.add_done_callback(lambda fut: fired.append(fut.result()))
    assert fired == [42]


def test_error_propagates_and_poisons_dependents():
    r, specs, _ = make_router(n_groups=1, duration=0.0)
    spec = specs[0]
    bad = api.make_op(spec, api.Op.FORWARD, 0, fail=True)
    dep = api.make_op(spec, api.Op.FORWARD, 1, prerequisites=(bad.req_id,))
    grand = api.make_op(spec, api.Op.FORWARD, 2, prerequisites=(dep.req_id,))
    f_bad = r.submit_queued_operation(bad)
    f_dep = r.submit_queued_operation(dep)
    f_grand = r.submit_queued_operation(grand)
    r.run_until_idle(timeout=30.0)    # must terminate despite the failure
    with pytest.raises(RuntimeError, match="injected"):
        f_bad.wait(timeout=1.0)
    with pytest.raises(RuntimeError, match="prerequisite"):
        f_dep.result()
    with pytest.raises(RuntimeError, match="prerequisite"):
        f_grand.result()
    assert not r.pending
    states = {t.state for t in r.executor.tasks.values()}
    assert states == {State.FAILED}


def test_timeout_bounds_call_even_with_hung_op():
    """An op stuck inside execute cannot be interrupted, but the timeout
    must still bound run_until_idle (the worker is abandoned after a short
    grace) instead of spinning on join forever."""
    r, specs, _ = make_router(n_groups=1, duration=3.0)
    r.submit_queued_operation(api.make_op(specs[0], api.Op.FORWARD, 0))
    t0 = time.monotonic()
    with pytest.raises(TimeoutError, match="stuck"):
        r.run_until_idle(timeout=0.2)
    assert time.monotonic() - t0 < 2.5   # 0.2s deadline + 1s grace + slack


def test_abandoned_worker_is_tracked_not_silently_leaked():
    """Regression: the abandon grace used to drop the hung worker's handle
    on the floor — the thread leaked invisibly and nothing ever reported
    it. Now run_until_idle records it, abandoned_workers() names it while
    the hung op runs, and the entry self-prunes once the op returns."""
    r, specs, _ = make_router(n_groups=1, duration=2.0)
    r.submit_queued_operation(api.make_op(specs[0], api.Op.FORWARD, 0))
    with pytest.raises(TimeoutError, match="stuck"):
        r.run_until_idle(timeout=0.2)
    names = r.abandoned_workers()
    assert names and names[0].startswith("dispatch-g0")
    # once the stuck execute returns, the daemon exits and the report drains
    deadline = time.monotonic() + 10.0
    while r.abandoned_workers() and time.monotonic() < deadline:
        time.sleep(0.05)
    assert r.abandoned_workers() == []


def test_serial_driver_also_poisons_dependents():
    r, specs, _ = make_router(n_groups=1, duration=0.0)
    spec = specs[0]
    bad = api.make_op(spec, api.Op.FORWARD, 0, fail=True)
    dep = api.make_op(spec, api.Op.FORWARD, 1, prerequisites=(bad.req_id,))
    f_bad = r.submit_queued_operation(bad)
    f_dep = r.submit_queued_operation(dep)
    r.drain()
    with pytest.raises(RuntimeError):
        f_bad.result()
    with pytest.raises(RuntimeError, match="prerequisite"):
        f_dep.result()
    assert not r.pending


@pytest.mark.parametrize("driver", ["serial", "concurrent"])
def test_broken_callback_fails_loudly_at_driver_exit(driver):
    """A user callback that raises must not vanish silently (nor kill a
    dispatch thread mid-protocol): the op's work completes, the error is
    recorded, and the driver raises on exit."""
    r, specs, _ = make_router(n_groups=1, duration=0.0)
    f = r.submit_queued_operation(api.make_op(specs[0], api.Op.FORWARD, 0))
    f.add_done_callback(lambda fut: 1 / 0)
    with pytest.raises(RuntimeError, match="callback"):
        if driver == "serial":
            r.drain()
        else:
            r.run_until_idle(timeout=30.0)
    assert f.result()["req_id"] > 0       # the op itself still completed
    assert len(r.callback_errors) == 1
    assert not r.pending


# --------------------------------------------------------- virtual clock
def _virtual_run(order_jobs):
    clock = VirtualClock()
    trace = []
    router = Router(now=clock, wpg_factory=lambda spec, sm: StubWPG(
        spec, sm, 0.0, trace))
    specs = {}
    for job in ("A", "B"):
        spec = api.DeploymentSpec(deployment_id=f"dep{job}", job_id=job,
                                  model_name="stub", role="train")
        router.create_deployment(spec, group_id=0)   # shared group
        specs[job] = spec
    for job, est in order_jobs:
        router.submit_queued_operation(
            api.make_op(specs[job], api.Op.FORWARD, exec_estimate=est))
        clock.advance(1.0)           # deterministic arrival spacing
    router.drain()
    return [dep for dep, _, _, _ in trace]


def test_hrrs_admission_deterministic_under_virtual_clock():
    """The SAME admission path that drives wall-clock dispatch, replayed on
    a manually-advanced clock, must order identically run-to-run."""
    workload = [("A", 3.0), ("B", 1.0), ("A", 2.0), ("B", 5.0),
                ("A", 1.0), ("B", 2.0)]
    first = _virtual_run(workload)
    second = _virtual_run(workload)
    assert first == second
    assert len(first) == len(workload)


def test_virtual_clock_advances_monotonically():
    clock = VirtualClock(start=5.0)
    assert clock.now() == 5.0
    assert clock.advance(2.5) == 7.5
    assert clock() == 7.5
    with pytest.raises(ValueError):
        clock.advance(-1.0)


# ----------------------------------------------- signal-driven teardown
def _count_cv_waits(executor):
    """Instrument the executor's condition variable: record the timeout of
    every wait() a dispatch worker performs."""
    waits = []
    orig_wait = executor.cv.wait

    def counting_wait(timeout=None):
        waits.append(timeout)
        return orig_wait(timeout)

    executor.cv.wait = counting_wait
    return waits


def test_idle_worker_parks_with_zero_polling_wakeups():
    """An idle dispatcher must block on the cv with NO timeout and NO
    periodic wakeups while another group's op runs (PR 1 polled every
    50 ms here). Wakeups may only come from real notifications."""
    r, specs, _ = make_router(n_groups=2, duration=0.4)
    waits = _count_cv_waits(r.executor)
    # only group 0 gets work; group 1's worker parks for the whole 0.4 s
    r.submit_queued_operation(api.make_op(specs[0], api.Op.FORWARD, 0))
    n = r.run_until_idle(timeout=30.0)
    assert n == 1
    # every wait was untimed (signal-driven), none was a 50 ms guard
    assert waits, "expected the idle group's worker to park on the cv"
    assert all(t is None for t in waits), waits
    # a 0.4 s op under 50 ms polling would have produced ~8 wakeups per
    # parked worker; signal-driven parking wakes only on notifications
    assert len(waits) <= 4, waits


def test_shutdown_token_wakes_parked_worker_promptly():
    """With an op still RUNNING past the deadline, the shutdown token must
    be notified through the cv: parked workers exit immediately and the
    call returns within deadline + grace, well before the op finishes."""
    r, specs, _ = make_router(n_groups=2, duration=2.0)
    # group 0's op out-sleeps the deadline; group 1 parks with no work
    r.submit_queued_operation(api.make_op(specs[0], api.Op.FORWARD, 0))
    t0 = time.monotonic()
    with pytest.raises(TimeoutError, match="stuck"):
        r.run_until_idle(timeout=0.15)
    elapsed = time.monotonic() - t0
    # bounded by deadline (0.15) + 1 s abandon grace, NOT by the 2 s op
    assert elapsed < 1.8, elapsed
    # the parked (idle-group) worker was woken by the shutdown
    # notification and exited; only the stuck executor thread may linger
    lingering = [t for t in threading.enumerate()
                 if t.name == "dispatch-g1" and t.is_alive()]
    assert not lingering


# ------------------------------------------------------- pending cleanup
@pytest.mark.parametrize("driver", ["serial", "concurrent"])
def test_pending_table_emptied_after_completion(driver):
    r, specs, _ = make_router(n_groups=2, duration=0.0)
    for s in specs:
        submit_batch(r, s, 4)
    assert len(r.pending) == 8
    if driver == "serial":
        r.drain()
    else:
        r.run_until_idle(timeout=30.0)
    assert r.pending == {}
    assert all(not q for q in r.request_queues.values())


# ---------------------------------------------------------------- billing
def test_billing_aggregates_across_split_deployments():
    """A job with split train/rollout deployments is billed for BOTH WPGs,
    and repeated billing passes are incremental (no double counting)."""
    c = PlexCluster(n_groups=1)
    c.billing["j"] = BillingRecord("j")
    c.router.wpgs = {
        "j-train": SimpleNamespace(spec=SimpleNamespace(job_id="j"),
                                   exec_log=[("update_actor", 1.0)]),
        "j-rollout": SimpleNamespace(spec=SimpleNamespace(job_id="j"),
                                     exec_log=[("generate", 2.0)]),
    }
    c.router.switch_log = [
        {"t": 0.0, "group": 0, "to_job": "j", "t_offload": 0.5,
         "t_load": 0.25}]
    c._bill_from_logs()
    rec = c.billing["j"]
    assert rec.busy_seconds == pytest.approx(3.0)     # both deployments
    assert rec.switch_seconds == pytest.approx(0.75)
    c._bill_from_logs()                               # idempotent re-pass
    assert rec.busy_seconds == pytest.approx(3.0)
    assert rec.switch_seconds == pytest.approx(0.75)
    c.router.wpgs["j-train"].exec_log.append(("update_actor", 0.5))
    c.router.switch_log.append(
        {"t": 1.0, "group": 0, "to_job": "j", "t_offload": 0.1,
         "t_load": 0.1})
    c._bill_from_logs()                               # incremental pickup
    assert rec.busy_seconds == pytest.approx(3.5)
    assert rec.switch_seconds == pytest.approx(0.95)
