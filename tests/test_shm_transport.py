"""Shared-memory transport (launch/shm_transport.py) in isolation.

Pool mechanics (alloc/reuse/high-water trim/destroy, name monotonicity),
the encode/decode roundtrip over nested trees (threshold split, bf16 wire
views, non-contiguous sources, namedtuples, shared leaves), descriptor
probes, the pickle-path passthroughs, and crash reaping by name prefix.
Everything here is single-process — the cross-process behaviour rides in
tests/test_proc_plane.py where real worker processes exist.
"""
import collections
import os

import numpy as np
import pytest

from repro.launch import shm_transport as shmt

pytestmark = pytest.mark.skipif(
    not shmt.shm_available(), reason="no usable shared memory on this host")


def shm_names(prefix: str):
    """Live /dev/shm entries under a test's segment prefix."""
    try:
        return sorted(n for n in os.listdir(shmt.SHM_DIR)
                      if n.startswith(prefix))
    except FileNotFoundError:
        return []


# ------------------------------------------------------------------- pool
def test_pool_alloc_reuse_and_trim():
    pool = shmt.SegmentPool("t-pool", max_pool_bytes=64 << 20,
                            max_free_segments=2)
    try:
        a = pool.alloc(1 << 20)
        assert a.size >= 1 << 20 and pool.busy_count() == 1
        pool.release([a.name])
        # same-size alloc is a free-list hit, not a new segment
        b = pool.alloc(1 << 20)
        assert b.name == a.name and pool.created == 1 and pool.reused == 1
        # names are monotonic: a released-then-trimmed name never comes back
        c = pool.alloc(4 << 20)
        assert c.name != b.name
        pool.release([b.name, c.name])
        # over the free-list cap, largest segments are unlinked first
        d = pool.alloc(8 << 20)
        e = pool.alloc(16 << 20)
        pool.release([d.name, e.name])
        assert len(pool.names()) <= pool.busy_count() + 2
        live = shm_names("t-pool")
        assert e.name not in live         # largest got trimmed
    finally:
        pool.destroy()
    assert shm_names("t-pool") == []


def test_pool_release_unknown_name_is_noop():
    pool = shmt.SegmentPool("t-noop")
    try:
        assert pool.release(["t-noop-999", "someone-else"]) == 0
    finally:
        pool.destroy()


def test_pool_high_water_bytes():
    pool = shmt.SegmentPool("t-hw", max_pool_bytes=2 << 20,
                            max_free_segments=8)
    try:
        segs = [pool.alloc(1 << 20) for _ in range(4)]
        pool.release([s.name for s in segs])
        assert pool.free_bytes() <= 2 << 20
    finally:
        pool.destroy()


# -------------------------------------------------------- encode / decode
Point = collections.namedtuple("Point", "x y")


def test_roundtrip_nested_tree():
    pool = shmt.SegmentPool("t-rt")
    cache = shmt.SegmentCache()
    big = np.arange(1 << 18, dtype=np.float32)            # 1 MiB
    small = np.arange(16, dtype=np.int64)                 # under threshold
    tree = {"a": big, "b": {"c": small, "d": [big * 2, "text", 7]},
            "p": Point(x=big * 3, y=None)}
    try:
        enc, segs = shmt.encode(tree, pool, threshold=64 << 10)
        # all large leaves pack into ONE segment; small array pickles
        assert len(segs) == 1
        assert isinstance(enc["a"], shmt.ShmRef)
        assert isinstance(enc["b"]["c"], np.ndarray)
        assert isinstance(enc["p"], Point)                 # shape preserved
        assert shmt.has_refs(enc) and shmt.refs_in(enc) == segs
        dec = shmt.decode(enc, cache, copy=True)
        np.testing.assert_array_equal(dec["a"], big)
        np.testing.assert_array_equal(dec["b"]["d"][0], big * 2)
        np.testing.assert_array_equal(dec["p"].x, big * 3)
        assert dec["b"]["d"][1] == "text" and dec["p"].y is None
        # copies own their data — releasing the segment can't corrupt them
        assert dec["a"].base is None
        pool.release(segs)
    finally:
        cache.close()
        pool.destroy()


def test_decode_views_are_zero_copy():
    pool = shmt.SegmentPool("t-view")
    cache = shmt.SegmentCache()
    arr = np.arange(1 << 18, dtype=np.float32)
    try:
        enc, segs = shmt.encode({"w": arr}, pool, threshold=1024)
        dec = shmt.decode(enc, cache, copy=False)
        assert dec["w"].base is not None                   # a view, no copy
        np.testing.assert_array_equal(dec["w"], arr)
        del dec                                            # drop the view…
        pool.release(segs)
    finally:
        cache.close()                                      # …before unmap
        pool.destroy()


def test_bf16_wire_roundtrip():
    ml_dtypes = pytest.importorskip("ml_dtypes")
    pool = shmt.SegmentPool("t-bf16")
    cache = shmt.SegmentCache()
    arr = np.linspace(-4, 4, 1 << 17, dtype=np.float32).astype(
        ml_dtypes.bfloat16)
    try:
        enc, segs = shmt.encode({"p": arr}, pool, threshold=1024)
        ref = enc["p"]
        assert ref.dtype == "bfloat16" and ref.nbytes == arr.nbytes
        dec = shmt.decode(enc, cache, copy=True)
        assert dec["p"].dtype == ml_dtypes.bfloat16
        np.testing.assert_array_equal(dec["p"], arr)
        pool.release(segs)
    finally:
        cache.close()
        pool.destroy()


def test_non_contiguous_source():
    pool = shmt.SegmentPool("t-nc")
    cache = shmt.SegmentCache()
    base = np.arange(1 << 18, dtype=np.float64).reshape(512, 512)
    sliced = base[::2, ::2]                                # non-contiguous
    assert not sliced.flags.c_contiguous
    try:
        enc, segs = shmt.encode({"s": sliced}, pool, threshold=1024)
        dec = shmt.decode(enc, cache, copy=True)
        np.testing.assert_array_equal(dec["s"], sliced)
        pool.release(segs)
    finally:
        cache.close()
        pool.destroy()


def test_shared_leaf_written_once():
    pool = shmt.SegmentPool("t-shared")
    cache = shmt.SegmentCache()
    arr = np.ones(1 << 18, np.float32)
    try:
        enc, segs = shmt.encode({"a": arr, "b": arr}, pool, threshold=1024)
        assert enc["a"] is enc["b"]                        # one descriptor
        dec = shmt.decode(enc, cache, copy=True)
        np.testing.assert_array_equal(dec["a"], dec["b"])
        pool.release(segs)
    finally:
        cache.close()
        pool.destroy()


def test_threshold_and_passthrough():
    pool = shmt.SegmentPool("t-thresh")
    small_tree = {"x": np.arange(8, dtype=np.float32), "y": 3}
    try:
        # everything under threshold → untouched object, no segments
        enc, segs = shmt.encode(small_tree, pool, threshold=1 << 20)
        assert segs == [] and enc is small_tree
        assert not shmt.has_refs(enc)
        # no pool (shm off) → same
        enc2, segs2 = shmt.encode({"w": np.ones(1 << 20)}, None)
        assert segs2 == [] and not shmt.has_refs(enc2)
        # object-dtype arrays never take the shm path
        objs = np.array([{"k": 1}, None], dtype=object)
        enc3, segs3 = shmt.encode({"o": objs}, pool, threshold=0)
        assert segs3 == []
        # decode of a ref-free tree is identity
        assert shmt.decode(small_tree, shmt.SegmentCache()) is small_tree
    finally:
        pool.destroy()


def test_transport_bundle_disabled_is_noop():
    tr = shmt.Transport(prefix="t-off", enabled=False)
    big = {"w": np.ones(1 << 20, np.float32)}
    enc, segs = tr.encode(big)
    assert enc is big and segs == [] and tr.pool_names() == []
    tr.close()
    assert shm_names("t-off") == []


# ----------------------------------------------------------------- reaping
def test_reap_prefix_scan_and_tracked_fallback():
    pool = shmt.SegmentPool("t-reap")
    a = pool.alloc(1 << 20)
    b = pool.alloc(1 << 20)
    names = [a.name, b.name]
    # simulate the owner dying without cleanup: drop the pool on the floor
    del pool
    assert set(shm_names("t-reap")) == set(names)
    removed = shmt.reap_prefix("t-reap", tracked=names)
    assert set(removed) == set(names)
    assert shm_names("t-reap") == []
    # idempotent: a second sweep finds nothing
    assert shmt.reap_prefix("t-reap", tracked=names) == []
    # prefix is respected — other owners' segments are never touched
    other = shmt.SegmentPool("t-keep")
    keep = other.alloc(1 << 20)
    try:
        assert shmt.reap_prefix("t-reap", tracked=[keep.name]) == []
        assert shm_names("t-keep") == [keep.name]
    finally:
        other.destroy()


def test_segment_cache_lru_eviction():
    pool = shmt.SegmentPool("t-lru", max_free_segments=16,
                            max_pool_bytes=1 << 30)
    cache = shmt.SegmentCache(max_entries=2)
    try:
        segs = [pool.alloc(1 << 20) for _ in range(3)]
        for s in segs:
            np.ndarray(4, np.float32, buffer=s.buf)[:] = 1.0
            ref = shmt.ShmRef(segment=s.name, offset=0, shape=(4,),
                              dtype="<f4", nbytes=16)
            np.testing.assert_array_equal(cache.view(ref), np.ones(4))
        assert len(cache._shms) <= 2                       # oldest evicted
        assert cache.seen == {s.name for s in segs}        # …but remembered
    finally:
        cache.close()
        pool.destroy()
