"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _qkv(b, s, h, kh, d, dtype, seed=0, t=None):
    t = t or s
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, kh, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, kh, d), jnp.float32).astype(dtype)
    return q, k, v


TOL = {jnp.float32: dict(rtol=2e-3, atol=2e-3),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kh,d", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 192, 4, 1, 128),     # MQA + non-block-multiple seq (padding path)
])
def test_flash_attention_sweep(b, s, h, kh, d, dtype):
    q, k, v = _qkv(b, s, h, kh, d, dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    kk = jnp.repeat(k, h // kh, 2)
    vv = jnp.repeat(v, h // kh, 2)
    expect = ref.ref_attention(q, kk, vv, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [0, 32, 64])
def test_flash_attention_sliding_window(window):
    q, k, v = _qkv(1, 128, 4, 2, 64, jnp.float32, seed=1)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    kk, vv = jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2)
    expect = ref.ref_attention(q, kk, vv, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_softcap_gemma():
    q, k, v = _qkv(1, 128, 4, 4, 64, jnp.float32, seed=2)
    out = ops.flash_attention(q, k, v, causal=True, softcap=50.0,
                              scale=0.125, block_q=64, block_k=64)
    expect = ref.ref_attention(q, k, v, causal=True, softcap=50.0, scale=0.125)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("pos", [0, 63, 100, 255])
def test_decode_attention_sweep(pos, dtype):
    b, h, kh, d, t = 2, 8, 2, 64, 256
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (b, t, kh, d), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (b, t, kh, d), jnp.float32).astype(dtype)
    out = ops.decode_attention(q, kc, vc, pos, block_k=64)
    expect = ref.ref_decode_attention(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 16, 32, 16),
    (2, 96, 4, 8, 16, 32),       # padding path (96 % 32 == 0, but try 24)
    (1, 72, 2, 8, 16, 24),
])
def test_ssd_kernel_sweep(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y, st = ops.ssd(x, dt, A, B, C, chunk=chunk)
    ye, ste = ref.ref_ssd_naive(x.astype(jnp.float32), dt, A, B, C)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ye, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(ste),
                               rtol=1e-2, atol=1e-2)


def test_ssd_kernel_matches_model_oracle():
    """Kernel == repro.models.mamba2.ssd_chunked (the model's XLA path)."""
    b, s, h, p, n = 2, 64, 4, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y, st = ops.ssd(x, dt, A, B, C, chunk=16)
    ye, ste = ref.ref_ssd(x, dt, A, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(ste), rtol=1e-3,
                               atol=1e-3)


def test_hd_parallel_decode_matches_attention_core():
    """The grouped (kh, g) decode einsum path == the standard core."""
    from repro.models.layers import _hd_parallel_decode_attention, attention_core
    b, s, h, kh, d, t = 2, 1, 8, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, t, kh, d))
    v = jax.random.normal(ks[2], (b, t, kh, d))
    pos = jnp.full((b, s), 40)
    kv_mask = jnp.arange(t) <= 40
    out = _hd_parallel_decode_attention(q, k, v, q_positions=pos,
                                        kv_mask=kv_mask, window=0,
                                        softcap=None, scale=d ** -0.5)
    expect = attention_core(q, k, v, q_positions=pos,
                            kv_positions=jnp.arange(t), causal=True,
                            window=0, softcap=None, scale=d ** -0.5,
                            kv_mask=kv_mask, q_chunk=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)
