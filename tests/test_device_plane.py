"""Device plane: mesh-slice carving, device-aware state residency,
cross-mesh migration (bit-identity, rollback), the bounded exec log, and —
under XLA_FLAGS=--xla_force_host_platform_device_count=8 — the e2e
disjoint-slice acceptance path."""
import jax
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import api
from repro.core.cluster import BillingRecord, PlexCluster
from repro.core.controller import JobConfig
from repro.core.state_manager import StateManager, Tier
from repro.core.worker import ExecLog
from repro.launch.mesh import DevicePlane, make_local_mesh

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

TINY = (("num_layers", 2), ("d_model", 32), ("num_heads", 4),
        ("num_kv_heads", 2), ("head_dim", 8), ("d_ff", 64),
        ("vocab_size", 64), ("tie_embeddings", True))


# ------------------------------------------------------------ DevicePlane

def test_carve_slices_disjoint_and_cover():
    plane = DevicePlane()
    slices = plane.carve(n_groups=2)
    seen = set()
    for s in slices:
        ids = set(s.device_ids())
        assert ids.isdisjoint(seen), "slices must be disjoint"
        seen |= ids
        assert s.mesh.axis_names == ("data", "model")
        assert s.mesh.shape["model"] == s.n_devices
    assert seen <= {d.id for d in jax.devices()}


def test_acquire_is_idempotent_and_deterministic():
    a, b = DevicePlane(slice_size=max(1, N_DEV // 2)), \
        DevicePlane(slice_size=max(1, N_DEV // 2))
    for plane in (a, b):
        s0 = plane.slice_for_group(0)
        assert plane.slice_for_group(0) is s0     # idempotent per group
    # identical acquisition order -> identical slice assignment (the
    # VirtualClock replay contract: mesh binding is clock-free)
    assert a.slice_for_group(1).index == b.slice_for_group(1).index
    assert a.domains() == b.domains()


def test_release_returns_lease():
    plane = DevicePlane()
    s0 = plane.slice_for_group(0)
    plane.release(0)
    assert plane.slice_index(0) is None
    # the freed slice is the lowest-index free slice again
    assert plane.slice_for_group(7).index == s0.index


def test_oversubscribed_groups_share_least_loaded_slice():
    plane = DevicePlane(slice_size=N_DEV)    # exactly one slice
    s0 = plane.slice_for_group(0)
    s1 = plane.slice_for_group(1)            # no free slice: shared
    assert s0 is s1


def test_make_local_mesh_validates_device_count():
    with pytest.raises(ValueError) as ei:
        make_local_mesh(data=N_DEV + 1, model=1)
    msg = str(ei.value)
    assert "xla_force_host_platform_device_count" in msg
    assert str(N_DEV + 1) in msg


# ---------------------------------------------------------------- ExecLog

def test_exec_log_ring_bounds_memory_and_preserves_cursors():
    """Churn regression: a week-long serve plane must not leak one tuple
    per op — the ring trims, while absolute-offset cursors keep billing
    exact across trims."""
    log = ExecLog(maxlen=16)
    cursor, billed = 0, 0.0
    for i in range(16 * 3):
        log.append(("op", 1.0))
        if i % 10 == 9:                      # bill faster than the trim
            new, cursor = log.since(cursor)
            billed += sum(dt for _, dt in new)
    new, cursor = log.since(cursor)
    billed += sum(dt for _, dt in new)
    assert len(log) <= 16                    # memory bounded
    assert log.total() == 48                 # absolute count preserved
    assert billed == 48.0                    # every op billed exactly once
    assert cursor == 48
    # legacy consumers: iteration / indexing cover the retained window
    assert list(log) == [("op", 1.0)] * len(log)
    assert log[0] == ("op", 1.0)


def test_cluster_billing_consumes_ring_cursors():
    c = PlexCluster(n_groups=1)
    spec = api.DeploymentSpec(deployment_id="jobR-d", job_id="jobR",
                              model_name="qwen2-0.5b", role="train")

    class _W:
        def __init__(self):
            self.spec = spec
            self.exec_log = ExecLog(maxlen=4)

    w = _W()
    c.billing["jobR"] = BillingRecord("jobR")
    for _ in range(12):                      # 3x the ring size
        w.exec_log.append(("op", 0.5))
        with c._bill_lock:
            c._bill_from_logs(extra_wpgs={"jobR-d": w})
    assert c.billing["jobR"].busy_seconds == pytest.approx(6.0)
    assert len(w.exec_log) <= 4


# ------------------------------------------- cross-mesh StateManager moves

def _two_slice_sms():
    plane = DevicePlane(slice_size=max(1, N_DEV // 2))
    src = StateManager(node_id="src", mesh_slice=plane.slice_for_group(0))
    dst = StateManager(node_id="dst", mesh_slice=plane.slice_for_group(1))
    return src, dst


def _sharded_tree(mesh):
    rng = np.random.RandomState(0)
    host = {
        "w": rng.rand(8, N_DEV * 4).astype(np.float32),
        "b": rng.rand(32).astype(np.float32),
        "scale": rng.rand(4, 4).astype(np.float32),
    }
    specs = {"w": P(None, "model"), "b": P(), "scale": P()}
    dev = {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
           for k, v in host.items()}
    return host, dev


def test_cross_slice_migrate_bit_identical_with_host_tier_entry():
    src, dst = _two_slice_sms()
    host, dev = _sharded_tree(src.mesh_slice.mesh)
    src.register("job:dep", dev, Tier.DEVICE, "params")
    mom = {k: np.zeros_like(v) for k, v in host.items()}
    src.register("job:dep", {"mu": mom}, Tier.DEVICE, "opt")
    # a host-tier (offloaded) entry rides along
    src.offload(["job:dep/params/b"], Tier.HOST)
    assert src.entries["job:dep/params/b"].tier == Tier.HOST

    tmpl = {k: np.zeros_like(v) for k, v in host.items()}
    before = jax.tree.map(np.asarray, src.gather("job:dep", tmpl, "params"))
    moved = src.migrate("job:dep", dst)
    assert moved > 0 and not src.keys_for("job:dep")
    assert src.last_migrate["bytes"] == moved
    assert src.last_migrate["cross_mesh"] == (N_DEV >= 2)

    after = jax.tree.map(np.asarray, dst.gather("job:dep", tmpl, "params"))
    for k in host:
        np.testing.assert_array_equal(before[k], after[k])
    # device-tier entries landed RESHARDED onto the destination slice
    dst_ids = set(dst.mesh_slice.device_ids())
    for key, e in dst.entries.items():
        if e.tier == Tier.DEVICE:
            arr_ids = {d.id for d in e.ref.devices()}
            assert arr_ids <= dst_ids, key
    # the sharded leaf kept its PartitionSpec across the reshard
    w = dst.entries["job:dep/params/w"]
    assert w.tier == Tier.DEVICE
    assert tuple(w.ref.sharding.spec) == (None, "model")


def test_mid_migration_failure_rolls_back():
    src, dst = _two_slice_sms()
    host, dev = _sharded_tree(src.mesh_slice.mesh)
    src.register("job:dep", dev, Tier.DEVICE, "params")
    keys_before = set(src.keys_for("job:dep"))
    tmpl = {k: np.zeros_like(v) for k, v in host.items()}
    before = jax.tree.map(np.asarray, src.gather("job:dep", tmpl, "params"))

    class _FailingEntries(dict):
        inserts = 0

        def __setitem__(self, k, v):
            type(self).inserts += 1
            if type(self).inserts == 2:
                raise RuntimeError("injected mid-migration failure")
            super().__setitem__(k, v)

    failing = _FailingEntries()
    failing.update(dst.entries)
    dst.entries = failing
    with pytest.raises(RuntimeError, match="injected"):
        src.migrate("job:dep", dst)
    # source untouched (all tiers), destination holds no partial copies
    assert set(src.keys_for("job:dep")) == keys_before
    again = jax.tree.map(np.asarray, src.gather("job:dep", tmpl, "params"))
    for k in host:
        np.testing.assert_array_equal(before[k], again[k])
    assert not [k for k in dst.entries if k.startswith("job:dep/")]


def test_prefetch_restores_recorded_spec_on_own_slice():
    src, _ = _two_slice_sms()
    host, dev = _sharded_tree(src.mesh_slice.mesh)
    keys = src.register("job:dep", dev, Tier.DEVICE, "params")
    src.offload(keys, Tier.HOST)
    src.prefetch(keys)
    w = src.entries["job:dep/params/w"]
    assert w.tier == Tier.DEVICE
    assert tuple(w.ref.sharding.spec) == (None, "model")
    ids = {d.id for d in w.ref.sharding.mesh.devices.flat}
    assert ids == set(src.mesh_slice.device_ids())


# ----------------------------------------------- e2e acceptance (8 devices)

def _job(job_id, seed, steps=1):
    return JobConfig(job_id=job_id, model_name="qwen2-0.5b", steps=steps,
                     batch_size=4, group_size=2, max_new_tokens=4,
                     seq_len=24, overrides=TINY, seed=seed)


def _sharding_device_ids(shardings):
    return {d.id
            for s in jax.tree.leaves(
                shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
            for d in s.mesh.devices.flat}


@multi_device
def test_e2e_disjoint_slices_and_cross_slice_live_migration():
    """Two real-model jobs on groups holding DISJOINT mesh slices; one is
    live-migrated across slices with params bit-identical and billing
    conserved."""
    c = PlexCluster(n_groups=2, devices_per_group=4)
    c.add_job(_job("jobM1", 1), group_id=0)
    c.add_job(_job("jobM2", 2), group_id=1)
    c.run(interleave=True)

    assert c.router.mesh_domains() == {0: 0, 1: 1}
    w1 = c.router.wpgs["jobM1-train"]
    w2 = c.router.wpgs["jobM2-train"]
    ids1 = _sharding_device_ids(w1.param_shardings())
    ids2 = _sharding_device_ids(w2.param_shardings())
    assert len(ids1) == 4 and len(ids2) == 4
    assert ids1.isdisjoint(ids2), "groups must execute on disjoint hardware"
    # the WPGs' live params actually reside on their group's slice
    for wpg, ids in ((w1, ids1), (w2, ids2)):
        sm = wpg.sm
        for k in sm.keys_for(wpg.job_prefix, "params"):
            e = sm.entries[k]
            if e.tier == Tier.DEVICE:
                arr_ids = {d.id for d in e.ref.devices()}
                assert arr_ids <= ids

    before = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                          w1.params())
    with c._bill_lock:
        c._bill_from_logs()
    busy_before = c.billing["jobM1"].busy_seconds
    assert busy_before > 0.0

    moved = c.reassign_job("jobM1", 1)
    assert moved > 0
    assert c.router.group_of["jobM1-train"] == 1
    assert c.router.migrate_log[-1]["cross_mesh"] is True

    after = w1.params()
    flat_b = jax.tree.leaves(before)
    flat_a = jax.tree.leaves(after)
    assert len(flat_b) == len(flat_a)
    for b, a in zip(flat_b, flat_a):
        np.testing.assert_array_equal(
            np.asarray(b, np.float32),
            np.asarray(jax.device_get(a), np.float32))
    # migrated state now lives on group 1's slice
    ids_after = {d.id
                 for leaf in flat_a if isinstance(leaf, jax.Array)
                 for d in leaf.devices()}
    assert ids_after and ids_after <= ids2
    # billing conserved: migration itself bills nothing, cursors survive
    with c._bill_lock:
        c._bill_from_logs()
    assert c.billing["jobM1"].busy_seconds == pytest.approx(busy_before)
