"""TrainState pytree + sharding-spec derivation for the full state."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import sharding as shd
from repro.models.registry import Model
from repro.train import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt_state: opt.AdamWState
    step: jax.Array


def init(model: Model, rng, adamw: opt.AdamWConfig = opt.AdamWConfig()) -> TrainState:
    params = model.init_params(rng)
    return TrainState(params=params, opt_state=opt.init(params, adamw),
                      step=jnp.zeros((), jnp.int32))


def abstract(model: Model, adamw: opt.AdamWConfig = opt.AdamWConfig()) -> TrainState:
    ap = model.abstract_params()
    return TrainState(params=ap, opt_state=opt.abstract_state(ap, adamw),
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def partition_specs(model: Model, mesh: Mesh, rules: shd.Rules,
                    zero: bool = True) -> TrainState:
    """PartitionSpecs for TrainState: params by logical axes; moments with
    ZeRO sharding over ``data``."""
    axes = model.logical_axes()
    ap = model.abstract_params()
    pspecs = shd.tree_partition_specs(axes, mesh, rules, ap)
    return TrainState(
        params=pspecs,
        opt_state=opt.state_partition_specs(pspecs, ap, mesh, zero=zero),
        step=P(),
    )


def shardings(model: Model, mesh: Mesh, rules: shd.Rules,
              zero: bool = True) -> TrainState:
    specs = partition_specs(model, mesh, rules, zero)
    return jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                        is_leaf=lambda x: isinstance(x, P))
