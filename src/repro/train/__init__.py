"""Training substrate: optimizer (ZeRO-sharded AdamW), train state,
checkpointing."""
