"""Checkpointing: canonical-key shard save/restore.

Checkpoints are materialised from the canonicalised state view (paper
§4.5.3: "checkpoint creation is treated as materialisation from managed
state"): every tensor is stored under its canonical key, independent of any
process-local layout, so restore works across different parallel configs
(resharding = slicing per the target PartitionSpec at load).

Layout:
  <dir>/<name>/metadata.json           step, keys, shapes, dtypes
  <dir>/<name>/shard_<i>.npz           canonical_key -> ndarray
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common

_SHARD_BYTES = 512 * 1024 * 1024


def _to_numpy(x):
    x = np.asarray(jax.device_get(x))
    if x.dtype == jnp.bfloat16:
        return x.view(np.uint16), "bfloat16"
    return x, str(x.dtype)


def _from_numpy(x, dtype: str):
    if dtype == "bfloat16":
        return x.view(jnp.bfloat16)
    return x


def save(path: str, tree, step: int = 0,
         extra_meta: Optional[Dict[str, Any]] = None) -> str:
    """Save a pytree checkpoint. Returns the checkpoint directory."""
    os.makedirs(path, exist_ok=True)
    flat = common.canonical_flat(tree, is_leaf=lambda x: hasattr(x, "shape"))
    meta: Dict[str, Any] = {"step": int(step), "tensors": {},
                            **(extra_meta or {})}
    shards: list[dict] = [{}]
    sizes = [0]
    for key, leaf in flat.items():
        arr, dtype = _to_numpy(leaf)
        meta["tensors"][key] = {
            "shape": list(arr.shape), "dtype": dtype,
            "shard": len(shards) - 1,
        }
        if sizes[-1] + arr.nbytes > _SHARD_BYTES and shards[-1]:
            shards.append({})
            sizes.append(0)
            meta["tensors"][key]["shard"] = len(shards) - 1
        shards[-1][key.replace("/", "__")] = arr
        sizes[-1] += arr.nbytes
    for i, shard in enumerate(shards):
        np.savez(os.path.join(path, f"shard_{i}.npz"), **shard)
    tmp = os.path.join(path, "metadata.json.tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f)
    os.replace(tmp, os.path.join(path, "metadata.json"))  # atomic commit
    return path


def load_flat(path: str) -> tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    with open(os.path.join(path, "metadata.json")) as f:
        meta = json.load(f)
    out: Dict[str, np.ndarray] = {}
    by_shard: Dict[int, list] = {}
    for key, info in meta["tensors"].items():
        by_shard.setdefault(info["shard"], []).append((key, info))
    for shard_idx, entries in by_shard.items():
        with np.load(os.path.join(path, f"shard_{shard_idx}.npz")) as z:
            for key, info in entries:
                out[key] = _from_numpy(z[key.replace("/", "__")], info["dtype"])
    return out, meta


def restore(path: str, template_tree, shardings=None):
    """Restore into the template's structure; optionally device_put with the
    given shardings tree (on-the-fly resharding)."""
    flat, meta = load_flat(path)
    tree = common.canonical_unflatten(
        template_tree, flat, is_leaf=lambda x: hasattr(x, "shape"))
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, meta


def latest(dirpath: str) -> Optional[str]:
    """Find the newest complete checkpoint under dirpath (step_* naming)."""
    if not os.path.isdir(dirpath):
        return None
    cands = []
    for name in os.listdir(dirpath):
        full = os.path.join(dirpath, name)
        if os.path.exists(os.path.join(full, "metadata.json")):
            cands.append((os.path.getmtime(full), full))
    return max(cands)[1] if cands else None
