"""AdamW with ZeRO-style sharded moments (pure JAX, no external deps).

The paper runs ZeRO stage 2 on all trials: parameters follow the model's
TP layout (replicated over ``data``), while optimizer moments are
additionally sharded over the ``data`` axis. ``zero_moment_spec`` derives the
moment PartitionSpec from a parameter's spec by assigning the ``data`` axis to
the first divisible unsharded dim.

A host-resident optimizer step (the paper's ZeRO-offload / §4.5.4 CPU
optimizer) lives in repro.core.state_manager, operating on canonicalised
offloaded state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-5
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    warmup_steps: int = 10
    # moments dtype: f32 is the safe default; bf16 halves optimizer memory
    moment_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def abstract_state(abstract_params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(sds, abstract_params),
        nu=jax.tree.map(sds, abstract_params),
    )


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state: AdamWState, params, cfg: AdamWConfig = AdamWConfig()):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype))

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {
        "grad_norm": gnorm, "lr": lr}


# ------------------------------------------------------------- ZeRO specs

def zero_moment_spec(param_spec: P, shape, mesh: Mesh,
                     zero_axis: str = "data") -> P:
    """Derive a moment PartitionSpec: param spec + ``zero_axis`` on the first
    divisible unsharded dim (ZeRO-2 moment sharding)."""
    if zero_axis not in mesh.axis_names:
        return param_spec
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a:
                used.add(a)
    if zero_axis in used:
        return param_spec
    n = mesh.shape[zero_axis]
    for i, e in enumerate(entries):
        if e is None and shape[i] % n == 0 and shape[i] >= n:
            entries[i] = zero_axis
            while entries and entries[-1] is None:
                entries.pop()
            return P(*entries)
    return param_spec


def state_partition_specs(param_pspecs, abstract_params, mesh: Mesh,
                          zero: bool = True) -> AdamWState:
    """PartitionSpecs for the full AdamWState."""
    if zero:
        mom = jax.tree.map(
            lambda ps, ap: zero_moment_spec(ps, ap.shape, mesh),
            param_pspecs, abstract_params,
            is_leaf=lambda x: isinstance(x, P))
    else:
        mom = param_pspecs
    return AdamWState(step=P(), mu=mom, nu=mom)
