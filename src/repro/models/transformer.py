"""Dense / MoE decoder-only transformer LM (qwen2, qwen3, gemma2,
deepseek-coder, arctic, granite families).

Layers are scanned (``jax.lax.scan`` over stacked params) with per-group
remat, so the compiled HLO stays one-group-sized regardless of depth. For
local/global alternating attention (gemma2) the scan iterates over groups of
``local_global_period`` layers so each sub-layer gets a *static* window —
no doubled attention compute.

Supports full-sequence forward (train / prefill-with-cache) and single-token
decode against a KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import moe as moe_lib
from repro.models.common import spec, stack_specs
from repro.models.layers import (
    Ctx,
    apply_norm,
    attn_apply,
    attn_param_specs,
    embed_apply,
    embed_param_specs,
    mlp_apply,
    mlp_param_specs,
    norm_param_specs,
    remat_policy,
    unembed_apply,
)


# ------------------------------------------------------------------ params

def layer_param_specs(cfg: ModelConfig):
    p = {
        "ln1": norm_param_specs(cfg),
        "attn": attn_param_specs(cfg),
        "ln2": norm_param_specs(cfg),
    }
    if cfg.num_experts:
        p["moe"] = moe_lib.moe_param_specs(cfg)
        if cfg.dense_residual:
            p["mlp"] = mlp_param_specs(cfg, cfg.d_ff)
    else:
        p["mlp"] = mlp_param_specs(cfg, cfg.d_ff)
    if cfg.post_norms:
        p["ln1_post"] = norm_param_specs(cfg)
        p["ln2_post"] = norm_param_specs(cfg)
    return p


def param_specs(cfg: ModelConfig):
    return {
        "embed": embed_param_specs(cfg),
        "layers": stack_specs(layer_param_specs(cfg), cfg.num_layers),
        "ln_f": norm_param_specs(cfg),
    }


def _group_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, period): scan over groups of `period` static sub-layers."""
    period = cfg.local_global_period or 1
    assert cfg.num_layers % period == 0, (cfg.name, cfg.num_layers, period)
    return cfg.num_layers // period, period


def _sub_window(cfg: ModelConfig, j: int, period: int) -> int:
    """Static sliding window for sub-layer j of a group (gemma2: local first,
    global last)."""
    if cfg.sliding_window and period > 1 and j < period - 1:
        return cfg.sliding_window
    if cfg.sliding_window and period == 1:
        return cfg.sliding_window
    return 0


# ----------------------------------------------------------------- forward

def _ffn(p, cfg: ModelConfig, x, ctx):
    if cfg.num_experts:
        out, aux = moe_lib.moe_apply(p["moe"], cfg, x, ctx)
        if cfg.dense_residual:
            out = out + mlp_apply(p["mlp"], cfg, x, ctx)
        return out, aux
    return mlp_apply(p["mlp"], cfg, x, ctx), jnp.zeros((), jnp.float32)


def layer_apply(p, cfg: ModelConfig, x, *, positions, window: int, ctx,
                cache=None, cache_pos=None):
    """One decoder layer. Returns (x, aux, kv)."""
    from repro.models.layers import constrain
    # seq_res is a no-op under the baseline rules; under the
    # sequence-parallel rules it shards the residual stream over the model
    # axis between blocks (Megatron SP: all-reduce -> RS/AG pairs in bf16).
    x = constrain(ctx, x, ("batch", "seq_res", "embed"))
    h = apply_norm(p["ln1"], x, cfg)
    a, kv = attn_apply(p["attn"], cfg, h, positions=positions, causal=True,
                       window=window, ctx=ctx, cache=cache, cache_pos=cache_pos)
    if cfg.post_norms:
        a = apply_norm(p["ln1_post"], a, cfg)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg)
    m, aux = _ffn(p, cfg, h, ctx)
    if cfg.post_norms:
        m = apply_norm(p["ln2_post"], m, cfg)
    return x + m, aux, kv


def forward(params, cfg: ModelConfig, tokens, ctx: Optional[Ctx] = None,
            return_cache: bool = False):
    """Teacher-forcing forward. tokens: (B, S) -> (logits, aux[, cache])."""
    b, s = tokens.shape
    x = embed_apply(params["embed"], cfg, tokens, ctx)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    n_groups, period = _group_layout(cfg)
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, period) + a.shape[1:]), params["layers"])

    def group_body(x, p_group):
        auxs, ks, vs = [], [], []
        for j in range(period):
            p_layer = jax.tree.map(lambda a: a[j], p_group)
            x, aux, kv = layer_apply(
                p_layer, cfg, x, positions=positions,
                window=_sub_window(cfg, j, period), ctx=ctx)
            auxs.append(aux)
            ks.append(kv["k"])
            vs.append(kv["v"])
        aux = jnp.stack(auxs).mean()
        if return_cache:
            return x, (aux, jnp.stack(ks), jnp.stack(vs))
        return x, aux

    policy = remat_policy(cfg)
    fn = group_body if policy is None else jax.checkpoint(group_body, policy=policy)
    x, ys = jax.lax.scan(fn, x, grouped)

    x = apply_norm(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], cfg, x, ctx)
    if return_cache:
        aux, ks, vs = ys  # (n_groups, period, B, S, K, D)
        flat = lambda a: a.reshape((cfg.num_layers,) + a.shape[2:])
        cache = {"k": flat(ks), "v": flat(vs),
                 "pos": jnp.full((), s, jnp.int32)}
        return logits, aux.mean(), cache
    return logits, ys.mean()


# ------------------------------------------------------------------ decode

def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    k, hd, l = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
    kv = spec((l, batch, max_len, k, hd),
              ("layers", "cache_batch", "cache_seq", "kv_heads", "cache_hd"),
              "zeros")
    return {"k": kv, "v": kv, "pos": spec((), (), "zeros", dtype=jnp.int32)}


def decode_step(params, cfg: ModelConfig, cache, tokens,
                ctx: Optional[Ctx] = None):
    """One decode step. tokens: (B, 1). cache k/v: (L, B, T, K, D)."""
    b = tokens.shape[0]
    pos = cache["pos"]
    x = embed_apply(params["embed"], cfg, tokens, ctx)
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    n_groups, period = _group_layout(cfg)
    regroup = lambda a: a.reshape((n_groups, period) + a.shape[1:])
    grouped = jax.tree.map(regroup, params["layers"])
    ck, cv = regroup(cache["k"]), regroup(cache["v"])

    def group_body(x, xs):
        p_group, ck_g, cv_g = xs
        ks, vs = [], []
        for j in range(period):
            p_layer = jax.tree.map(lambda a: a[j], p_group)
            x, _, kv = layer_apply(
                p_layer, cfg, x, positions=positions,
                window=_sub_window(cfg, j, period), ctx=ctx,
                cache={"k": ck_g[j], "v": cv_g[j]}, cache_pos=pos)
            ks.append(kv["k"])
            vs.append(kv["v"])
        return x, (jnp.stack(ks), jnp.stack(vs))

    x, (ks, vs) = jax.lax.scan(group_body, x, (grouped, ck, cv))
    x = apply_norm(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], cfg, x, ctx)
    flat = lambda a: a.reshape((cfg.num_layers,) + a.shape[2:])
    return logits, {"k": flat(ks), "v": flat(vs), "pos": pos + 1}
