"""Common parameter-spec machinery shared by every model family.

Parameters are declared as :class:`ParamSpec` pytrees (shape + logical axes +
init), from which we derive:

- ``abstract_params``  -> ShapeDtypeStruct pytree (dry-run, no allocation)
- ``init_params``      -> materialised arrays (smoke tests / real training)
- ``logical_axes``     -> logical-axis pytree consumed by repro.models.sharding
- ``canonical_flat``   -> flat {key: leaf} view; these keys are the
  StateManager's canonical tensor identifiers (DESIGN.md §4.5.2).
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Tuple[Optional[str], ...]


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Axes                 # logical axis name per dim (None = unsharded)
    init: str = "normal"       # "normal" | "zeros" | "ones" | "embed" | "ssm_a" | "dt_bias"
    dtype: Any = jnp.bfloat16
    scale: float = 1.0         # fan-in style scale multiplier for "normal"


def spec(shape, axes, init="normal", dtype=jnp.bfloat16, scale=1.0) -> ParamSpec:
    assert len(shape) == len(axes), (shape, axes)
    return ParamSpec(tuple(int(s) for s in shape), tuple(axes), init, dtype, scale)


def stack_specs(tree, num: int):
    """Prepend a scanned ``layers`` dimension to every spec in the tree."""
    def _stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec((num,) + s.shape, ("layers",) + s.axes, s.init, s.dtype, s.scale)
    return jax.tree.map(_stack, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract_params(specs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec
    )


def logical_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def _init_one(key, s: ParamSpec):
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "ssm_a":
        # A_log init: log of uniform [1, 16) as in mamba2
        u = jax.random.uniform(key, s.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(s.dtype)
    if s.init == "dt_bias":
        # inverse-softplus of dt uniform in [1e-3, 1e-1]
        dt = jnp.exp(
            jax.random.uniform(key, s.shape, jnp.float32)
            * (math.log(1e-1) - math.log(1e-3))
            + math.log(1e-3)
        )
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(s.dtype)
    # fan-in scaled normal; embeddings use unit scale
    fan_in = s.shape[0] if s.init == "embed" else int(np.prod(s.shape[:-1])) or 1
    std = s.scale / math.sqrt(fan_in) if s.init != "embed" else s.scale
    return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)


def init_params(rng, specs):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, [_init_one(k, s) for k, s in zip(keys, leaves)])


# ---------------------------------------------------------------- canonical keys

def canonical_flat(tree, is_leaf=None) -> dict[str, Any]:
    """Flatten a params pytree into {canonical_key: leaf}.

    Canonical keys are '/'-joined paths — the logical identifiers the
    StateManager deduplicates offloaded state by (paper §4.5.2).
    ParamSpec leaves are kept intact.
    """
    if is_leaf is None:
        is_leaf = is_spec
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def canonical_unflatten(template_tree, flat: dict[str, Any], is_leaf=None):
    """Inverse of canonical_flat, keyed by the template tree's structure."""
    if is_leaf is None:
        is_leaf = is_spec
    paths, treedef = jax.tree_util.tree_flatten_with_path(template_tree, is_leaf=is_leaf)
    leaves = []
    for path, _ in paths:
        key = "/".join(_path_str(p) for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]
