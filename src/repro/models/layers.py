"""Shared model building blocks: norms, RoPE, GQA attention (sliding-window,
softcap, qk-norm, qkv-bias, cross-attention), gated/plain MLPs, embeddings.

All functions are pure; parameters are nested dicts produced from the
``*_param_specs`` declarations in :mod:`repro.models.common`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import sharding
from repro.models.common import spec


class Ctx(NamedTuple):
    """Sharding context threaded through model code (None outside jit)."""

    mesh: object
    rules: sharding.Rules


def constrain(ctx: Optional[Ctx], x, axes):
    if ctx is None:
        return x
    return sharding.constrain(x, ctx.mesh, ctx.rules, axes)


# ------------------------------------------------------------------- norms

def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_param_specs(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": spec((d,), ("embed",), "ones"),
                "bias": spec((d,), ("embed",), "zeros")}
    return {"scale": spec((d,), ("embed",), "zeros")}  # (1 + scale) convention


def apply_norm(p, x, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


# ------------------------------------------------------------------- rope

def rope(x, positions, theta: float):
    """Rotate-half RoPE. x: (B, S, H, D); positions: (B, S) or (S,)."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angle = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(angle)[:, :, None, :]
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, d_model: int):
    """Whisper-style sinusoidal embedding. positions: (B, S) -> (B, S, d)."""
    half = d_model // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10_000.0) / (half - 1)))
    angle = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# --------------------------------------------------------------- attention

def attn_param_specs(cfg: ModelConfig, cross: bool = False):
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": spec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": spec((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wv": spec((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wo": spec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = spec((h, hd), ("heads", "head_dim"), "zeros")
        p["bk"] = spec((k, hd), ("kv_heads", "head_dim"), "zeros")
        p["bv"] = spec((k, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        p["q_norm"] = spec((hd,), ("head_dim",), "zeros")
        p["k_norm"] = spec((hd,), ("head_dim",), "zeros")
    return p


def _project_qkv(p, cfg: ModelConfig, x, kv_x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attention_core(q, k, v, *, q_positions, kv_positions, causal: bool,
                   window: int, softcap: Optional[float], scale: float,
                   kv_mask=None, impl: str = "xla", q_chunk: int = 256):
    """Grouped-query attention.

    q: (B, S, H, D); k, v: (B, T, K, D). Returns (B, S, H, D).
    ``window`` 0 disables sliding-window masking. ``kv_mask`` optionally marks
    valid cache slots (B, T) or (T,).

    For q_len > q_chunk the computation is blocked over query chunks
    (lax.map + per-chunk remat) so the (S, T) score matrix never fully
    materialises — the XLA analogue of the Pallas flash kernel's VMEM tiling.
    """
    if impl == "pallas":  # pragma: no cover - TPU path, validated in kernels tests
        from repro.kernels import ops as kops

        return kops.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        )
    kh = k.shape[2]
    if kh != q.shape[2]:
        # Repeat KV to full heads: keeps the `heads` dim shardable over the
        # model axis (a (kh, groups) reshape would break the 16-way shard and
        # make GSPMD insert per-block all-reduces). Done BEFORE query
        # chunking so the dK/dV group-reduction happens once per layer, not
        # once per chunk.
        g = q.shape[2] // kh
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    if q_chunk and q.shape[1] > q_chunk:
        return _chunked_attention(
            q, k, v, q_positions=q_positions, kv_positions=kv_positions,
            causal=causal, window=window, softcap=softcap, scale=scale,
            kv_mask=kv_mask, q_chunk=q_chunk)
    b, s, h, d = q.shape
    t = k.shape[1]
    # bf16 inputs with f32 accumulation (MXU-style): avoids materialising
    # f32 copies of the K cache on the XLA path.
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qp = q_positions if q_positions.ndim == 2 else q_positions[None, :]
    kp = kv_positions if kv_positions.ndim == 2 else kv_positions[None, :]
    mask = jnp.ones((qp.shape[0], s, t), dtype=bool)
    if causal:
        mask &= kp[:, None, :] <= qp[:, :, None]
    if window:
        mask &= kp[:, None, :] > (qp[:, :, None] - window)
    if kv_mask is not None:
        km = kv_mask if kv_mask.ndim == 2 else kv_mask[None, :]
        mask &= km[:, None, :]
    scores = jnp.where(mask[:, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
    return out


def _hd_parallel_decode_attention(q, k, v, *, q_positions, kv_mask, window,
                                  softcap, scale, ctx=None):
    """GQA decode attention with the head_dim contraction sharded.

    Uses the grouped (kh, g) einsum form — no repeat op — so GSPMD keeps the
    hd-sharded cache local and emits only a small score all-reduce
    (the partial-sum combine) instead of all-gathering the cache.
    q: (B, S, H, D); k, v: (B, T, KH, D).
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qp = q_positions if q_positions.ndim == 2 else q_positions[None, :]
    kp = jnp.arange(t)
    mask = kp[None, None, :] <= qp[:, :, None]
    if window:
        mask &= kp[None, None, :] > (qp[:, :, None] - window)
    km = kv_mask if kv_mask.ndim == 2 else kv_mask[None, :]
    mask &= km[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    # pin the PV output to the cache's head_dim sharding: GSPMD must reshard
    # this tiny tensor for the output projection, not gather the V cache
    out = constrain(ctx, out, ("cache_batch", None, None, None, "cache_hd"))
    return out.reshape(b, s, h, d)


def _chunked_attention(q, k, v, *, q_positions, kv_positions, causal, window,
                       softcap, scale, kv_mask, q_chunk):
    """Query-blocked attention: sequential map over q chunks, each rematted.

    Peak live score memory drops from O(S*T) to O(q_chunk*T) per (batch,
    head); flops are unchanged (full T per chunk — the causal upper triangle
    is masked, not skipped; see kernels/flash_attention.py for the TPU
    kernel that does skip it).
    """
    b, s, h, d = q.shape
    nq = -(-s // q_chunk)
    pad = nq * q_chunk - s
    qp = q_positions if q_positions.ndim == 2 else jnp.broadcast_to(
        q_positions[None, :], (b, s))
    if pad:
        q = jnp.pad(q, [(0, 0), (0, pad), (0, 0), (0, 0)])
        qp = jnp.pad(qp, [(0, 0), (0, pad)], constant_values=-1)
    q_blocks = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, d), 1, 0)
    p_blocks = jnp.moveaxis(qp.reshape(b, nq, q_chunk), 1, 0)

    @jax.checkpoint
    def one(args):
        q_c, p_c = args
        return attention_core(
            q_c, k, v, q_positions=p_c, kv_positions=kv_positions,
            causal=causal, window=window, softcap=softcap, scale=scale,
            kv_mask=kv_mask, q_chunk=0)

    out = jax.lax.map(one, (q_blocks, p_blocks))      # (nq, b, qc, h, d)
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * q_chunk, h, d)
    return out[:, :s]


def attn_apply(p, cfg: ModelConfig, x, *, positions, causal=True, window=0,
               kv_x=None, kv_positions=None, ctx: Optional[Ctx] = None,
               cache=None, cache_pos=None, use_rope=True):
    """Full attention block: project, (rope), (cache update), core, out-proj.

    cache: optional dict {"k": (B, T, K, D), "v": ...} updated at cache_pos.
    Returns ``(out, kv)`` where kv is the updated cache dict when a cache was
    given, else the freshly-projected (post-rope) {"k", "v"} — the prefill
    path uses the latter to build a cache.
    """
    kv_src = x if kv_x is None else kv_x
    q, k, v = _project_qkv(p, cfg, x, kv_src)
    scale = cfg.attn_scale if cfg.attn_scale is not None else cfg.resolved_head_dim ** -0.5
    if use_rope and cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        if kv_x is None:  # self-attention: rotate keys by their own positions
            k = rope(k, kv_positions if kv_positions is not None else positions,
                     cfg.rope_theta)
    if cache is not None:
        # single-token (or short-chunk) decode: write k/v at cache_pos
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype),
                                                 cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype),
                                                 cache_pos, axis=1)
        kv_out = {"k": ck, "v": cv}
        # Head-dim-parallel decode attention: when kv_heads cannot shard
        # over the model axis, the cache is stored head_dim-sharded (the
        # `cache_hd` fallback). Re-shard the tiny queries to match so the
        # QK^T contraction runs as sharded partial sums (small score
        # all-reduce) instead of all-gathering the whole cache.
        hd_parallel = (
            ctx is not None and "model" in getattr(ctx.mesh, "axis_names", ())
            and cfg.num_kv_heads % ctx.mesh.shape["model"] != 0
            and ck.shape[-1] % ctx.mesh.shape["model"] == 0
        )
        kv_pos = jnp.arange(cache["k"].shape[1])
        kv_mask = kv_pos <= (cache_pos + x.shape[1] - 1)
        if hd_parallel:
            ckc = constrain(ctx, ck, ("cache_batch", "cache_seq", "kv_heads",
                                      "cache_hd"))
            cvc = constrain(ctx, cv, ("cache_batch", "cache_seq", "kv_heads",
                                      "cache_hd"))
            qc = constrain(ctx, q, ("cache_batch", None, None, "cache_hd"))
            out = _hd_parallel_decode_attention(
                qc, ckc, cvc, q_positions=positions, kv_mask=kv_mask,
                window=window, softcap=cfg.attn_logit_softcap, scale=scale,
                ctx=ctx)
        else:
            out = attention_core(
                q, ck, cv, q_positions=positions, kv_positions=kv_pos,
                causal=causal, window=window,
                softcap=cfg.attn_logit_softcap, scale=scale,
                kv_mask=kv_mask, impl="xla", q_chunk=cfg.attn_q_chunk,
            )
    else:
        kv_out = {"k": k, "v": v}
        kv_positions = positions if kv_positions is None else kv_positions
        out = attention_core(
            q, k, v, q_positions=positions, kv_positions=kv_positions,
            causal=causal, window=window, softcap=cfg.attn_logit_softcap,
            scale=scale, impl=cfg.attn_impl, q_chunk=cfg.attn_q_chunk,
        )
    out = constrain(ctx, out, ("batch", "seq", "heads", "head_dim"))
    # pin the row-parallel partial-sum point on the bf16 einsum output so
    # the TP all-reduce runs in bf16 (XLA would otherwise hoist the f32
    # convert of the downstream norm above the all-reduce, doubling bytes)
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    proj = constrain(ctx, proj, ("batch", "seq", "embed"))
    return proj, kv_out


# --------------------------------------------------------------------- mlp

def mlp_param_specs(cfg: ModelConfig, d_ff: int):
    d = cfg.d_model
    if cfg.norm == "layernorm":  # whisper-style plain MLP with biases
        return {
            "wi": spec((d, d_ff), ("embed", "mlp")),
            "bi": spec((d_ff,), ("mlp",), "zeros"),
            "wo": spec((d_ff, d), ("mlp", "embed")),
            "bo": spec((d,), ("embed",), "zeros"),
        }
    return {
        "wi_gate": spec((d, d_ff), ("embed", "mlp")),
        "wi_up": spec((d, d_ff), ("embed", "mlp")),
        "wo": spec((d_ff, d), ("mlp", "embed")),
    }


def _act(cfg: ModelConfig, x):
    if cfg.act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def mlp_apply(p, cfg: ModelConfig, x, ctx: Optional[Ctx] = None):
    if "wi" in p:  # plain MLP with biases (whisper)
        h = jnp.einsum("bsd,df->bsf", x, p["wi"]) + p["bi"]
        h = _act(cfg, h)
        return jnp.einsum("bsf,fd->bsd", h, p["wo"]) + p["bo"]
    g = _act(cfg, jnp.einsum("bsd,df->bsf", x, p["wi_gate"]))
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"])
    h = constrain(ctx, g * u, ("batch", "seq", "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# -------------------------------------------------------------- embeddings

def embed_param_specs(cfg: ModelConfig):
    p = {"embedding": spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           "embed", scale=1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return p


def embed_apply(p, cfg: ModelConfig, tokens, ctx: Optional[Ctx] = None):
    x = p["embedding"].astype(jnp.bfloat16)[tokens]
    if cfg.family == "dense" and cfg.post_norms:  # gemma scales embeddings
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return constrain(ctx, x, ("batch", "seq", "embed"))


def unembed_apply(p, cfg: ModelConfig, x, ctx: Optional[Ctx] = None):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["embedding"]).astype(jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"]).astype(jnp.float32)
    if cfg.final_logit_softcap:
        logits = cfg.final_logit_softcap * jnp.tanh(logits / cfg.final_logit_softcap)
    return constrain(ctx, logits, ("batch", "seq", "vocab"))


def remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint_policies.nothing_saveable
