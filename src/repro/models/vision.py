"""llama-3.2-vision backbone: dense decoder with gated cross-attention image
layers every ``cross_attn_period`` layers (20 cross layers for the 100L/90B).

The vision tower is a STUB per the assignment: ``input_specs()`` provides
patch embeddings already projected to d_model (B, vision_seq, d_model).
Cross layers use tanh-gated residuals (zero-init gates) as in the reference
model, so an image-free init leaves the text path untouched.

Decode: self-attn KV cache + cross K/V precomputed once from the patch
embeddings.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import transformer as tf
from repro.models.common import spec, stack_specs
from repro.models.layers import (
    Ctx,
    apply_norm,
    attn_apply,
    attn_param_specs,
    attention_core,
    embed_apply,
    embed_param_specs,
    mlp_apply,
    mlp_param_specs,
    norm_param_specs,
    remat_policy,
    unembed_apply,
)


def _layout(cfg: ModelConfig):
    q = cfg.cross_attn_period
    n_groups = cfg.num_layers // q
    per_group = q - 1               # self layers per group, then 1 cross layer
    return n_groups, per_group


def cross_layer_param_specs(cfg: ModelConfig):
    return {
        "ln1": norm_param_specs(cfg),
        "attn": attn_param_specs(cfg),
        "gate_attn": spec((), (), "zeros", dtype=jnp.float32),
        "ln2": norm_param_specs(cfg),
        "mlp": mlp_param_specs(cfg, cfg.d_ff),
        "gate_mlp": spec((), (), "zeros", dtype=jnp.float32),
    }


def param_specs(cfg: ModelConfig):
    n_groups, per_group = _layout(cfg)
    return {
        "embed": embed_param_specs(cfg),
        "self_layers": stack_specs(
            stack_specs(tf.layer_param_specs(cfg), per_group), n_groups),
        "cross_layers": stack_specs(cross_layer_param_specs(cfg), n_groups),
        "ln_f": norm_param_specs(cfg),
    }


def _cross_layer(p, cfg: ModelConfig, x, vision, positions, vis_positions, ctx,
                 cross_kv=None):
    h = apply_norm(p["ln1"], x, cfg)
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
        out = attention_core(q, cross_kv["k"], cross_kv["v"],
                             q_positions=positions, kv_positions=vis_positions,
                             causal=False, window=0, softcap=None,
                             scale=cfg.resolved_head_dim ** -0.5)
        a = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
        kv = cross_kv
    else:
        a, kv = attn_apply(p["attn"], cfg, h, positions=positions, kv_x=vision,
                           kv_positions=vis_positions, causal=False, window=0,
                           ctx=ctx, use_rope=False)
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * a
    h = apply_norm(p["ln2"], x, cfg)
    m = mlp_apply(p["mlp"], cfg, h, ctx)
    return x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * m, kv


def forward(params, cfg: ModelConfig, tokens, vision,
            ctx: Optional[Ctx] = None, return_cache: bool = False):
    """tokens: (B, S); vision: (B, T_vis, d_model) stubbed patch embeddings."""
    b, s = tokens.shape
    t_vis = vision.shape[1]
    x = embed_apply(params["embed"], cfg, tokens, ctx)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    vis_positions = jnp.broadcast_to(jnp.arange(t_vis)[None, :], (b, t_vis))
    policy = remat_policy(cfg)

    def group_body(x, xs):
        p_group, p_cross = xs
        ks, vs = [], []
        for j in range(_layout(cfg)[1]):
            p_layer = jax.tree.map(lambda a: a[j], p_group)
            x, _, kv = tf.layer_apply(p_layer, cfg, x, positions=positions,
                                      window=0, ctx=ctx)
            ks.append(kv["k"])
            vs.append(kv["v"])
        x, ckv = _cross_layer(p_cross, cfg, x, vision, positions,
                              vis_positions, ctx)
        if return_cache:
            return x, (jnp.stack(ks), jnp.stack(vs), ckv["k"], ckv["v"])
        return x, None

    fn = group_body if policy is None else jax.checkpoint(group_body, policy=policy)
    x, ys = jax.lax.scan(fn, x, (params["self_layers"], params["cross_layers"]))
    x = apply_norm(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], cfg, x, ctx)
    if return_cache:
        ks, vs, cks, cvs = ys
        cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs,
                 "pos": jnp.full((), s, jnp.int32)}
        return logits, jnp.zeros((), jnp.float32), cache
    return logits, jnp.zeros((), jnp.float32)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    n_groups, per_group = _layout(cfg)
    k, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    kv = spec((n_groups, per_group, batch, max_len, k, hd),
              ("layers", None, "cache_batch", "cache_seq", "kv_heads", "cache_hd"),
              "zeros")
    ckv = spec((n_groups, batch, cfg.vision_seq, k, hd),
               ("layers", "cache_batch", None, "kv_heads", "cache_hd"), "zeros")
    return {"k": kv, "v": kv, "cross_k": ckv, "cross_v": ckv,
            "pos": spec((), (), "zeros", dtype=jnp.int32)}


def decode_step(params, cfg: ModelConfig, cache, tokens,
                ctx: Optional[Ctx] = None):
    b = tokens.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    t_vis = cache["cross_k"].shape[2]
    vis_positions = jnp.broadcast_to(jnp.arange(t_vis)[None, :], (b, t_vis))
    x = embed_apply(params["embed"], cfg, tokens, ctx)

    def group_body(x, xs):
        p_group, p_cross, ck_g, cv_g, xk, xv = xs
        ks, vs = [], []
        for j in range(_layout(cfg)[1]):
            p_layer = jax.tree.map(lambda a: a[j], p_group)
            x, _, kv = tf.layer_apply(p_layer, cfg, x, positions=positions,
                                      window=0, ctx=ctx,
                                      cache={"k": ck_g[j], "v": cv_g[j]},
                                      cache_pos=pos)
            ks.append(kv["k"])
            vs.append(kv["v"])
        x, _ = _cross_layer(p_cross, cfg, x, None, positions, vis_positions,
                            ctx, cross_kv={"k": xk, "v": xv})
        return x, (jnp.stack(ks), jnp.stack(vs))

    x, (ks, vs) = jax.lax.scan(
        group_body, x,
        (params["self_layers"], params["cross_layers"], cache["k"], cache["v"],
         cache["cross_k"], cache["cross_v"]))
    x = apply_norm(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], cfg, x, ctx)
    return logits, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "pos": pos + 1}
