"""Mamba2 (SSD — state-space duality) mixer and attention-free LM.

Implements the chunked SSD algorithm of arXiv:2405.21060: intra-chunk
quadratic (attention-like) blocks plus an inter-chunk recurrent state scan.
Decode is O(1) in sequence length — the cache is a fixed-size
(conv window, SSM state) pair per layer, which is why the ssm/hybrid
families run the ``long_500k`` cell.

The chunk kernel has a Pallas TPU implementation in
``repro.kernels.ssd`` (this module is also its jnp oracle via
``cfg.attn_impl == "xla"``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.common import spec, stack_specs
from repro.models.layers import (
    Ctx,
    apply_norm,
    constrain,
    embed_apply,
    embed_param_specs,
    norm_param_specs,
    remat_policy,
    rms_norm,
    unembed_apply,
)


# ------------------------------------------------------------------ params

def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def in_proj_dim(cfg: ModelConfig) -> int:
    return 2 * cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state + cfg.ssm_nheads


def mixer_param_specs(cfg: ModelConfig):
    d, di, h = cfg.d_model, cfg.d_inner, cfg.ssm_nheads
    return {
        "in_proj": spec((d, in_proj_dim(cfg)), ("embed", "ssm_inner")),
        "conv_w": spec((conv_dim(cfg), cfg.ssm_conv), ("conv_dim", None)),
        "conv_b": spec((conv_dim(cfg),), ("conv_dim",), "zeros"),
        "A_log": spec((h,), ("ssm_heads",), "ssm_a", dtype=jnp.float32),
        "D": spec((h,), ("ssm_heads",), "ones", dtype=jnp.float32),
        "dt_bias": spec((h,), ("ssm_heads",), "dt_bias", dtype=jnp.float32),
        "norm": spec((di,), ("ssm_inner",), "zeros"),
        "out_proj": spec((di, d), ("ssm_inner", "embed")),
    }


def layer_param_specs(cfg: ModelConfig):
    return {"ln": norm_param_specs(cfg), "mixer": mixer_param_specs(cfg)}


def param_specs(cfg: ModelConfig):
    return {
        "embed": embed_param_specs(cfg),
        "layers": stack_specs(layer_param_specs(cfg), cfg.num_layers),
        "ln_f": norm_param_specs(cfg),
    }


# --------------------------------------------------------------------- SSD

def segsum(x):
    """x: (..., l) -> (..., l, l) with out[i, j] = sum_{j<k<=i} x_k (else -inf)."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x: (b, s, h, p); dt: (b, s, h) (post-softplus); A: (h,) (negative);
    B, C: (b, s, g, n). Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    s_orig = s
    if s % chunk:
        # zero-pad: dt=0 at pads -> decay exp(0)=1 and zero state update, so
        # the final state is unaffected; padded outputs are sliced off.
        pad = chunk - s % chunk
        x = jnp.pad(x, [(0, 0), (0, pad), (0, 0), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
        B = jnp.pad(B, [(0, 0), (0, pad), (0, 0), (0, 0)])
        C = jnp.pad(C, [(0, 0), (0, pad), (0, 0), (0, 0)])
        s = s + pad
    nc = s // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)   # (b,nc,l,h,n)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)

    dA = (dtc * A[None, None, None, :]).astype(jnp.float32)       # (b,nc,l,h)
    dA_cs = jnp.cumsum(dA, axis=2)                                # (b,nc,l,h)

    # ---- intra-chunk (diagonal blocks): quadratic within a chunk
    L = jnp.exp(segsum(jnp.moveaxis(dA, -1, -2)))                 # (b,nc,h,l,l)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    scores = scores * L * jnp.moveaxis(dtc, -1, -2)[:, :, :, None, :]
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores.astype(x.dtype), xc)

    # ---- chunk summary states: S_c = sum_j exp(dA_j+1..L) dt_j B_j x_j^T
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)           # (b,nc,l,h)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bc.astype(jnp.float32),
                        (decay_states * dtc).astype(jnp.float32),
                        xc.astype(jnp.float32))                   # (b,nc,h,p,n)

    # ---- inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                     # (b,nc,h)

    def scan_fn(s_in, xs):
        st, dec = xs                                              # (b,h,p,n), (b,h)
        s_out = s_in * dec[:, :, None, None] + st
        return s_out, s_in

    init = (jnp.zeros((b, h, p, n), jnp.float32)
            if initial_state is None else initial_state.astype(jnp.float32))
    final_state, entry_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    entry_states = jnp.moveaxis(entry_states, 0, 1)               # (b,nc,h,p,n)

    # ---- off-diagonal contribution from the incoming state
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Cc.astype(jnp.float32), entry_states, jnp.exp(dA_cs))
    y = (y_diag.astype(jnp.float32) + y_off).reshape(b, s, h, p)
    return y[:, :s_orig].astype(x.dtype), final_state


def ssd_decode(state, x, dt, A, B, C):
    """Single-token SSD update.

    state: (b, h, p, n); x: (b, h, p); dt: (b, h); B, C: (b, g, n).
    Returns (y (b, h, p), new_state).
    """
    h, g = x.shape[1], B.shape[1]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)           # (b,h,n)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt.astype(jnp.float32) * A[None, :])             # (b,h)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt.astype(jnp.float32),
                     x.astype(jnp.float32), Bh)
    new_state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------- conv1d

def causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, C); w: (C, K)."""
    k = w.shape[1]
    xp = jnp.pad(x, [(0, 0), (k - 1, 0), (0, 0)])
    out = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for i in range(k):
        out = out + xp[:, i:i + s, :].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def conv_decode(conv_state, x_new, w, b):
    """conv_state: (B, C, K-1); x_new: (B, C). Returns (out (B, C), new_state)."""
    window = jnp.concatenate([conv_state, x_new[:, :, None]], axis=2)  # (B,C,K)
    out = jnp.einsum("bck,ck->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32))
    out = jax.nn.silu(out + b.astype(jnp.float32)).astype(x_new.dtype)
    return out, window[:, :, 1:]


# ------------------------------------------------------------------- mixer

def _split_in_proj(cfg: ModelConfig, zxbcdt):
    di, gn = cfg.d_inner, cfg.ssm_ngroups * cfg.ssm_state
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    return z, xBC, dt


def _split_xbc(cfg: ModelConfig, xBC):
    di, gn = cfg.d_inner, cfg.ssm_ngroups * cfg.ssm_state
    return xBC[..., :di], xBC[..., di:di + gn], xBC[..., di + gn:]


def mixer_apply(p, cfg: ModelConfig, x, ctx: Optional[Ctx] = None,
                cache=None, return_state: bool = False):
    """Full-sequence mamba2 mixer. x: (B, S, d_model).

    Returns (out, new_cache). With ``cache`` (dict conv/ssm) the input must
    be a single step (S == 1) and the decode path is used. With
    ``return_state`` in full-seq mode, the final (conv, ssm) states are
    returned so a prefill can seed a decode cache.
    """
    b, s, _ = x.shape
    h, pdim, n, g = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_ngroups
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt_raw = _split_in_proj(cfg, zxbcdt)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if cache is None:
        xBC_raw = xBC
        xBC = causal_conv(xBC, p["conv_w"], p["conv_b"])
        xs, B, C = _split_xbc(cfg, xBC)
        xs = constrain(ctx, xs, ("batch", "seq", "ssm_inner"))
        y, final_state = ssd_chunked(xs.reshape(b, s, h, pdim), dt, A,
                                     B.reshape(b, s, g, n), C.reshape(b, s, g, n),
                                     cfg.ssm_chunk)
        y = y + p["D"][None, None, :, None].astype(y.dtype) \
            * xs.reshape(b, s, h, pdim)
        new_cache = None
        if return_state:
            kc = cfg.ssm_conv - 1
            conv_state = jnp.moveaxis(xBC_raw[:, s - kc:, :], 1, 2)  # (B, C, K-1)
            new_cache = {"conv": conv_state, "ssm": final_state}
    else:
        xBC_step, new_conv = conv_decode(cache["conv"], xBC[:, 0],
                                         p["conv_w"], p["conv_b"])
        xs, B, C = _split_xbc(cfg, xBC_step[:, None, :])
        y1, new_ssm = ssd_decode(cache["ssm"], xs[:, 0].reshape(b, h, pdim),
                                 dt[:, 0], A, B[:, 0].reshape(b, g, n),
                                 C[:, 0].reshape(b, g, n))
        y = y1[:, None] + p["D"][None, None, :, None].astype(y1.dtype) \
            * xs.reshape(b, 1, h, pdim)
        new_cache = {"conv": new_conv, "ssm": new_ssm}

    y = y.reshape(b, s, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), new_cache


def block_apply(p, cfg: ModelConfig, x, ctx=None, cache=None,
                return_state: bool = False):
    h = apply_norm(p["ln"], x, cfg)
    out, new_cache = mixer_apply(p["mixer"], cfg, h, ctx, cache, return_state)
    return x + out, new_cache


# ----------------------------------------------------------------- model

def forward(params, cfg: ModelConfig, tokens, ctx: Optional[Ctx] = None,
            return_cache: bool = False):
    b, s = tokens.shape
    x = embed_apply(params["embed"], cfg, tokens, ctx)
    policy = remat_policy(cfg)

    def body(x, p_layer):
        x, st = block_apply(p_layer, cfg, x, ctx, return_state=return_cache)
        return x, (st["conv"], st["ssm"]) if return_cache else None

    fn = body if policy is None else jax.checkpoint(body, policy=policy)
    x, ys = jax.lax.scan(fn, x, params["layers"])
    x = apply_norm(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], cfg, x, ctx)
    if return_cache:
        convs, ssms = ys
        cache = {"conv": convs, "ssm": ssms,
                 "pos": jnp.full((), s, jnp.int32)}
        return logits, jnp.zeros((), jnp.float32), cache
    return logits, jnp.zeros((), jnp.float32)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache: conv window + SSM state per layer. O(1) in max_len."""
    l, h, pdim, n = cfg.num_layers, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    return {
        "conv": spec((l, batch, conv_dim(cfg), cfg.ssm_conv - 1),
                     ("layers", "cache_batch", "conv_dim", None), "zeros"),
        "ssm": spec((l, batch, h, pdim, n),
                    ("layers", "cache_batch", "ssm_heads", None, None),
                    "zeros", dtype=jnp.float32),
        "pos": spec((), (), "zeros", dtype=jnp.int32),
    }


def init_cache_zeros(cfg: ModelConfig, batch: int):
    from repro.models.common import init_params
    import jax.random as jr
    return init_params(jr.PRNGKey(0), cache_specs(cfg, batch, 0))


def decode_step(params, cfg: ModelConfig, cache, tokens,
                ctx: Optional[Ctx] = None):
    b = tokens.shape[0]
    x = embed_apply(params["embed"], cfg, tokens, ctx)

    def body(x, xs):
        p_layer, conv_c, ssm_c = xs
        x, nc = block_apply(p_layer, cfg, x, ctx,
                            cache={"conv": conv_c, "ssm": ssm_c})
        return x, (nc["conv"], nc["ssm"])

    x, (convs, ssms) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["ssm"]))
    x = apply_norm(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], cfg, x, ctx)
    return logits, {"conv": convs, "ssm": ssms, "pos": cache["pos"] + 1}
