"""Token-choice top-k Mixture-of-Experts FFN (capacity-based, scatter dispatch).

Dispatch uses argsort + scatter-add into an (experts, capacity, d_model)
buffer — O(N·k·log) routing with *no* (N, E) one-hot matmuls, so compiled HLO
FLOPs reflect the true active compute (E·C·d·f GEMMs). Supports:

- arctic-480b: 128 experts top-2 with a parallel dense-residual MLP
- granite-moe: 40 experts top-8
- paper qwen3 MoE models: 128 experts top-8

Expert weights carry the ``experts`` logical axis -> EP sharding over the
``model`` mesh axis when divisible (best-effort rules otherwise shard the
per-expert mlp dim).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.common import spec
from repro.models.layers import Ctx, constrain, _act


def moe_param_specs(cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    return {
        "router": spec((d, e), ("embed", "experts"), dtype=jnp.float32),
        "wi_gate": spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wi_up": spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wo": spec((e, f, d), ("experts", "expert_mlp", "embed")),
    }


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    per = n_tokens * cfg.experts_per_token / cfg.num_experts
    return max(8, int(math.ceil(per * cfg.capacity_factor / 8.0)) * 8)


def moe_apply(p, cfg: ModelConfig, x, ctx: Optional[Ctx] = None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Dispatch is BATCH-LOCAL: each batch row routes its own tokens into a
    per-row (experts, cap) buffer. Because the batch dim is data-sharded,
    every routing op (sort, rank, scatter, combine) stays shard-local —
    no cross-device collectives for dispatch; only the expert GEMMs
    communicate (weight gathers under FSDP / EP partial sums). Per-row
    capacity trades a little load-balance slack (covered by
    ``capacity_factor``) for locality — the same trade production MoE
    stacks make.
    """
    b, s, d = x.shape
    k = cfg.experts_per_token
    e = cfg.num_experts
    cap = expert_capacity(cfg, s)
    nk = s * k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_ids = jax.lax.top_k(probs, k)           # (b, s, k)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss (Switch-style) + router z-loss
    me = probs.mean((0, 1))                                # (e,)
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0) / (b * nk)
    aux = e * jnp.sum(me * ce)
    aux = aux + 1e-3 * jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- per-row dispatch: sort by expert, rank-in-expert, scatter.
    # vmapped over the batch row so the scatters carry proper operand
    # batching dims — SPMD then keeps the whole dispatch shard-local instead
    # of treating the batch index as a scattered dim (which forces partial
    # -sum all-reduces of the dispatch buffers).
    flat_eid = expert_ids.reshape(b, nk)
    flat_gw = gate_w.reshape(b, nk)

    def _route_row(x_row, eid_row):
        order = jnp.argsort(eid_row, stable=True)
        sorted_eid = eid_row[order]
        counts = jnp.zeros((e,), jnp.int32).at[eid_row].add(1)
        offsets = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(nk) - offsets[sorted_eid]         # rank within expert
        keep = pos < cap
        pos = jnp.where(keep, pos, 0)
        src = jnp.repeat(jnp.arange(s), k)[order]
        vals = jnp.where(keep[:, None], x_row[src], 0).astype(x_row.dtype)
        buf_row = jnp.zeros((e, cap, d), x_row.dtype).at[sorted_eid, pos].add(vals)
        return buf_row, sorted_eid, pos, keep, src, order

    buf, sorted_eid, pos_sorted, keep, src_tok, order = jax.vmap(_route_row)(
        x, flat_eid)
    has_model = (ctx is not None
                 and "model" in getattr(ctx.mesh, "axis_names", ()))
    ep = has_model and e % ctx.mesh.shape["model"] == 0

    # The dispatch scatter writes at data-dependent expert ids, so it must
    # land in a buffer whose experts dim is UNsharded (SPMD cannot route a
    # dynamic scatter across expert shards without partial-sum all-reduces).
    # EP case: scatter model-replicated, then SLICE down to the EP layout —
    # slicing is free, each model shard keeps its own experts.
    buf = constrain(ctx, buf, ("batch", None, None, None))
    if ep:
        # EP GEMM layout: experts over model AND the contraction dim over
        # data, matching the FSDP-sharded weights — GSPMD then computes
        # aligned partial-sum GEMMs instead of all-gathering the (huge)
        # expert weights every microbatch.
        buf = constrain(ctx, buf, (None, "experts", None, "embed"))

    # ---- expert compute: batched GEMMs over the experts axis.
    # Non-EP (granite: 40 % 16 != 0) with many tokens: gather the (small)
    # FSDP-sharded weights explicitly once per layer; otherwise GSPMD
    # reshards the contraction dim over the idle model axis and pays f32
    # partial-sum all-reduces of the (b, e, cap, f) buffers. For decode
    # (tokens-per-row ~ 1) the partial sums are tiny and gathering would
    # dominate — keep the weights sharded there.
    gather_weights = has_model and not ep and s >= 64
    if gather_weights:
        wi_gate = constrain(ctx, p["wi_gate"], ("experts", None, None))
        wi_up = constrain(ctx, p["wi_up"], ("experts", None, None))
        wo = constrain(ctx, p["wo"], ("experts", None, None))
    else:
        wi_gate, wi_up, wo = p["wi_gate"], p["wi_up"], p["wo"]
    g = _act(cfg, jnp.einsum("becd,edf->becf", buf, wi_gate))
    u = jnp.einsum("becd,edf->becf", buf, wi_up)
    ep_axes = ("batch", "experts", None, None) if ep else \
        ("batch", None, None, None)
    h = constrain(ctx, g * u, ep_axes)
    out_buf = jnp.einsum("becf,efd->becd", h, wo)
    out_buf = constrain(ctx, out_buf, ep_axes)
    if ep:
        # one explicit gather of the expert outputs back to replicated-over-
        # model so the combine's dynamic expert-id gather stays local
        out_buf = constrain(ctx, out_buf, ("batch", None, None, None))

    # ---- combine: gather back, weight by gates, segment-sum per token
    w_sorted = jnp.take_along_axis(flat_gw, order, axis=-1)

    def _combine_row(out_row, sorted_eid_r, pos_r, keep_r, src_r, w_r):
        eo = out_row[sorted_eid_r, pos_r]                  # (nk, d)
        eo = jnp.where(keep_r[:, None], eo, 0)
        return jnp.zeros((s, d), eo.dtype).at[src_r].add(
            eo * w_r[:, None].astype(eo.dtype))

    combined = jax.vmap(_combine_row)(out_buf, sorted_eid, pos_sorted, keep,
                                      src_tok, w_sorted)
    combined = constrain(ctx, combined, ("batch", None, None))
    return combined.astype(x.dtype), aux
