"""zamba2-style hybrid: Mamba2 backbone with ONE shared attention block whose
weights are re-applied every ``attn_period`` blocks (11 applications for the
81-block zamba2-7b).

Layout for L total blocks, period q:
  n_attn   = L // q                      (shared-attn applications)
  n_mamba  = L - n_attn                  (mamba2 blocks)
  grouped  = n_attn groups of (q-1) mamba blocks, each followed by the shared
             attn block; plus ``n_mamba - n_attn*(q-1)`` trailing mamba blocks.

The shared block keeps a *separate KV cache per application* (weights are
shared, activations are not). Sub-quadratic core -> runs the long_500k cell;
the shared-attn KV cache seq dim is sharded over ``data`` by the long-context
rules.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import mamba2 as mb
from repro.models.common import spec, stack_specs
from repro.models.layers import (
    Ctx,
    apply_norm,
    attn_apply,
    attn_param_specs,
    embed_apply,
    embed_param_specs,
    mlp_apply,
    mlp_param_specs,
    norm_param_specs,
    remat_policy,
    unembed_apply,
)


def _layout(cfg: ModelConfig):
    q = cfg.attn_period
    n_attn = cfg.num_layers // q
    n_mamba = cfg.num_layers - n_attn
    per_group = q - 1
    trailing = n_mamba - n_attn * per_group
    return n_attn, per_group, trailing


def shared_block_param_specs(cfg: ModelConfig):
    return {
        "ln1": norm_param_specs(cfg),
        "attn": attn_param_specs(cfg),
        "ln2": norm_param_specs(cfg),
        "mlp": mlp_param_specs(cfg, cfg.d_ff),
    }


def param_specs(cfg: ModelConfig):
    n_attn, per_group, trailing = _layout(cfg)
    p = {
        "embed": embed_param_specs(cfg),
        "mamba_grouped": stack_specs(
            stack_specs(mb.layer_param_specs(cfg), per_group), n_attn),
        "shared_attn": shared_block_param_specs(cfg),
        "ln_f": norm_param_specs(cfg),
    }
    if trailing:
        p["mamba_tail"] = stack_specs(mb.layer_param_specs(cfg), trailing)
    return p


def _shared_attn_apply(p, cfg: ModelConfig, x, positions, ctx,
                       cache=None, cache_pos=None):
    h = apply_norm(p["ln1"], x, cfg)
    a, kv = attn_apply(p["attn"], cfg, h, positions=positions, causal=True,
                       window=0, ctx=ctx, cache=cache, cache_pos=cache_pos)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg)
    return x + mlp_apply(p["mlp"], cfg, h, ctx), kv


def forward(params, cfg: ModelConfig, tokens, ctx: Optional[Ctx] = None,
            return_cache: bool = False):
    b, s = tokens.shape
    x = embed_apply(params["embed"], cfg, tokens, ctx)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    policy = remat_policy(cfg)
    shared = params["shared_attn"]

    def mamba_body(x, p_layer):
        x, st = mb.block_apply(p_layer, cfg, x, ctx, return_state=return_cache)
        return x, (st["conv"], st["ssm"]) if return_cache else None

    def group_body(x, p_group):
        x, states = jax.lax.scan(mamba_body, x, p_group)
        x, kv = _shared_attn_apply(shared, cfg, x, positions, ctx)
        if return_cache:
            return x, (kv["k"], kv["v"], states[0], states[1])
        return x, None

    fn = group_body if policy is None else jax.checkpoint(group_body, policy=policy)
    x, ys = jax.lax.scan(fn, x, params["mamba_grouped"])
    tail_states = None
    if "mamba_tail" in params:
        tail_fn = mamba_body if policy is None else jax.checkpoint(mamba_body,
                                                                   policy=policy)
        x, tail_states = jax.lax.scan(tail_fn, x, params["mamba_tail"])
    x = apply_norm(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], cfg, x, ctx)
    if return_cache:
        ks, vs, convs, ssms = ys
        cache = {"attn_k": ks, "attn_v": vs, "conv_g": convs, "ssm_g": ssms,
                 "pos": jnp.full((), s, jnp.int32)}
        if tail_states is not None:
            cache["conv_t"], cache["ssm_t"] = tail_states
        return logits, jnp.zeros((), jnp.float32), cache
    return logits, jnp.zeros((), jnp.float32)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    n_attn, per_group, trailing = _layout(cfg)
    k, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    kv = spec((n_attn, batch, max_len, k, hd),
              ("layers", "cache_batch", "cache_seq", "kv_heads", "cache_hd"),
              "zeros")
    c = {
        "attn_k": kv,
        "attn_v": kv,
        "conv_g": spec((n_attn, per_group, batch, mb.conv_dim(cfg), cfg.ssm_conv - 1),
                       ("layers", None, "cache_batch", "conv_dim", None), "zeros"),
        "ssm_g": spec((n_attn, per_group, batch, cfg.ssm_nheads, cfg.ssm_head_dim,
                       cfg.ssm_state),
                      ("layers", None, "cache_batch", "ssm_heads", None, None),
                      "zeros", dtype=jnp.float32),
        "pos": spec((), (), "zeros", dtype=jnp.int32),
    }
    if trailing:
        c["conv_t"] = spec((trailing, batch, mb.conv_dim(cfg), cfg.ssm_conv - 1),
                           ("layers", "cache_batch", "conv_dim", None), "zeros")
        c["ssm_t"] = spec((trailing, batch, cfg.ssm_nheads, cfg.ssm_head_dim,
                           cfg.ssm_state),
                          ("layers", "cache_batch", "ssm_heads", None, None),
                          "zeros", dtype=jnp.float32)
    return c


def decode_step(params, cfg: ModelConfig, cache, tokens,
                ctx: Optional[Ctx] = None):
    b = tokens.shape[0]
    pos = cache["pos"]
    x = embed_apply(params["embed"], cfg, tokens, ctx)
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    shared = params["shared_attn"]

    def mamba_body(x, xs):
        p_layer, conv_c, ssm_c = xs
        x, nc = mb.block_apply(p_layer, cfg, x, ctx,
                               cache={"conv": conv_c, "ssm": ssm_c})
        return x, (nc["conv"], nc["ssm"])

    def group_body(x, xs):
        p_group, conv_g, ssm_g, ck, cv = xs
        x, (convs, ssms) = jax.lax.scan(mamba_body, x, (p_group, conv_g, ssm_g))
        x, kv = _shared_attn_apply(shared, cfg, x, positions, ctx,
                                   cache={"k": ck, "v": cv}, cache_pos=pos)
        return x, (convs, ssms, kv["k"], kv["v"])

    x, (convs, ssms, ks, vs) = jax.lax.scan(
        group_body, x,
        (params["mamba_grouped"], cache["conv_g"], cache["ssm_g"],
         cache["attn_k"], cache["attn_v"]))
    new_cache = {"conv_g": convs, "ssm_g": ssms, "attn_k": ks, "attn_v": vs,
                 "pos": pos + 1}
    if "mamba_tail" in params:
        x, (convs_t, ssms_t) = jax.lax.scan(
            mamba_body, x, (params["mamba_tail"], cache["conv_t"], cache["ssm_t"]))
        new_cache["conv_t"], new_cache["ssm_t"] = convs_t, ssms_t
    x = apply_norm(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], cfg, x, ctx)
    return logits, new_cache
