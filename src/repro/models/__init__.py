"""Model zoo substrate: pure-JAX model families with declarative param specs
and logical-axis sharding annotations."""
