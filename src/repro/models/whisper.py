"""Whisper-large-v3 backbone: encoder-decoder transformer.

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, encoder_seq, d_model) directly into the
encoder. Positional information uses (parameter-free) sinusoidal embeddings
so parameter shapes stay independent of the assigned sequence lengths
(real whisper uses learned decoder positions; noted in DESIGN.md).

Decode: decoder self-attn KV cache of the assigned length plus cross-attn
K/V precomputed once from the encoder output.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.common import spec, stack_specs
from repro.models.layers import (
    Ctx,
    apply_norm,
    attn_apply,
    attn_param_specs,
    constrain,
    embed_apply,
    embed_param_specs,
    mlp_apply,
    mlp_param_specs,
    norm_param_specs,
    remat_policy,
    sinusoidal_positions,
    unembed_apply,
    _project_qkv,
)


# ------------------------------------------------------------------ params

def enc_layer_param_specs(cfg: ModelConfig):
    return {
        "ln1": norm_param_specs(cfg),
        "attn": attn_param_specs(cfg),
        "ln2": norm_param_specs(cfg),
        "mlp": mlp_param_specs(cfg, cfg.d_ff),
    }


def dec_layer_param_specs(cfg: ModelConfig):
    return {
        "ln1": norm_param_specs(cfg),
        "self_attn": attn_param_specs(cfg),
        "ln2": norm_param_specs(cfg),
        "cross_attn": attn_param_specs(cfg),
        "ln3": norm_param_specs(cfg),
        "mlp": mlp_param_specs(cfg, cfg.d_ff),
    }


def param_specs(cfg: ModelConfig):
    return {
        "embed": embed_param_specs(cfg),
        "enc_layers": stack_specs(enc_layer_param_specs(cfg), cfg.encoder_layers),
        "enc_ln_f": norm_param_specs(cfg),
        "layers": stack_specs(dec_layer_param_specs(cfg), cfg.num_layers),
        "ln_f": norm_param_specs(cfg),
    }


# ----------------------------------------------------------------- encoder

def encode(params, cfg: ModelConfig, frames, ctx: Optional[Ctx] = None):
    """frames: (B, T_enc, d_model) stubbed frame embeddings -> (B, T_enc, d)."""
    b, t, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
    x = frames + sinusoidal_positions(pos, cfg.d_model).astype(frames.dtype)
    x = constrain(ctx, x, ("batch", "seq", "embed"))
    policy = remat_policy(cfg)

    def body(x, p_layer):
        h = apply_norm(p_layer["ln1"], x, cfg)
        a, _ = attn_apply(p_layer["attn"], cfg, h, positions=pos, causal=False,
                          window=0, ctx=ctx, use_rope=False)
        x = x + a
        h = apply_norm(p_layer["ln2"], x, cfg)
        return x + mlp_apply(p_layer["mlp"], cfg, h, ctx), None

    fn = body if policy is None else jax.checkpoint(body, policy=policy)
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return apply_norm(params["enc_ln_f"], x, cfg)


# ----------------------------------------------------------------- decoder

def _dec_layer(p, cfg: ModelConfig, x, enc_out, positions, enc_positions, ctx,
               cache=None, cache_pos=None, cross_kv=None):
    h = apply_norm(p["ln1"], x, cfg)
    a, kv = attn_apply(p["self_attn"], cfg, h, positions=positions, causal=True,
                       window=0, ctx=ctx, cache=cache, cache_pos=cache_pos,
                       use_rope=False)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg)
    if cross_kv is not None:
        # decode: reuse precomputed cross K/V
        from repro.models.layers import attention_core
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross_attn"]["wq"])
        if cfg.qkv_bias:
            q = q + p["cross_attn"]["bq"]
        scale = cfg.resolved_head_dim ** -0.5
        out = attention_core(q, cross_kv["k"], cross_kv["v"],
                             q_positions=positions, kv_positions=enc_positions,
                             causal=False, window=0, softcap=None, scale=scale)
        c = jnp.einsum("bshk,hkd->bsd", out, p["cross_attn"]["wo"])
        ckv = cross_kv
    else:
        c, ckv = attn_apply(p["cross_attn"], cfg, h, positions=positions,
                            kv_x=enc_out, kv_positions=enc_positions,
                            causal=False, window=0, ctx=ctx, use_rope=False)
    x = x + c
    h = apply_norm(p["ln3"], x, cfg)
    return x + mlp_apply(p["mlp"], cfg, h, ctx), kv, ckv


def forward(params, cfg: ModelConfig, tokens, frames,
            ctx: Optional[Ctx] = None, return_cache: bool = False):
    """tokens: (B, S); frames: (B, T_enc, d_model)."""
    b, s = tokens.shape
    enc_out = encode(params, cfg, frames, ctx)
    t_enc = enc_out.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    enc_positions = jnp.broadcast_to(jnp.arange(t_enc)[None, :], (b, t_enc))
    x = embed_apply(params["embed"], cfg, tokens, ctx)
    x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    policy = remat_policy(cfg)

    def body(x, p_layer):
        x, kv, ckv = _dec_layer(p_layer, cfg, x, enc_out, positions,
                                enc_positions, ctx)
        if return_cache:
            return x, (kv["k"], kv["v"], ckv["k"], ckv["v"])
        return x, None

    fn = body if policy is None else jax.checkpoint(body, policy=policy)
    x, ys = jax.lax.scan(fn, x, params["layers"])
    x = apply_norm(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], cfg, x, ctx)
    if return_cache:
        ks, vs, cks, cvs = ys
        cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs,
                 "pos": jnp.full((), s, jnp.int32)}
        return logits, jnp.zeros((), jnp.float32), cache
    return logits, jnp.zeros((), jnp.float32)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    k, hd, l = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
    kv = spec((l, batch, max_len, k, hd),
              ("layers", "cache_batch", "cache_seq", "kv_heads", "cache_hd"),
              "zeros")
    ckv = spec((l, batch, cfg.encoder_seq, k, hd),
               ("layers", "cache_batch", None, "kv_heads", "cache_hd"), "zeros")
    return {"k": kv, "v": kv, "cross_k": ckv, "cross_v": ckv,
            "pos": spec((), (), "zeros", dtype=jnp.int32)}


def decode_step(params, cfg: ModelConfig, cache, tokens,
                ctx: Optional[Ctx] = None):
    b = tokens.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    t_enc = cache["cross_k"].shape[2]
    enc_positions = jnp.broadcast_to(jnp.arange(t_enc)[None, :], (b, t_enc))
    x = embed_apply(params["embed"], cfg, tokens, ctx)
    x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)

    def body(x, xs):
        p_layer, ck, cv, xk, xv = xs
        x, kv, _ = _dec_layer(p_layer, cfg, x, None, positions, enc_positions,
                              ctx, cache={"k": ck, "v": cv}, cache_pos=pos,
                              cross_kv={"k": xk, "v": xv})
        return x, (kv["k"], kv["v"])

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = apply_norm(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], cfg, x, ctx)
    return logits, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "pos": pos + 1}
