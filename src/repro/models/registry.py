"""Unified model API over all families + dry-run input specs.

``build_model(cfg)`` returns a :class:`Model` facade with a uniform
signature regardless of family:

    model.forward(params, batch, ctx, return_cache=False)
    model.decode_step(params, cache, batch, ctx)
    model.param_specs() / abstract_params() / init_params(rng)
    model.cache_specs(batch, max_len)
    model.input_specs(shape)        # ShapeDtypeStructs + logical axes

``batch`` is a dict: always ``tokens``; ``frames`` for audio, ``vision`` for
vlm. RLVR train batches additionally carry ``behavior_logprobs``,
``advantages``, ``loss_mask`` (consumed by repro.rl, not the model).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig, ShapeSpec
from repro.models import common, hybrid, mamba2, transformer, vision, whisper
from repro.models.common import ParamSpec, is_spec
from repro.models.layers import Ctx

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "ssm": mamba2,
    "hybrid": hybrid,
    "audio": whisper,
    "vlm": vision,
}


class InputSpec(NamedTuple):
    sds: jax.ShapeDtypeStruct
    axes: tuple


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.mod = _FAMILY_MODULES[cfg.family]

    # ------------------------------------------------------------- params
    def param_specs(self):
        return self.mod.param_specs(self.cfg)

    def abstract_params(self):
        return common.abstract_params(self.param_specs())

    def logical_axes(self):
        return common.logical_axes(self.param_specs())

    def init_params(self, rng):
        return common.init_params(rng, self.param_specs())

    def param_count(self) -> int:
        return common.param_count(self.param_specs())

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts active)."""
        cfg = self.cfg
        total = self.param_count()
        if not cfg.num_experts:
            return total
        expert = 0
        flat = common.canonical_flat(self.param_specs())
        for key, s in flat.items():
            if "/moe/" in f"/{key}/" and any(
                w in key for w in ("wi_gate", "wi_up", "wo")
            ):
                expert += int(np.prod(s.shape))
        return total - expert + expert * cfg.experts_per_token // cfg.num_experts

    # ------------------------------------------------------------ compute
    def _extras(self, params, batch):
        if self.cfg.family == "audio":
            return (batch["frames"],)
        if self.cfg.family == "vlm":
            return (batch["vision"],)
        return ()

    def forward(self, params, batch: Dict[str, Any], ctx: Optional[Ctx] = None,
                return_cache: bool = False):
        return self.mod.forward(params, self.cfg, batch["tokens"],
                                *self._extras(params, batch), ctx=ctx,
                                return_cache=return_cache)

    def decode_step(self, params, cache, batch: Dict[str, Any],
                    ctx: Optional[Ctx] = None):
        return self.mod.decode_step(params, self.cfg, cache, batch["tokens"],
                                    ctx=ctx)

    def cache_specs(self, batch: int, max_len: int):
        return self.mod.cache_specs(self.cfg, batch, max_len)

    def abstract_cache(self, batch: int, max_len: int):
        return common.abstract_params(self.cache_specs(batch, max_len))

    def init_cache(self, rng, batch: int, max_len: int):
        return common.init_params(rng, self.cache_specs(batch, max_len))

    # ------------------------------------------------------------- inputs
    def input_specs(self, shape: ShapeSpec, rl_train: bool = True
                    ) -> Dict[str, InputSpec]:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        b = shape.global_batch
        s = shape.seq_len
        dt = common.dtype_of(cfg.dtype)
        out: Dict[str, InputSpec] = {}
        if shape.kind in ("train", "prefill"):
            out["tokens"] = InputSpec(
                jax.ShapeDtypeStruct((b, s), jnp.int32), ("batch", "seq"))
        else:  # decode: one new token against a cache of length seq_len
            out["tokens"] = InputSpec(
                jax.ShapeDtypeStruct((b, 1), jnp.int32), ("cache_batch", None))
        if cfg.family == "audio" and shape.kind != "decode":
            out["frames"] = InputSpec(
                jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dt),
                ("batch", None, "embed"))
        if cfg.family == "vlm" and shape.kind != "decode":
            out["vision"] = InputSpec(
                jax.ShapeDtypeStruct((b, cfg.vision_seq, cfg.d_model), dt),
                ("batch", None, "embed"))
        if shape.kind == "train" and rl_train:
            out["behavior_logprobs"] = InputSpec(
                jax.ShapeDtypeStruct((b, s), jnp.float32), ("batch", "seq"))
            out["advantages"] = InputSpec(
                jax.ShapeDtypeStruct((b,), jnp.float32), ("batch",))
            out["loss_mask"] = InputSpec(
                jax.ShapeDtypeStruct((b, s), jnp.float32), ("batch", "seq"))
        return out

    def dummy_batch(self, rng, shape: ShapeSpec, rl_train: bool = True):
        """Materialised random batch matching input_specs (smoke tests)."""
        specs = self.input_specs(shape, rl_train)
        keys = jax.random.split(rng, len(specs))
        batch = {}
        for key, (name, ispec) in zip(keys, specs.items()):
            sds = ispec.sds
            if np.issubdtype(sds.dtype, np.integer):
                batch[name] = jax.random.randint(
                    key, sds.shape, 0, self.cfg.vocab_size, sds.dtype)
            else:
                batch[name] = jax.random.normal(key, sds.shape, jnp.float32
                                                ).astype(sds.dtype) * 0.02
        if "behavior_logprobs" in batch:
            batch["behavior_logprobs"] = -jnp.abs(batch["behavior_logprobs"])
        if "loss_mask" in batch:
            batch["loss_mask"] = jnp.ones_like(batch["loss_mask"])
        return batch


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
