"""Logical-axis -> mesh-axis sharding rules (MaxText-style), with best-effort
divisibility resolution.

Two rule sets ship by default:

- ``RULES_TP``      — paper-faithful ZeRO-2 analogue: tensor-parallel params
  over the ``model`` axis, replicated over ``data``; optimizer moments are
  additionally sharded over ``data`` (see repro.train.optimizer).
- ``RULES_FSDP_TP`` — beyond-paper default for very large models: adds
  FSDP-style sharding of the embed dim over ``data``.

``resolve(axes, mesh, rules)`` maps a logical-axis tuple to a PartitionSpec,
dropping any assignment whose dim is not divisible by the mesh axes and any
mesh axis already used by an earlier dim (GSPMD requires distinct axes).
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]
Rules = Tuple[Tuple[str, MeshAxes], ...]

RULES_TP: Rules = (
    ("batch", ("pod", "data")),
    ("cache_batch", ("pod", "data")),
    # fallback: KV-cache head_dim takes the model axis only when kv_heads
    # could not (GQA archs with kv_heads < mesh model size). head_dim is
    # chosen over the seq dim because the decode cache write
    # (dynamic-update-slice at `pos`) would force SPMD to rematerialise a
    # seq-sharded buffer every step.
    ("cache_hd", "model"),
    ("cache_seq", None),
    # prefill OUTPUT caches: seq-sharded over model (cheap slicing of the
    # per-layer K/V stack; no decode-time DUS to worry about)
    ("cache_seq_out", "model"),
    # fallback: MoE expert-capacity / per-expert mlp dims take the model
    # axis only when the expert count could not (granite: 40 experts on a
    # 16-way axis)
    ("expert_cap", None),
    ("expert_mlp", None),
    ("seq_res", None),              # residual-stream seq (SP rules: model)
    ("vocab", "model"),
    ("embed", None),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("qkv_merged", "model"),
    ("mlp", "model"),
    ("experts", "model"),
    ("ssm_inner", "model"),
    ("ssm_heads", "model"),
    ("conv_dim", "model"),
    ("layers", None),
    ("seq", None),
    ("state", None),
    ("head_dim", None),
    ("groups", None),
)

RULES_FSDP_TP: Rules = (("embed", "data"),) + tuple(
    (k, v) for k, v in RULES_TP if k != "embed"
)

# Beyond-paper: Megatron-style sequence parallelism — the residual stream is
# sharded over the model axis between blocks, turning the per-layer f32
# activation all-reduces into bf16 reduce-scatter/all-gather pairs.
RULES_FSDP_TP_SP: Rules = (("seq_res", "model"),) + tuple(
    (k, v) for k, v in RULES_FSDP_TP if k != "seq_res"
)

# Context-parallel overrides for the long-context decode cells: the KV cache's
# sequence dim is sharded over `data` (batch=1 cannot use it).
RULES_LONG_CONTEXT: Rules = (
    ("cache_seq", "data"),
    ("cache_batch", "pod"),
    ("batch", "pod"),
) + tuple(
    (k, v)
    for k, v in RULES_TP
    if k not in ("cache_seq", "cache_batch", "batch")
)
# In the long rules cache_seq is PRIMARY (batch=1 leaves `data` free and the
# single-sequence cache must spread); it is not in FALLBACK_AXES there
# because the hybrid archs running long_500k have divisible kv heads.


def named_rules(name: str) -> Rules:
    return {
        "tp": RULES_TP,
        "fsdp_tp": RULES_FSDP_TP,
        "fsdp_tp_sp": RULES_FSDP_TP_SP,
        "long": RULES_LONG_CONTEXT,
    }[name]


def _mesh_axes_tuple(v: MeshAxes) -> Tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


# Axes only assigned in a second pass, after the primary axes had their
# chance — e.g. a decode cache's seq dim takes the model axis only when
# kv_heads could not (GQA archs whose kv count doesn't divide the mesh).
FALLBACK_AXES = {"cache_hd"}


def resolve(axes: Sequence[Optional[str]], mesh: Mesh, rules: Rules,
            shape: Optional[Sequence[int]] = None) -> P:
    """Logical axes -> PartitionSpec, best-effort divisible, two-pass
    (primary axes then fallback axes)."""
    rule_map = dict(rules)
    used: set[str] = set()
    out: list = [None] * len(axes)

    def try_assign(i, ax):
        assigned: Tuple[str, ...] = ()
        if ax is not None and ax in rule_map:
            cand = tuple(
                m for m in _mesh_axes_tuple(rule_map[ax])
                if m in mesh.axis_names and m not in used
            )
            if cand:
                total = int(np.prod([mesh.shape[m] for m in cand]))
                if shape is None or (total and shape[i] % total == 0):
                    assigned = cand
        used.update(assigned)
        if len(assigned) == 1:
            out[i] = assigned[0]
        elif assigned:
            out[i] = assigned

    for i, ax in enumerate(axes):
        if ax not in FALLBACK_AXES:
            try_assign(i, ax)
    for i, ax in enumerate(axes):
        if ax in FALLBACK_AXES:
            try_assign(i, ax)
    # trim trailing Nones for tidier specs
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_partition_specs(axes_tree, mesh: Mesh, rules: Rules, shapes_tree=None):
    """Map a logical-axes pytree (tuples as leaves) to PartitionSpecs."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x
    )
    if shapes_tree is None:
        return jax.tree.map(lambda a: resolve(a, mesh, rules), axes_tree, is_leaf=is_axes)
    return jax.tree.map(
        lambda a, s: resolve(a, mesh, rules, shape=s.shape),
        axes_tree,
        shapes_tree,
        is_leaf=is_axes,
    )


def tree_shardings(axes_tree, mesh: Mesh, rules: Rules, shapes_tree=None):
    specs = tree_partition_specs(axes_tree, mesh, rules, shapes_tree)
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def constrain(x, mesh: Mesh, rules: Rules, axes: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axes (no-op outside a mesh ctx)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, resolve(axes, mesh, rules, shape=x.shape))
        )
    except ValueError:
        return x
