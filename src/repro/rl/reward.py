"""Verifiable rewards for RLVR: exact-answer math checking.

The paper trains on a proprietary AIME-style math dataset with verifiable
answers; we substitute a synthetic arithmetic task (repro.rl.data) whose
answers are checked exactly — the same "verifier" role, fully reproducible.
"""
from __future__ import annotations

import re
from typing import List, Optional, Sequence

import numpy as np


def extract_answer(text: str) -> Optional[int]:
    """Pull the final integer answer out of a generated completion."""
    matches = re.findall(r"-?\d+", text)
    if not matches:
        return None
    return int(matches[-1])


def verify(completion: str, target: int) -> float:
    """Binary verifiable reward: 1.0 iff the final integer equals target."""
    got = extract_answer(completion)
    return 1.0 if got is not None and got == target else 0.0


def batch_rewards(completions: Sequence[str], targets: Sequence[int]) -> np.ndarray:
    return np.array([verify(c, t) for c, t in zip(completions, targets)],
                    dtype=np.float32)


class ToolStallSimulator:
    """Models agentic tool-call stalls (paper §2: long-tailed rollouts).

    Draws per-sample tool latencies from a lognormal so the rollout phase
    exhibits the paper's characteristic long tail. Used by the cluster
    simulator and benchmarks; deterministic under a seed.
    """

    def __init__(self, p_tool: float = 0.3, mu: float = 0.0, sigma: float = 1.0,
                 scale: float = 2.0, seed: int = 0):
        self.p_tool = p_tool
        self.mu, self.sigma, self.scale = mu, sigma, scale
        self.rng = np.random.default_rng(seed)

    def sample_stalls(self, n: int) -> np.ndarray:
        has_tool = self.rng.random(n) < self.p_tool
        stalls = self.rng.lognormal(self.mu, self.sigma, n) * self.scale
        return np.where(has_tool, stalls, 0.0).astype(np.float32)
