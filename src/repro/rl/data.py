"""Synthetic verifiable-math data pipeline.

Generates arithmetic reasoning prompts ("17 + 4 * 3 = ?") with exact integer
answers, a character-level tokenizer confined to the low end of any model's
vocab, and packed/padded batches. Deterministic under seeds; infinite
iterator semantics for training.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np

# char-level tokenizer: reserve 0=pad, 1=bos, 2=eos
_CHARS = "0123456789+-*() =?"
PAD, BOS, EOS = 0, 1, 2
_OFFSET = 3
VOCAB_MIN = _OFFSET + len(_CHARS)


def encode(text: str) -> List[int]:
    return [BOS] + [_OFFSET + _CHARS.index(c) for c in text if c in _CHARS]


def decode(ids) -> str:
    out = []
    for i in ids:
        i = int(i)
        if i == EOS:
            break
        if i >= _OFFSET and i - _OFFSET < len(_CHARS):
            out.append(_CHARS[i - _OFFSET])
    return "".join(out)


@dataclasses.dataclass(frozen=True)
class Problem:
    prompt: str
    answer: int
    difficulty: int       # 1..5, mirroring the paper's 5 difficulty buckets


def sample_problem(rng: np.random.Generator, difficulty: int) -> Problem:
    """Difficulty scales the number of operands (paper: 5 AIME-like tiers)."""
    n_ops = difficulty + 1
    terms = rng.integers(1, 10 ** min(difficulty, 3), size=n_ops)
    ops = rng.choice(["+", "-", "*"], size=n_ops - 1)
    expr = str(terms[0])
    for op, t in zip(ops, terms[1:]):
        expr += f" {op} {t}"
    return Problem(prompt=f"{expr} = ?", answer=int(eval(expr)),
                   difficulty=difficulty)


class MathDataset:
    """~45k-sample synthetic dataset across 5 difficulties (paper §6.1)."""

    def __init__(self, size: int = 45_000, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.size = size

    def sample(self, n: int) -> List[Problem]:
        return [sample_problem(self.rng, int(self.rng.integers(1, 6)))
                for _ in range(n)]

    def batches(self, batch_size: int, seq_len: int,
                group_size: int = 1) -> Iterator[Tuple[np.ndarray, List[Problem]]]:
        """Yields (tokens (B, S), problems). Each prompt repeated group_size
        times (GRPO grouping)."""
        while True:
            probs = self.sample(batch_size // group_size)
            probs = [p for p in probs for _ in range(group_size)]
            tokens = np.full((batch_size, seq_len), PAD, dtype=np.int32)
            for i, p in enumerate(probs):
                ids = encode(p.prompt)[:seq_len]
                tokens[i, :len(ids)] = ids
            yield tokens, probs


def pack_rollout_batch(prompt_tokens: np.ndarray, completions: np.ndarray,
                       logprobs: np.ndarray, rewards: np.ndarray,
                       group_size: int, seq_len: int):
    """Assemble the GRPO train batch from rollout artifacts.

    prompt_tokens: (B, P); completions: (B, C); logprobs: (B, C) behavior
    logprobs of completion tokens; rewards: (B,).
    """
    from repro.rl.grpo import group_relative_advantages
    import jax.numpy as jnp

    b, p_len = prompt_tokens.shape
    c_len = completions.shape[1]
    tokens = np.full((b, seq_len), PAD, dtype=np.int32)
    behave = np.zeros((b, seq_len), dtype=np.float32)
    mask = np.zeros((b, seq_len), dtype=np.float32)
    n = min(seq_len, p_len + c_len)
    tokens[:, :p_len] = prompt_tokens
    tokens[:, p_len:n] = completions[:, :n - p_len]
    behave[:, p_len:n] = logprobs[:, :n - p_len]
    mask[:, p_len:n] = 1.0
    adv = np.asarray(group_relative_advantages(jnp.asarray(rewards), group_size))
    return {
        "tokens": tokens,
        "behavior_logprobs": behave,
        "advantages": adv,
        "loss_mask": mask,
    }
