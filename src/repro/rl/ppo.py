"""PPO objective (actor + value head) — the paper's baseline algorithm family.

PlexRL schedules PPO's extra model roles (critic, reference) as additional
WPG deployments; this module provides the losses so multi-role jobs can be
expressed against the service API.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Ctx
from repro.models.registry import Model
from repro.rl.grpo import token_logprobs


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    clip_eps: float = 0.2
    value_coef: float = 0.5
    entropy_coef: float = 0.0
    gae_lambda: float = 0.95
    gamma: float = 1.0


def gae_advantages(rewards, values, mask, cfg: PPOConfig):
    """Generalized advantage estimation over token sequences.

    rewards/values/mask: (B, T). Rewards are typically terminal-only for
    RLVR (verifiable reward at the last response token).
    """
    b, t = rewards.shape

    def step(carry, xs):
        r, v, v_next, m = xs
        delta = r + cfg.gamma * v_next * m - v
        adv = delta + cfg.gamma * cfg.gae_lambda * m * carry
        return adv, adv

    v_next = jnp.concatenate([values[:, 1:], jnp.zeros((b, 1))], axis=1)
    xs = (rewards.T, values.T, v_next.T, mask.T)
    xs = jax.tree.map(lambda a: a[::-1], xs)
    _, advs = jax.lax.scan(step, jnp.zeros((b,)), xs)
    return advs[::-1].T


def ppo_loss(params, model: Model, batch: Dict[str, Any], cfg: PPOConfig,
             ctx: Optional[Ctx] = None):
    """batch: tokens, behavior_logprobs, advantages (B, S) token-level,
    value_targets (B, S), loss_mask."""
    logits, aux = model.forward(params, batch, ctx)[:2]
    logp = token_logprobs(logits, batch["tokens"])
    behave = batch["behavior_logprobs"][:, 1:]
    mask = batch["loss_mask"][:, 1:]
    adv = batch["advantages"][:, 1:] if batch["advantages"].ndim == 2 \
        else batch["advantages"][:, None]

    ratio = jnp.exp(logp - behave)
    clipped = jnp.clip(ratio, 1.0 - cfg.clip_eps, 1.0 + cfg.clip_eps)
    denom = jnp.clip(mask.sum(), 1.0)
    pg = -(jnp.minimum(ratio * adv, clipped * adv) * mask).sum() / denom

    loss = pg + 0.01 * aux
    if cfg.entropy_coef:
        p = jax.nn.softmax(logits[:, :-1].astype(jnp.float32), -1)
        ent = -(p * jnp.log(p + 1e-9)).sum(-1)
        loss = loss - cfg.entropy_coef * (ent * mask).sum() / denom
    return loss, {"pg_loss": pg}


def value_loss(values, targets, old_values, mask, cfg: PPOConfig):
    """Clipped value loss for a critic deployment."""
    v_clip = old_values + jnp.clip(values - old_values, -cfg.clip_eps, cfg.clip_eps)
    l1 = jnp.square(values - targets)
    l2 = jnp.square(v_clip - targets)
    denom = jnp.clip(mask.sum(), 1.0)
    return cfg.value_coef * (jnp.maximum(l1, l2) * mask).sum() / denom
