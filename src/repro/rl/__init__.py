"""RLVR algorithm substrate: rollout, GRPO/PPO objectives, verifiable
rewards, data pipeline."""
