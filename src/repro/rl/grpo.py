"""GRPO (group-relative policy optimization) objective + step builders.

These step functions are the *primitives* PlexRL schedules (paper Tab. 2):
``compute_log_prob`` (forward), ``update_actor`` (forward+backward+step) and
the serving-side prefill/decode steps. Each builder closes over a model and
sharding context and returns a pure function suitable for ``jax.jit`` with
explicit in/out shardings.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Ctx
from repro.models.registry import Model
from repro.train import optimizer as opt
from repro.train.train_state import TrainState


@dataclasses.dataclass(frozen=True)
class GRPOConfig:
    clip_eps: float = 0.2
    kl_coef: float = 0.0        # optional KL vs behavior policy
    aux_coef: float = 0.01      # MoE load-balance weight
    group_size: int = 8         # rollouts per prompt


def token_logprobs(logits, tokens):
    """logits: (B, S, V); tokens: (B, S). Next-token logprobs (B, S-1)."""
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)[..., 0]


def group_relative_advantages(rewards, group_size: int, eps: float = 1e-6):
    """rewards: (B,) with B = n_prompts * group_size (grouped contiguously)."""
    b = rewards.shape[0]
    g = rewards.reshape(b // group_size, group_size)
    mean = g.mean(axis=1, keepdims=True)
    std = g.std(axis=1, keepdims=True)
    return ((g - mean) / (std + eps)).reshape(b)


def grpo_loss(params, model: Model, batch: Dict[str, Any], cfg: GRPOConfig,
              ctx: Optional[Ctx] = None):
    """Clipped importance-sampling surrogate with group-relative advantages.

    batch: tokens (B,S), behavior_logprobs (B,S), advantages (B,),
    loss_mask (B,S) — mask selects response tokens.
    """
    logits, aux = model.forward(params, batch, ctx)[:2]
    logp = token_logprobs(logits, batch["tokens"])           # (B, S-1)
    behave = batch["behavior_logprobs"][:, 1:]
    mask = batch["loss_mask"][:, 1:]
    adv = batch["advantages"][:, None]

    log_ratio = logp - behave
    ratio = jnp.exp(log_ratio)
    clipped = jnp.clip(ratio, 1.0 - cfg.clip_eps, 1.0 + cfg.clip_eps)
    surrogate = jnp.minimum(ratio * adv, clipped * adv)
    denom = jnp.clip(mask.sum(), 1.0)
    pg_loss = -(surrogate * mask).sum() / denom
    # k3 KL estimator (Schulman): unbiased, positive. log_ratio is clamped
    # so an off-policy outlier cannot overflow exp() into inf (which would
    # NaN the loss even at kl_coef == 0 via 0 * inf).
    lr_c = jnp.clip(log_ratio, -20.0, 20.0)
    kl = ((jnp.exp(-lr_c) - 1.0 + lr_c) * mask).sum() / denom
    loss = pg_loss + cfg.aux_coef * aux
    if cfg.kl_coef:
        loss = loss + cfg.kl_coef * kl
    metrics = {
        "pg_loss": pg_loss,
        "kl": kl,
        "aux": aux,
        "ratio_mean": (ratio * mask).sum() / denom,
        "entropy_proxy": -(logp * mask).sum() / denom,
    }
    return loss, metrics


# -------------------------------------------------------------- step fns

def compute_grads(params, model: Model, batch, grpo_cfg: GRPOConfig,
                  ctx: Optional[Ctx], grad_accum: int = 1):
    """Grads of grpo_loss, with optional microbatched gradient accumulation
    (activation-memory control for large train cells). Accumulates in f32."""
    if grad_accum <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            grpo_loss, has_aux=True)(params, model, batch, grpo_cfg, ctx)
        return grads, dict(metrics, loss=loss)

    def split(a):
        return a.reshape((grad_accum, a.shape[0] // grad_accum) + a.shape[1:])

    micro = jax.tree.map(split, batch)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def mb_step(acc, mbatch):
        (loss, metrics), grads = jax.value_and_grad(
            grpo_loss, has_aux=True)(params, model, mbatch, grpo_cfg, ctx)
        acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
        return acc, dict(metrics, loss=loss)

    grads, metrics = jax.lax.scan(mb_step, zeros, micro)
    grads = jax.tree.map(lambda g, p: (g / grad_accum).astype(p.dtype),
                         grads, params)
    return grads, jax.tree.map(lambda m: m.mean(), metrics)


def make_update_actor(model: Model, grpo: GRPOConfig = GRPOConfig(),
                      adamw: opt.AdamWConfig = opt.AdamWConfig(),
                      ctx: Optional[Ctx] = None, grad_accum: int = 1):
    """``update_actor`` primitive: fwd+bwd+AdamW. (state, batch) -> (state, metrics)."""

    def step(state: TrainState, batch):
        grads, metrics = compute_grads(state.params, model, batch, grpo, ctx,
                                       grad_accum)
        new_params, new_opt, opt_metrics = opt.update(
            grads, state.opt_state, state.params, adamw)
        metrics = dict(metrics, **opt_metrics)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step


def make_compute_log_prob(model: Model, ctx: Optional[Ctx] = None):
    """``compute_log_prob`` primitive (paper Tab. 2): forward-only logprobs."""

    def step(params, batch):
        logits, _ = model.forward(params, batch, ctx)[:2]
        return token_logprobs(logits, batch["tokens"])

    return step


def make_prefill(model: Model, ctx: Optional[Ctx] = None,
                 cache_len: Optional[int] = None):
    def step(params, batch):
        logits, _, cache = model.forward(params, batch, ctx, return_cache=True)
        return logits[:, -1:], cache

    return step


def make_decode(model: Model, ctx: Optional[Ctx] = None):
    def step(params, cache, batch):
        logits, new_cache = model.decode_step(params, cache, batch, ctx)
        return logits, new_cache

    return step
