"""Autoregressive rollout with KV cache: prefill + decode loop.

Used by the end-to-end examples and by the PlexRL ``generate`` service
primitive. Sampling is temperature-based with greedy as temperature->0;
returns behavior logprobs for importance-sampled objectives.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Ctx
from repro.models.registry import Model


@dataclasses.dataclass(frozen=True)
class RolloutConfig:
    max_new_tokens: int = 32
    temperature: float = 1.0
    greedy: bool = False
    eos_id: int = 2


def _pad_cache(cache, extra: int):
    """Grow self-attn cache seq dims by `extra` slots (zero-filled)."""
    out = {}
    for k, v in cache.items():
        if k in ("k", "v", "attn_k", "attn_v") and hasattr(v, "ndim") and v.ndim >= 4:
            ax = v.ndim - 3
            pad = [(0, 0)] * v.ndim
            pad[ax] = (0, extra)
            out[k] = jnp.pad(v, pad)
        else:
            out[k] = v
    return out


def rollout(model: Model, params, prompt_tokens, rng,
            cfg: RolloutConfig = RolloutConfig(),
            ctx: Optional[Ctx] = None,
            extra_inputs: Optional[Dict[str, Any]] = None
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Generate completions. prompt_tokens: (B, P) int32.

    Returns (completions (B, N), logprobs (B, N), done_mask (B, N)).
    """
    batch = {"tokens": prompt_tokens, **(extra_inputs or {})}
    last_logits, _, cache = model.forward(params, batch, ctx, return_cache=True)
    last_logits = last_logits[:, -1]
    cache = _pad_cache(cache, cfg.max_new_tokens)

    def sample(logits, key):
        logits = logits.astype(jnp.float32)
        if cfg.greedy or cfg.temperature <= 0:
            tok = jnp.argmax(logits, axis=-1)
        else:
            tok = jax.random.categorical(key, logits / cfg.temperature, axis=-1)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return tok, jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]

    def step(carry, key):
        logits, cache, alive = carry
        tok, logp = sample(logits, key)
        tok = jnp.where(alive, tok, cfg.eos_id)
        new_logits, new_cache = model.decode_step(params, cache,
                                                  {"tokens": tok[:, None]}, ctx)
        alive = alive & (tok != cfg.eos_id)
        return (new_logits[:, -1], new_cache, alive), (tok, logp, alive)

    keys = jax.random.split(rng, cfg.max_new_tokens)
    b = prompt_tokens.shape[0]
    init = (last_logits, cache, jnp.ones((b,), bool))
    _, (toks, logps, alive) = jax.lax.scan(step, init, keys)
    return toks.T, logps.T, alive.T
