"""Cluster control plane: online profiling, automatic placement, capacity
adjustment (paper §4.3-§4.4).

The :class:`PlacementDirector` closes the loop between three previously
disconnected subsystems — the trace-fitting placement machinery
(``scheduler/placement.py``, until now reachable only from the offline
simulator), the live serve-mode dispatch plane (``router.py``), and state
migration (``state_manager.py``) — so live jobs are *placed* instead of
pinned to a hard-coded group:

- **Online profiler.** The executor exports a per-job stream of
  :class:`~repro.core.scheduler.executor.PhaseRecord` completions; the
  director folds them into per-cycle phase durations (rollout /
  compute_log_prob / update_actor / sync_weight) and, once a clean cycle
  exists, into the same :class:`~repro.core.scheduler.placement.JobTrace`
  the simulator consumes (§4.3.2 cold-start profiling).
- **Cold → warm lifecycle.** A job arriving with no trace is placed on a
  *dedicated* profiling group (``place_cold``; spawning one if none is
  free). After ``cold_cycles`` clean cycles it is re-fitted with
  ``place_warm`` micro-shift search — pack-first: groups already hosting
  warm jobs are tried before empty ones, so profiling groups drain and can
  be retired — and, if the fit lands elsewhere, *migrated* through
  ``Router.reassign_job`` (hold → quiesce → StateManager.migrate → rehome,
  §4.5.3) without losing billing continuity.
- **Capacity adjuster** (§4.4). Queue-depth / occupancy telemetry from
  ``Router.group_telemetry`` drives group spawn (``Router.ensure_group`` +
  the serve plane's dynamic per-group worker spawn) and retire
  (``Router.retire_group``), bounded by ``min_groups`` / ``max_groups``.

Everything is event-driven from job arrivals and step completions (no
background timer thread), so the whole decision sequence is deterministic
under a :class:`~repro.core.scheduler.executor.VirtualClock` and replayable
bit-identically; ``events`` is the append-only decision log tests and
operators read.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler.executor import TaskExecutor  # noqa: F401 (docs)
from repro.core.scheduler.intervals import IntervalSet
from repro.core.scheduler.placement import (JobTrace, NodeGroup, Placed,
                                            PlacementConfig, PlacementPolicy)

# Executor op value -> profiled phase (paper Table 2 cycle anatomy).
PHASE_OF_OP = {
    "generate": "rollout",
    "forward": "compute_log_prob",
    "update_actor": "update_actor",
    "forward_backward": "update_actor",
    "optim_step": "update_actor",
    "sync_weights": "sync_weight",
}
TRAIN_PHASES = ("compute_log_prob", "update_actor", "sync_weight")


@dataclasses.dataclass(frozen=True)
class DirectorConfig:
    horizon: float = 600.0          # rolling planning window (seconds)
    max_cycles: int = 64            # cap on pre-allocated warm cycles
    cold_cycles: int = 1            # clean cycles before the warm re-fit
    warmup_cycles: int = 1          # leading cycles DROPPED from the fold
    #   (the first cycle carries JIT compilation / cache warming and would
    #   poison the steady-state trace; set 0 for exact-replay tests)
    cold_reserve_s: float = 60.0    # dedicated-group reservation length
    group_nodes: int = 1            # node count of spawned groups
    min_groups: int = 1
    max_groups: int = 32
    spawn_queue_depth: int = 8      # per-group QUEUED depth triggering spawn
    placement: Optional[PlacementConfig] = None


@dataclasses.dataclass
class _JobState:
    job_id: str
    nodes: int
    phase: str = "cold"             # "cold" (profiling) | "warm" (fitted)
    group_id: int = -1
    seq_cursor: int = 0             # last consumed PhaseRecord.seq
    open_cycle: Dict[str, float] = dataclasses.field(default_factory=dict)
    cycles: List[Dict[str, float]] = dataclasses.field(default_factory=list)
    trace: Optional[JobTrace] = None


def trace_from_cycles(cycles: Sequence[Dict[str, float]],
                      nodes: int = 1) -> Optional[JobTrace]:
    """Fold per-cycle phase durations into a JobTrace (mean per phase, the
    same anatomy as ``traces.Profiler.trace``: training segments
    back-to-back after the rollout gap)."""
    mean: Dict[str, float] = {}
    for phase in ("rollout",) + TRAIN_PHASES:
        vals = [c[phase] for c in cycles if phase in c]
        if vals:
            mean[phase] = sum(vals) / len(vals)
    if "rollout" not in mean or "update_actor" not in mean:
        return None
    t = mean["rollout"]
    segs = []
    for p in TRAIN_PHASES:
        if p in mean:
            segs.append((t, mean[p]))
            t += mean[p]
    if t <= 1e-9:
        return None                 # degenerate (clock never advanced)
    return JobTrace(period=t, segments=tuple(segs), nodes=nodes)


class PlacementDirector:
    """Live placement + capacity control over a Router's node groups.

    Thread-safe: client threads call :meth:`assign` / :meth:`on_job_step` /
    :meth:`on_job_removed` concurrently; one re-entrant lock serializes
    decisions (the underlying Router/executor operations take their own
    locks)."""

    def __init__(self, router, cfg: Optional[DirectorConfig] = None,
                 initial_groups: Sequence[int] = ()):
        self.router = router
        self.cfg = cfg or DirectorConfig()
        pcfg = self.cfg.placement or PlacementConfig(horizon=self.cfg.horizon)
        self.policy = PlacementPolicy([], pcfg)
        self._lock = threading.RLock()
        self._jobs: Dict[str, _JobState] = {}
        self.events: List[dict] = []
        for g in initial_groups:
            self.register_group(g)

    # Decision-log retention: decisions are per job-lifecycle (not
    # per-step), but a long-lived plane with heavy job churn still accretes
    # — keep the most recent window.
    MAX_EVENTS = 4096

    # ------------------------------------------------------------- helpers
    def _log(self, event: str, **kw):
        self.events.append(dict(event=event, **kw))
        if len(self.events) > self.MAX_EVENTS:
            del self.events[:len(self.events) - self.MAX_EVENTS]

    def job_state(self, job_id: str) -> Optional[_JobState]:
        with self._lock:
            return self._jobs.get(job_id)

    def profiled_trace(self, job_id: str) -> Optional[JobTrace]:
        with self._lock:
            js = self._jobs.get(job_id)
            return js.trace if js else None

    def register_group(self, group_id: int):
        """Track an externally created group (e.g. the cluster's seed
        groups) in the placement state."""
        with self._lock:
            if self.policy.group(group_id) is not None:
                return
            now = self.router.now()
            self.policy.add_group(NodeGroup(
                group_id, self.cfg.group_nodes,
                IntervalSet([(now, now + self.cfg.horizon)]),
                horizon_end=now + self.cfg.horizon))

    def _spawn_group(self, now: float, reason: str) -> int:
        known = set(self.router.known_groups()) | \
            {g.group_id for g in self.policy.groups}
        gid = max(known, default=-1) + 1
        self.router.ensure_group(gid)
        self.policy.add_group(NodeGroup(
            gid, self.cfg.group_nodes,
            IntervalSet([(now, now + self.cfg.horizon)]),
            horizon_end=now + self.cfg.horizon))
        self._log("spawn_group", group=gid, reason=reason, t=now)
        return gid

    def _advance(self, now: float):
        """Roll every group's planning window: retire capacity behind
        ``now``, project resident jobs into the extended horizon."""
        for g in self.policy.groups:
            g.advance_to(now)
            g.extend_to(now + self.cfg.horizon)

    # ------------------------------------------------------------- arrival
    def assign(self, job_id: str, nodes: int = 1,
               expected_duration: Optional[float] = None) -> int:
        """Place an arriving (trace-less) job: a dedicated profiling group,
        spawning one if none is free (§4.3.2 cold start). Returns the
        group_id the caller should deploy onto."""
        with self._lock:
            if job_id in self._jobs:
                return self._jobs[job_id].group_id
            now = self.router.now()
            self._advance(now)
            dur = min(expected_duration or self.cfg.cold_reserve_s,
                      self.cfg.horizon * 0.5)
            placed = self.policy.place_cold(job_id, nodes, dur, origin=now)
            if placed is None and len(self.policy.groups) < self.cfg.max_groups:
                self._spawn_group(now, reason=f"cold:{job_id}")
                placed = self.policy.place_cold(job_id, nodes, dur,
                                                origin=now)
            if placed is None:
                # fleet at max size and no clean group: profile on the group
                # with the fewest residents (profiling is noisier, not wrong)
                g = min(self.policy.groups,
                        key=lambda g: (len(g.resident), g.group_id))
                gid = g.group_id
                self._log("cold_overflow", job=job_id, group=gid, t=now)
            else:
                gid = placed.group_id
                self._log("cold_place", job=job_id, group=gid, t=now)
            self._jobs[job_id] = _JobState(job_id, nodes, "cold", gid)
            return gid

    # ---------------------------------------------------------- telemetry
    def _fold(self, js: _JobState):
        """Consume the job's new PhaseRecords: carve live completions out of
        group free windows and accumulate per-cycle phase durations."""
        recs = self.router.executor.phase_records_since(js.job_id,
                                                        js.seq_cursor)
        for r in recs:
            js.seq_cursor = max(js.seq_cursor, r.seq)
            g = self.policy.group(r.group_id)
            if g is not None:
                g.note_busy(r.t_started, r.t_finished)
            phase = PHASE_OF_OP.get(r.op)
            if phase is None:
                continue
            if (phase == "rollout" and "rollout" in js.open_cycle
                    and "update_actor" in js.open_cycle):
                js.cycles.append(js.open_cycle)   # next cycle's rollout
                js.open_cycle = {}
            js.open_cycle[phase] = js.open_cycle.get(phase, 0.0) + r.duration
        # a completed step means the open cycle (if whole) is closed
        if "rollout" in js.open_cycle and "update_actor" in js.open_cycle:
            js.cycles.append(js.open_cycle)
            js.open_cycle = {}
        # bounded history: promotion reads warmup+cold cycles; keep a small
        # tail beyond that (future drift re-profiling) so a week-long warm
        # job does not accumulate one dict per step forever
        keep = self.cfg.warmup_cycles + self.cfg.cold_cycles + 8
        if len(js.cycles) > keep and js.phase != "cold":
            del js.cycles[:len(js.cycles) - keep]

    # ----------------------------------------------------------- lifecycle
    def on_job_step(self, job_id: str):
        """Per-step hook (event-driven; deterministic under VirtualClock):
        fold telemetry, promote cold→warm once profiled, adjust capacity.

        The blocking half of a promotion — the migration's admission-hold
        drain — runs OUTSIDE the director lock, so one job's migration
        never stalls other jobs' step hooks or new-job placement; the
        placement state itself is already updated before the lock drops."""
        migration = None
        with self._lock:
            js = self._jobs.get(job_id)
            if js is None:
                return
            now = self.router.now()
            self._advance(now)
            self._fold(js)
            if (js.phase == "cold"
                    and len(js.cycles) >= (self.cfg.warmup_cycles
                                           + self.cfg.cold_cycles)):
                migration = self._promote(js, now)
            if migration is None:
                self._adjust_capacity(now)
                return
        src, dst = migration
        try:
            moved = self.router.reassign_job(job_id, dst)  # blocking drain
        except Exception as e:  # noqa: BLE001 - migration is an optimization
            # e.g. a quiesce timeout behind a long-running op: the job still
            # runs on src. Roll the placement state back (free the dst
            # reservation, re-pin src) and keep driving the job — a failed
            # consolidation move must never kill a healthy job.
            with self._lock:
                now = self.router.now()
                js = self._jobs.get(job_id)
                self.policy.remove(job_id)
                if js is not None:
                    js.group_id = src
                    if js.trace is not None:
                        self.policy.place_warm(job_id, js.trace,
                                               origin=now, groups=[src])
                self._log("migrate_failed", job=job_id, src=src, dst=dst,
                          error=str(e), t=now)
            return
        with self._lock:
            now = self.router.now()
            self._log("migrate", job=job_id, src=src, dst=dst,
                      bytes=moved, t=now)
            self._adjust_capacity(now)   # retires the drained group

    def _promote(self, js: _JobState,
                 now: float) -> Optional[Tuple[int, int]]:
        """Cold→warm: build the profiled trace, micro-shift fit it
        (pack-first). Returns the (src, dst) migration the caller must
        realize when the fit lands on another group, else None."""
        trace = trace_from_cycles(js.cycles[self.cfg.warmup_cycles:],
                                  js.nodes)
        if trace is None:
            return None
        self.policy.remove(js.job_id)      # release the cold reservation
        placed = self._fit_warm(js.job_id, trace, now)
        js.trace = trace
        js.phase = "warm"
        if placed is None:
            self._log("unplaceable", job=js.job_id, group=js.group_id,
                      period=trace.period, t=now)
            return None
        old_gid = js.group_id
        js.group_id = placed.group_id
        self._log("warm_place", job=js.job_id, group=placed.group_id,
                  shift=placed.shift, period=trace.period,
                  duty=trace.duty(), t=now)
        if placed.group_id != old_gid:
            return (old_gid, placed.group_id)
        return None

    def _fit_warm(self, job_id: str, trace: JobTrace,
                  now: float) -> Optional[Placed]:
        n_cycles = max(1, min(self.cfg.max_cycles,
                              int(self.cfg.horizon
                                  // max(trace.period, 1e-9))))
        cold_groups = {s.group_id for s in self._jobs.values()
                       if s.phase == "cold" and s.job_id != job_id}
        # pack-first: consolidate onto groups already hosting warm jobs so
        # drained profiling groups become retirable (repacking density,
        # §4.3.2) — then the remaining (resident-free) non-profiling
        # groups, then a fresh spawn
        tiers = [
            [g.group_id for g in self.policy.groups
             if g.resident and g.group_id not in cold_groups],
            [g.group_id for g in self.policy.groups
             if not g.resident and g.group_id not in cold_groups],
        ]
        for tier in tiers:
            if not tier:
                continue
            placed = self.policy.place_warm(job_id, trace,
                                            n_cycles=n_cycles,
                                            origin=now, groups=tier)
            if placed is not None:
                return placed
        if len(self.policy.groups) < self.cfg.max_groups:
            gid = self._spawn_group(now, reason=f"warm:{job_id}")
            return self.policy.place_warm(job_id, trace, n_cycles=n_cycles,
                                          origin=now, groups=[gid])
        return None

    def on_job_removed(self, job_id: str):
        with self._lock:
            js = self._jobs.pop(job_id, None)
            self.policy.remove(job_id)
            self.router.executor.drop_job_telemetry(job_id)
            now = self.router.now()
            if js is not None:
                self._log("job_removed", job=job_id, t=now)
            self._retire_idle(now)

    # ------------------------------------------------- capacity adjustment
    def poll(self):
        """Explicit capacity-adjustment tick (the event hooks call this
        implicitly; exposed for external control loops)."""
        with self._lock:
            now = self.router.now()
            self._advance(now)
            self._adjust_capacity(now)

    def _adjust_capacity(self, now: float):
        telem = self.router.group_telemetry()
        deep = sorted(g for g, t in telem.items()
                      if t["queue_depth"] >= self.cfg.spawn_queue_depth)
        if deep:
            # queue pressure: keep (or create) one spare group rather than
            # retiring — the next warm fit / repack can expand onto it
            if len(self.policy.groups) < self.cfg.max_groups:
                spare = [g for g in self.policy.groups
                         if not g.resident and not telem.get(
                             g.group_id, {}).get("deployments")]
                if not spare:
                    self._spawn_group(now, reason=f"queue_depth:g{deep[0]}")
        else:
            self._retire_idle(now, telem)

    def _retire_idle(self, now: float, telem: Optional[Dict] = None):
        """Retire groups with no placed jobs, no deployments, and no queued
        or running work (down to ``min_groups``)."""
        if telem is None:
            telem = self.router.group_telemetry()
        for gid in sorted((g.group_id for g in self.policy.groups),
                          reverse=True):
            if len(self.policy.groups) <= self.cfg.min_groups:
                break
            g = self.policy.group(gid)
            if g is None or g.resident:
                continue
            t = telem.get(gid)
            if t and (t["deployments"] or t["queue_depth"] or t["running"]):
                continue
            try:
                self.router.retire_group(gid)
            except RuntimeError:
                continue               # raced an attach: leave it alone
            self.policy.remove_group(gid)
            self._log("retire_group", group=gid, t=now)
