"""Model State Manager: per-node authority over tensor residency (paper §4.5).

Three-tier hierarchy adapted to this runtime:

    DEVICE  — accelerator memory (jax arrays, possibly sharded)
    HOST    — canonicalised numpy buffers ("pinned host memory")
    DISK    — .npz spill files ("NVMe", via repro.train.checkpoint shards)

Key mechanisms reproduced:
- §4.5.1 hierarchical residency with scheduler-directed prefetch/offload and
  capacity-aware eviction (device -> host -> disk).
- §4.5.2 canonicalised offloaded state: tensors are indexed by logical key
  (repro.models.common.canonical_flat), deduplicating data-parallel replicas
  and decoupling storage from process layout.
- §4.5.3 materialisation (checkpoints from managed state), weight sync with
  on-the-fly zero-redundancy resharding (each target fetches only the slices
  its layout needs), cross-node migration.
- §4.5.4 off-critical-path work: a host-resident AdamW step (the CPU
  optimizer of ZeRO-offload) over canonical host state.

All transfer timings are recorded; HRRS pulls its C_setup estimates from
``load_time_estimate`` / ``offload_time_estimate``.
"""
from __future__ import annotations

import dataclasses
import enum
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.models import common


class Tier(enum.IntEnum):
    DEVICE = 0
    HOST = 1
    DISK = 2


@dataclasses.dataclass
class Entry:
    key: str                         # canonical logical key (job-scoped)
    tier: Tier
    nbytes: int
    ref: Any = None                  # jax array (DEVICE) / np array (HOST)
    path: Optional[str] = None       # DISK shard path
    version: int = 0
    refcount: int = 1                # dedup count across logical replicas
    last_touch: float = 0.0
    is_bf16: bool = False            # DISK tier stores bf16 as uint16 views
    spec: Any = None                 # PartitionSpec the DEVICE copy had, so
    #   prefetch/migrate can rebuild the layout on THIS node's mesh slice
    #   (or reshard onto a different slice's mesh — §4.5.3)


def _nbytes(x) -> int:
    return int(np.prod(x.shape)) * x.dtype.itemsize


class StateManager:
    """One instance per node. Owns every byte of managed model state."""

    def __init__(self, node_id: str = "node0",
                 device_capacity: float = float("inf"),
                 host_capacity: float = float("inf"),
                 disk_dir: Optional[str] = None,
                 clock: Callable[[], float] = time.monotonic,
                 mesh_slice=None):
        self.node_id = node_id
        self.device_capacity = device_capacity
        self.host_capacity = host_capacity
        self.disk_dir = disk_dir or os.path.join("/tmp", f"plexrl_{node_id}")
        self.clock = clock
        # the node group's MeshSlice (launch/mesh.py): DEVICE-tier entries
        # live on these devices; None = wherever jax defaults (legacy view)
        self.mesh_slice = mesh_slice
        self.entries: Dict[str, Entry] = {}
        self.transfer_log: List[Tuple[str, str, int, float]] = []
        self._bw_estimate: Dict[str, float] = {}   # bytes/s per direction
        self.last_migrate: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------ helpers
    def _tier_bytes(self, tier: Tier) -> int:
        return sum(e.nbytes for e in self.entries.values() if e.tier == tier)

    def usage(self) -> Dict[str, int]:
        return {t.name: self._tier_bytes(t) for t in Tier}

    def _record(self, direction: str, nbytes: int, dt: float):
        self.transfer_log.append((direction, "", nbytes, dt))
        if dt > 0 and nbytes > 0:
            bw = nbytes / dt
            old = self._bw_estimate.get(direction)
            self._bw_estimate[direction] = bw if old is None else 0.7 * old + 0.3 * bw

    def _estimate(self, direction: str, nbytes: int, default_bw: float) -> float:
        bw = self._bw_estimate.get(direction, default_bw)
        return nbytes / max(bw, 1.0)

    @staticmethod
    def _leaf_spec(leaf):
        """The PartitionSpec of a device-resident jax array (None for host
        numpy / unsharded arrays)."""
        shd = getattr(leaf, "sharding", None)
        return shd.spec if isinstance(shd, NamedSharding) else None

    def _to_device(self, arr, spec=None):
        """Place a host array onto THIS node's mesh slice, restoring
        ``spec`` when it still fits the slice's mesh; falls back to a
        replicated put on the slice (or the default device with no slice)."""
        if self.mesh_slice is not None:
            if spec is not None:
                try:
                    return jax.device_put(
                        arr, NamedSharding(self.mesh_slice.mesh, spec))
                except Exception:  # noqa: BLE001 - spec may not divide here
                    pass
            # replicate across the slice: compatible under jit with leaves
            # that DID reshard onto the slice's mesh
            return jax.device_put(
                arr, NamedSharding(self.mesh_slice.mesh, PartitionSpec()))
        return jnp.asarray(arr)

    # ----------------------------------------------------------- register
    def register(self, job_id: str, tree, tier: Tier = Tier.DEVICE,
                 prefix: str = "params") -> List[str]:
        """Adopt a pytree of tensors under canonical keys. Re-registering an
        existing key with the same version only bumps the refcount (§4.5.2
        dedup of data-parallel replicas)."""
        flat = common.canonical_flat(tree, is_leaf=lambda x: hasattr(x, "shape"))
        keys = []
        for sub, leaf in flat.items():
            key = f"{job_id}/{prefix}/{sub}"
            if key in self.entries:
                self.entries[key].refcount += 1
            else:
                self.entries[key] = Entry(
                    key=key, tier=tier, nbytes=_nbytes(leaf),
                    ref=leaf, last_touch=self.clock(),
                    spec=self._leaf_spec(leaf))
            keys.append(key)
        self._evict_if_needed()
        return keys

    def keys_for(self, job_id: str, prefix: Optional[str] = None) -> List[str]:
        pre = f"{job_id}/" + (f"{prefix}/" if prefix else "")
        return [k for k in self.entries if k.startswith(pre)]

    def unregister(self, keys: Sequence[str]):
        for k in keys:
            e = self.entries.get(k)
            if e is None:
                continue
            e.refcount -= 1
            if e.refcount <= 0:
                if e.path and os.path.exists(e.path):
                    os.unlink(e.path)
                del self.entries[k]

    # ------------------------------------------------------ tier movement
    def offload(self, keys: Sequence[str], to: Tier = Tier.HOST) -> float:
        """Move state down the hierarchy. Returns elapsed seconds.

        Timed through the injected ``self.clock`` (NOT time.monotonic): under
        a VirtualClock replay transfers take zero virtual time, so measured
        C_setup feedback — and therefore HRRS admission — stays
        deterministic."""
        t0 = self.clock()
        moved = 0
        for k in keys:
            e = self.entries.get(k)
            # a key may vanish mid-iteration when a deployment detaches
            # concurrently (teardown unregisters); skipping it is the move
            if e is None or e.tier >= to:
                continue
            if to == Tier.HOST:
                arr = np.asarray(jax.device_get(e.ref))
                e.ref = arr
            else:  # DISK
                if e.tier == Tier.DEVICE:
                    e.ref = np.asarray(jax.device_get(e.ref))
                os.makedirs(self.disk_dir, exist_ok=True)
                path = os.path.join(self.disk_dir,
                                    k.replace("/", "__") + ".npy")
                arr = e.ref
                e.is_bf16 = arr.dtype == jnp.bfloat16
                np.save(path, arr.view(np.uint16) if e.is_bf16 else arr)
                e.path = path
                e.ref = None
            e.tier = to
            e.last_touch = self.clock()
            moved += e.nbytes
        dt = self.clock() - t0
        self._record("offload", moved, dt)
        return dt

    def prefetch(self, keys: Sequence[str], shardings=None) -> float:
        """Move state up to DEVICE (scheduler-directed prefetch). Timed via
        ``self.clock`` for the same determinism contract as offload."""
        t0 = self.clock()
        moved = 0
        for i, k in enumerate(keys):
            e = self.entries.get(k)
            if e is None or e.tier == Tier.DEVICE:
                continue
            if e.tier == Tier.DISK:
                arr = np.load(e.path)
                if e.is_bf16:
                    arr = arr.view(jnp.bfloat16)
                e.ref = arr
            arr = e.ref
            shd = None
            if shardings is not None:
                shd = shardings[i] if isinstance(shardings, (list, tuple)) \
                    else shardings.get(k)
            if shd is not None:
                e.ref = jax.device_put(arr, shd)
                e.spec = shd.spec if isinstance(shd, NamedSharding) else e.spec
            else:
                # no explicit target layout: restore the entry's recorded
                # spec on THIS node's mesh slice (device-aware residency)
                e.ref = self._to_device(arr, e.spec)
                e.spec = self._leaf_spec(e.ref)
            e.tier = Tier.DEVICE
            e.last_touch = self.clock()
            moved += e.nbytes
        dt = self.clock() - t0
        self._record("load", moved, dt)
        self._evict_if_needed()
        return dt

    def _evict_if_needed(self):
        """Capacity-aware LRU eviction DEVICE->HOST->DISK."""
        while self._tier_bytes(Tier.DEVICE) > self.device_capacity:
            victims = [e for e in self.entries.values() if e.tier == Tier.DEVICE]
            victim = min(victims, key=lambda e: e.last_touch)
            self.offload([victim.key], Tier.HOST)
        while self._tier_bytes(Tier.HOST) > self.host_capacity:
            victims = [e for e in self.entries.values() if e.tier == Tier.HOST]
            victim = min(victims, key=lambda e: e.last_touch)
            self.offload([victim.key], Tier.DISK)

    # --------------------------------------------------------- estimates
    def load_time_estimate(self, nbytes: int) -> float:
        return self._estimate("load", nbytes, 1e10)

    def offload_time_estimate(self, nbytes: int) -> float:
        return self._estimate("offload", nbytes, 1e10)

    def job_bytes(self, job_id: str) -> int:
        return sum(e.nbytes for k, e in self.entries.items()
                   if k.startswith(f"{job_id}/"))

    # ------------------------------------------------------- gather trees
    def gather(self, job_id: str, template, prefix: str = "params"):
        """Rebuild a pytree from managed entries (any tier; loads lazily from
        disk, leaves host tensors as numpy)."""
        flat = {}
        pre = f"{job_id}/{prefix}/"
        for k, e in self.entries.items():
            if not k.startswith(pre):
                continue
            if e.tier == Tier.DISK:
                arr = np.load(e.path)
                if e.is_bf16:
                    arr = arr.view(jnp.bfloat16)
            else:
                arr = e.ref
            flat[k[len(pre):]] = arr
        return common.canonical_unflatten(
            template, flat, is_leaf=lambda x: hasattr(x, "shape"))

    # ------------------------------------------------ §4.5.3 materialise
    def materialize_checkpoint(self, job_id: str, template, path: str,
                               step: int = 0, prefix: str = "params") -> str:
        """Checkpoint = materialisation from managed state — works even if
        (part of) the state is offloaded; no user-triggered export path."""
        from repro.train import checkpoint as ckpt
        tree = self.gather(job_id, template, prefix)
        return ckpt.save(path, tree, step=step,
                         extra_meta={"job_id": job_id, "node": self.node_id})

    def sync_weights(self, job_id: str, template,
                     target_shardings=None, prefix: str = "params",
                     dtype=None):
        """Weight synchronisation to a rollout deployment: materialise
        training-visible state into the target layout. Zero-redundancy: with
        NamedShardings, jax.device_put moves only the slices each target
        shard needs."""
        tree = self.gather(job_id, template, prefix)
        if dtype is not None:
            tree = jax.tree.map(lambda a: jnp.asarray(a, dtype), tree)
        if target_shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, target_shardings)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return tree

    def migrate(self, job_id: str, dst: "StateManager") -> int:
        """Cross-node deployment migration (§4.5.3): mirror managed state to
        the destination node's manager and drop it here.

        Cross-mesh resharding: a DEVICE-tier entry is gathered off THIS
        node's slice (device_get) and re-laid-out on the destination
        slice's mesh with its recorded PartitionSpec (device_put with the
        target NamedSharding); a destination without a mesh slice receives
        host-tier copies (the legacy path). Transactional: nothing is
        unregistered here until EVERY entry has landed on ``dst`` — a
        mid-copy failure rolls the destination back and leaves this node's
        state (all tiers, including disk files) untouched. Timed through
        the injected clock, so the realized reshard cost feeds the
        control plane's migration floor without breaking VirtualClock
        replay (virtual transfers take zero time and are discarded)."""
        t0 = self.clock()
        keys = list(self.keys_for(job_id))
        cross_mesh = (dst.mesh_slice is not None
                      and (self.mesh_slice is None
                           or dst.mesh_slice.devices != self.mesh_slice.devices))
        staged: List[str] = []
        moved = 0
        try:
            for k in keys:
                e = self.entries[k]
                if e.tier == Tier.DEVICE:
                    arr = np.asarray(jax.device_get(e.ref))
                elif e.tier == Tier.DISK:
                    arr = np.load(e.path)
                    if e.is_bf16:
                        arr = arr.view(jnp.bfloat16)
                else:
                    arr = e.ref
                tier, ref, spec = Tier.HOST, arr, e.spec
                if e.tier == Tier.DEVICE and dst.mesh_slice is not None:
                    # reshard onto the target slice: the entry arrives
                    # device-resident in the layout its spec dictates there
                    ref = dst._to_device(arr, e.spec)
                    tier = Tier.DEVICE
                    spec = dst._leaf_spec(ref)
                dst.entries[k] = Entry(key=k, tier=tier, nbytes=e.nbytes,
                                       ref=ref, version=e.version,
                                       last_touch=dst.clock(), spec=spec)
                staged.append(k)
                moved += e.nbytes
        except Exception:
            for k in staged:     # rollback: the source still owns the state
                dst.entries.pop(k, None)
            raise
        for k in keys:
            self.unregister([k])
        dst._evict_if_needed()
        dt = self.clock() - t0
        self._record("migrate", moved, dt)
        self.last_migrate = {"bytes": moved, "seconds": dt,
                             "cross_mesh": cross_mesh, "keys": len(keys)}
        return moved

    # ------------------------------------ cross-PROCESS migration halves
    def export_state(self, job_id: str, max_inline_bytes: int = 64 << 20
                     ) -> Dict[str, Any]:
        """Serialise a job's managed state for transport to ANOTHER PROCESS
        (the process plane's migrate-export). Everything is host-staged —
        jax arrays cannot cross a pipe — and entries larger than
        ``max_inline_bytes`` spill to a fresh disk-tier file and travel by
        absolute path instead (same host, so the importer reads it
        directly). With the shm transport active the caller disables this
        tier (``max_inline_bytes`` huge): inline arrays ride shared-memory
        descriptors instead, which beats the double disk pass. bf16
        travels as uint16 views (numpy pickles those; ml_dtypes scalars it
        may not), PartitionSpecs as plain tuples. Non-destructive: the
        source keeps its entries until the importer has committed and the
        caller drops them.

        Spill files are TRANSACTION-SCOPED: names carry a fresh transfer
        id (``export__{txn}__...``), the payload lists them under
        ``"spills"``, and exactly one party deletes them — the importer on
        commit AND on rollback (:meth:`import_state`), or the caller when
        the importer died before running (``StateManagerProxy.migrate``);
        ``respawn_dead_groups`` sweeps anything a crash orphaned."""
        import uuid
        keys = list(self.keys_for(job_id))
        txn = uuid.uuid4().hex[:12]
        entries = []
        spills: List[str] = []
        total = 0
        t0 = self.clock()
        for k in keys:
            e = self.entries[k]
            if e.tier == Tier.DISK:
                arr = np.load(e.path)
                if e.is_bf16:
                    arr = arr.view(jnp.bfloat16)
            elif e.tier == Tier.DEVICE:
                arr = np.asarray(jax.device_get(e.ref))
            else:
                arr = np.asarray(e.ref)
            is_bf16 = arr.dtype == jnp.bfloat16
            ent = {"key": k, "nbytes": e.nbytes, "version": e.version,
                   "tier": int(e.tier), "is_bf16": is_bf16,
                   "spec": None if e.spec is None else tuple(e.spec),
                   "path": None, "data": None}
            wire = arr.view(np.uint16) if is_bf16 else arr
            if arr.nbytes > max_inline_bytes:
                os.makedirs(self.disk_dir, exist_ok=True)
                path = os.path.join(
                    self.disk_dir,
                    f"export__{txn}__" + k.replace("/", "__") + ".npy")
                np.save(path, wire)
                ent["path"] = path
                spills.append(path)
            else:
                ent["data"] = wire
            entries.append(ent)
            total += e.nbytes
        self._record("migrate", total, self.clock() - t0)
        return {"job_id": job_id, "entries": entries, "bytes": total,
                "txn": txn, "spills": spills}

    def import_state(self, payload: Dict[str, Any]) -> int:
        """Adopt an :meth:`export_state` payload into THIS manager.
        Entries exported from DEVICE re-lay-out onto this manager's mesh
        slice with their recorded spec; HOST/DISK exports arrive HOST.
        Transactional like :meth:`migrate`: a mid-import failure removes
        every staged entry before re-raising, leaving the (untouched)
        exporter the sole owner. The transaction also owns the exporter's
        spill files: consumed (unlinked) on success AND on rollback —
        either way the transfer is over and nobody will read them again."""
        t0 = self.clock()
        staged: List[str] = []
        spills = [p for p in payload.get("spills", ())
                  if p and os.path.basename(p).startswith("export__")]
        moved = 0
        try:
            for ent in payload["entries"]:
                if ent["path"] is not None:
                    arr = np.load(ent["path"])
                else:
                    arr = ent["data"]
                if ent["is_bf16"]:
                    arr = arr.view(jnp.bfloat16)
                spec = None if ent["spec"] is None \
                    else PartitionSpec(*ent["spec"])
                if Tier(ent["tier"]) == Tier.DEVICE:
                    ref = self._to_device(arr, spec)
                    tier, spec = Tier.DEVICE, self._leaf_spec(ref)
                else:
                    # HOST entries must own their buffer: ``arr`` may be a
                    # view over a pooled shm segment that is recycled the
                    # moment this import's reply acks the transfer
                    arr = np.asarray(arr)
                    ref = arr if arr.base is None else np.array(arr)
                    tier = Tier.HOST
                self.entries[ent["key"]] = Entry(
                    key=ent["key"], tier=tier, nbytes=ent["nbytes"],
                    ref=ref, version=ent["version"],
                    last_touch=self.clock(), spec=spec)
                staged.append(ent["key"])
                moved += ent["nbytes"]
        except Exception:
            for k in staged:     # rollback: the exporter still owns the state
                self.entries.pop(k, None)
            for path in spills:  # transfer dead — spills will never be read
                if os.path.exists(path):
                    os.unlink(path)
            raise
        for path in spills:
            if os.path.exists(path):
                os.unlink(path)
        self._evict_if_needed()
        dt = self.clock() - t0
        self._record("load", moved, dt)
        self.last_migrate = {"bytes": moved, "seconds": dt,
                             "cross_mesh": True,
                             "keys": len(payload["entries"])}
        return moved

    # ------------------------------------------- §4.5.4 host optimizer
    def host_optimizer_step(self, job_id: str, grads_tree, template,
                            lr: float = 3e-5, b1: float = 0.9,
                            b2: float = 0.95, eps: float = 1e-8,
                            prefix: str = "params") -> int:
        """CPU AdamW over host-resident canonical state (ZeRO-offload): runs
        off the device critical path while other WPGs execute. Moments are
        created lazily on HOST at first use. Returns the new step count."""
        pre = f"{job_id}/{prefix}/"
        gflat = common.canonical_flat(
            grads_tree, is_leaf=lambda x: hasattr(x, "shape"))
        step_key = f"{job_id}/opt/step"
        if step_key not in self.entries:
            self.entries[step_key] = Entry(step_key, Tier.HOST, 8,
                                           ref=np.zeros((), np.int64))
        step = int(self.entries[step_key].ref) + 1
        self.entries[step_key].ref = np.asarray(step, np.int64)
        c1 = 1.0 - b1 ** step
        c2 = 1.0 - b2 ** step
        for sub, g in gflat.items():
            pkey = pre + sub
            e = self.entries[pkey]
            if e.tier == Tier.DEVICE:
                # pull a host copy; device copy becomes stale until sync
                e.ref = np.asarray(jax.device_get(e.ref))
                e.tier = Tier.HOST
            p = np.asarray(e.ref, np.float32)
            g32 = np.asarray(jax.device_get(g), np.float32)
            for mom, beta in (("mu", b1), ("nu", b2)):
                mkey = f"{job_id}/opt/{mom}/{sub}"
                if mkey not in self.entries:
                    self.entries[mkey] = Entry(mkey, Tier.HOST,
                                               g32.nbytes,
                                               ref=np.zeros_like(g32))
            mu = self.entries[f"{job_id}/opt/mu/{sub}"]
            nu = self.entries[f"{job_id}/opt/nu/{sub}"]
            mu.ref = b1 * mu.ref + (1 - b1) * g32
            nu.ref = b2 * nu.ref + (1 - b2) * np.square(g32)
            upd = (mu.ref / c1) / (np.sqrt(nu.ref / c2) + eps)
            newp = (p - lr * upd)
            e.ref = newp.astype(np.asarray(e.ref).dtype) \
                if np.asarray(e.ref).dtype != np.float32 else newp
            e.version += 1
        return step
