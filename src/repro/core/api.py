"""Serviceized execution API (paper §4.2): the narrow remote interface.

Algorithm code (RLController) sees only logical deployments and a small set
of primitive operations; placement, parallelism, state movement, and
ordering are the system's concern.

Client surface (the dataflow API)
---------------------------------
:class:`Deployment` is the bound client handle a controller programs
against: ``dep.generate(...)``, ``dep.update_actor(...)`` etc. submit one
operation each and return a chainable :class:`Future`.

- ``future.then(fn)`` derives a new future resolving to ``fn(result)``
  (errors propagate past ``fn``; an exception inside ``fn`` becomes the
  derived future's error).
- :func:`gather` joins several futures into one resolving to the list of
  results.
- Any :class:`Future` passed as an operation *argument* is a dataflow edge:
  the futures' source operations are registered as prerequisites
  automatically, the Router holds the op until they settle, and the resolved
  values are spliced into the arguments at dispatch time. No manual
  ``req_id`` wiring, no nested callbacks.

``make_op`` + ``Router.submit_queued_operation`` remain the low-level
escape hatch underneath (explicit req_id prerequisites, custom arrival
times); everything the handle does compiles down to them.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple


class Op(enum.Enum):
    INIT = "init"                       # deployment lifecycle
    GENERATE = "generate"               # rollout (prefill + decode loop)
    FORWARD = "forward"                 # compute_log_prob / reward model
    FORWARD_BACKWARD = "forward_backward"
    OPTIM_STEP = "optim_step"
    UPDATE_ACTOR = "update_actor"       # fused fwd+bwd+step
    SYNC_WEIGHTS = "sync_weights"
    SAVE_CHECKPOINT = "save_checkpoint"
    LOAD_CHECKPOINT = "load_checkpoint"


@dataclasses.dataclass(frozen=True)
class DeploymentSpec:
    """One logical deployment -> one worker-process group (WPG)."""
    deployment_id: str
    job_id: str
    model_name: str                     # repro.configs registry id
    role: str                           # "train" | "rollout" | "reference" | "critic"
    nodes: int = 1
    parallelism: Tuple[Tuple[str, int], ...] = ()   # e.g. (("data",2),("model",4))
    overrides: Tuple[Tuple[str, Any], ...] = ()     # ModelConfig.replace kwargs


class _CallbackList:
    """Back-compat shim: ``future.callbacks.append(cb)`` must stay race-safe
    now that operations complete on dispatch worker threads, so appends are
    routed through :meth:`Future.add_done_callback`."""

    __slots__ = ("_future",)

    def __init__(self, future: "Future"):
        self._future = future

    def append(self, cb: Callable[["Future"], None]):
        self._future.add_done_callback(cb)


class Future:
    """Thread-safe future for the non-blocking control plane (§5.2.2).

    Completion is signalled through a condition variable so any thread can
    block in :meth:`wait`; callbacks are fired OUTSIDE the internal lock
    because a callback may submit follow-up operations that resolve further
    futures (possibly on other dispatch threads).

    ``sources`` is the dataflow provenance: the req_ids of the operations
    this value (transitively) derives from. Submitting a future as an op
    argument turns its sources into scheduler prerequisites, so by the time
    the dependent op is admitted the future is resolved (or about to be, in
    the narrow window between a source op's COMPLETED transition and its
    callback chain firing — dispatch bridges that with a bounded wait).
    """

    __slots__ = ("_cond", "_done", "_result", "_error", "_callbacks",
                 "callbacks", "sources")

    def __init__(self, sources: Tuple[int, ...] = ()):
        self._cond = threading.Condition()
        self._done = False
        self._result = None
        self._error: Optional[BaseException] = None
        self._callbacks: List[Callable[["Future"], None]] = []
        self.callbacks = _CallbackList(self)
        self.sources: Tuple[int, ...] = tuple(sources)

    # ------------------------------------------------------------ resolve
    def _resolve(self, result, error: Optional[BaseException]):
        with self._cond:
            if self._done:
                raise RuntimeError("future already resolved")
            self._result = result
            self._error = error
            self._done = True
            cbs, self._callbacks = self._callbacks, []
            self._cond.notify_all()
        for cb in cbs:
            cb(self)

    def set_result(self, value):
        self._resolve(value, None)

    def set_error(self, err: BaseException):
        self._resolve(None, err)

    # ------------------------------------------------------------ observe
    def add_done_callback(self, cb: Callable[["Future"], None]):
        """Register ``cb(future)``; fires immediately if already resolved."""
        with self._cond:
            if not self._done:
                self._callbacks.append(cb)
                return
        cb(self)

    def done(self) -> bool:
        return self._done

    def wait(self, timeout: Optional[float] = None):
        """Block until resolved, then return :meth:`result` (re-raising the
        operation's error). Raises ``TimeoutError`` if ``timeout`` elapses."""
        with self._cond:
            if not self._cond.wait_for(lambda: self._done, timeout):
                raise TimeoutError(
                    f"operation did not complete within {timeout}s")
        return self.result()

    def result(self):
        if not self._done:
            raise RuntimeError("future not resolved; drive the cluster loop")
        if self._error is not None:
            raise self._error
        return self._result

    # ----------------------------------------------------------- dataflow
    def then(self, fn: Callable[[Any], Any]) -> "Future":
        """Chain: a future resolving to ``fn(self.result())``.

        If this future errors, the error propagates and ``fn`` never runs;
        if ``fn`` raises, the derived future carries that error. The derived
        future inherits this future's dataflow sources, so it can itself be
        passed as an op argument (the Router gates on the same source ops).
        """
        child = Future(sources=self.sources)

        def _link(parent: "Future"):
            if parent._error is not None:
                child.set_error(parent._error)
                return
            try:
                child.set_result(fn(parent._result))
            except Exception as e:  # noqa: BLE001 - user transform error
                child.set_error(e)

        self.add_done_callback(_link)
        return child


def gather(*futures: Future) -> Future:
    """Join futures into one resolving to ``[f.result(), ...]`` in argument
    order; the first error wins (later results are dropped). The joined
    future's sources are the union of the inputs' sources, so it composes
    with future-argument splicing like any other future."""
    futures = tuple(futures)
    sources: Tuple[int, ...] = tuple(
        dict.fromkeys(s for f in futures for s in f.sources))
    joined = Future(sources=sources)
    if not futures:
        joined.set_result([])
        return joined
    lock = threading.Lock()
    remaining = [len(futures)]
    fired = [False]
    results: List[Any] = [None] * len(futures)

    def _arm(i: int, f: Future):
        def _done(fut: Future):
            with lock:
                if fired[0]:
                    return
                if fut._error is not None:
                    err, fire = fut._error, "error"
                    fired[0] = True
                else:
                    results[i] = fut._result
                    remaining[0] -= 1
                    if remaining[0]:
                        return
                    fire = "result"
                    fired[0] = True
            # fire outside the counting lock (callbacks may submit ops)
            if fire == "error":
                joined.set_error(err)
            else:
                joined.set_result(list(results))
        f.add_done_callback(_done)

    for i, f in enumerate(futures):
        _arm(i, f)
    return joined


_req_counter = itertools.count(1)


# Containers are searched/spliced _MAX_ARG_DEPTH levels below each
# top-level argument value. The two walks MUST agree: every future the
# splice can reach must also have been seen by the prerequisite scan,
# otherwise dispatch would block on an ungated future.
_MAX_ARG_DEPTH = 3

# Upper bound on the dispatch-time wait for a future argument whose source
# ops already COMPLETED: it covers the client-side `.then` transform chain
# still running on the resolving thread (packing a large rollout batch can
# take real time), NOT the ops themselves — those are gated by
# prerequisites. Module-level so deployments with pathological transforms
# can raise it.
SPLICE_TIMEOUT_S = 600.0


def _walk_futures(obj, found: List[Future], depth: int = 0):
    """Collect Future instances from an argument value and its containers
    (lists, tuples, dicts) up to ``_MAX_ARG_DEPTH`` levels deep — deep
    enough for every realistic op signature without touching tensor
    payloads. Mirrors :func:`_splice` exactly."""
    if isinstance(obj, Future):
        found.append(obj)
        return
    if depth >= _MAX_ARG_DEPTH:
        return
    if isinstance(obj, (list, tuple)):
        for v in obj:
            _walk_futures(v, found, depth + 1)
    elif isinstance(obj, dict):
        for v in obj.values():
            _walk_futures(v, found, depth + 1)


def _splice(obj, depth: int = 0, timeout: Optional[float] = None):
    """Replace embedded futures with their resolved values (dispatch-time
    argument substitution; mirrors :func:`_walk_futures`). The futures'
    source ops are COMPLETED by the time the dependent op is dispatched, so
    the bounded wait only bridges the instant between a source's state
    transition and its callback chain; a future that errored re-raises
    here, failing (and thus poisoning) the dependent op."""
    if isinstance(obj, Future):
        return obj.wait(timeout=SPLICE_TIMEOUT_S if timeout is None
                        else timeout)
    if depth >= _MAX_ARG_DEPTH:
        return obj
    if isinstance(obj, list):
        return [_splice(v, depth + 1, timeout) for v in obj]
    if isinstance(obj, tuple):
        return tuple(_splice(v, depth + 1, timeout) for v in obj)
    if isinstance(obj, dict):
        return {k: _splice(v, depth + 1, timeout) for k, v in obj.items()}
    return obj


@dataclasses.dataclass
class QueuedOperation:
    """submit_queued_operation wrapper (§5.2.2): request + future handle."""
    req_id: int
    deployment_id: str
    job_id: str
    op: Op
    args: tuple = ()
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    exec_estimate: float = 1.0
    arrival_time: float = 0.0
    future: Future = dataclasses.field(default_factory=Future)
    prerequisites: Tuple[int, ...] = ()
    has_future_args: bool = False
    # set by Router.teardown for an op already RUNNING when its deployment
    # detaches: the execution backend, pinned so the op still completes
    # normally after the router's wpg table entry is gone
    pinned_wpg: Any = None

    def resolve_args(self):
        """Dispatch-time dataflow splice: substitute resolved values for any
        future passed as an argument. Mutates in place (each op dispatches
        exactly once)."""
        if not self.has_future_args:
            return
        self.args = tuple(_splice(v) for v in self.args)
        self.kwargs = {k: _splice(v) for k, v in self.kwargs.items()}
        self.has_future_args = False


def make_op(deployment: DeploymentSpec, op: Op, *args,
            exec_estimate: float = 1.0, arrival_time: float = 0.0,
            prerequisites: Tuple[int, ...] = (), **kwargs) -> QueuedOperation:
    """Low-level constructor (escape hatch): builds one QueuedOperation.

    Futures embedded in ``args``/``kwargs`` are detected here: their source
    ops join ``prerequisites`` and the op is marked for dispatch-time
    splicing. ``prerequisites`` may also mix Futures with raw req_ids."""
    req_id = next(_req_counter)
    embedded: List[Future] = []
    # scan each top-level value from depth 0 so the reachable set is
    # IDENTICAL to resolve_args' splice (which substitutes per value)
    for v in args:
        _walk_futures(v, embedded)
    for v in kwargs.values():
        _walk_futures(v, embedded)
    prereqs: List[int] = []
    for p in prerequisites:
        if isinstance(p, Future):
            if not p.sources and not p.done():
                raise ValueError(
                    "ordering future has no source operations and is "
                    "unresolved: the scheduler cannot gate on it")
            prereqs.extend(p.sources)
        else:
            prereqs.append(p)
    for f in embedded:
        if not f.sources and not f.done():
            # no prerequisite can gate this op, so dispatch would block a
            # group's exclusive lock waiting on a hand-made future — refuse
            # loudly at submit time instead
            raise ValueError(
                "argument future has no source operations and is "
                "unresolved: resolve it first, or derive it from a "
                "Deployment op so admission can be gated on it")
        prereqs.extend(f.sources)
    # dedup, drop self-reference, preserve order
    prereqs = [p for p in dict.fromkeys(prereqs) if p != req_id]
    qop = QueuedOperation(
        req_id=req_id,
        deployment_id=deployment.deployment_id,
        job_id=deployment.job_id,
        op=op, args=args, kwargs=kwargs,
        exec_estimate=exec_estimate,
        arrival_time=arrival_time,
        prerequisites=tuple(prereqs),
        has_future_args=bool(embedded),
    )
    qop.future.sources = (req_id,)
    return qop


class Deployment:
    """Bound client handle: one logical deployment plus the router serving
    it. Every method submits one primitive operation and returns its
    :class:`Future` immediately (non-blocking, §5.2.2); the scheduler owns
    ordering via the dataflow edges described in the module docstring.

    ``after=`` takes futures (or raw req_ids) that must complete first even
    though their results are not consumed — the pure-ordering edge (e.g.
    one-step-async gating of generation k on update k-1-s).
    """

    def __init__(self, spec: DeploymentSpec, router):
        self.spec = spec
        self.router = router

    # ----------------------------------------------------------- plumbing
    @property
    def deployment_id(self) -> str:
        return self.spec.deployment_id

    @property
    def job_id(self) -> str:
        return self.spec.job_id

    @property
    def wpg(self):
        return self.router.wpgs[self.spec.deployment_id]

    def call(self, op: Op, *args, exec_estimate: float = 1.0,
             after: Tuple = (), **kwargs) -> Future:
        """Generic submit: any primitive op through the dataflow path."""
        qop = make_op(self.spec, op, *args, exec_estimate=exec_estimate,
                      prerequisites=tuple(after), **kwargs)
        return self.router.submit_queued_operation(qop)

    # ------------------------------------------------------ primitive ops
    def init(self, seed: int = 0, *, exec_estimate: float = 1.0,
             after: Tuple = ()) -> Future:
        return self.call(Op.INIT, seed, exec_estimate=exec_estimate,
                         after=after)

    def generate(self, prompt_tokens, *, max_new_tokens: int = 32,
                 temperature: float = 1.0, exec_estimate: float = 1.0,
                 after: Tuple = (), **kwargs) -> Future:
        return self.call(Op.GENERATE, prompt_tokens,
                         max_new_tokens=max_new_tokens,
                         temperature=temperature,
                         exec_estimate=exec_estimate, after=after, **kwargs)

    def forward(self, batch, *, output: str = "logprobs",
                exec_estimate: float = 1.0, after: Tuple = ()) -> Future:
        """Forward-only op; ``output`` picks the readout ("logprobs" for
        compute_log_prob, "values" for a critic deployment)."""
        return self.call(Op.FORWARD, batch, output=output,
                         exec_estimate=exec_estimate, after=after)

    def forward_backward(self, batch, *, objective: str = "grpo",
                         exec_estimate: float = 1.0,
                         after: Tuple = ()) -> Future:
        return self.call(Op.FORWARD_BACKWARD, batch, objective=objective,
                         exec_estimate=exec_estimate, after=after)

    def optim_step(self, grads, *, host: bool = False,
                   exec_estimate: float = 1.0, after: Tuple = ()) -> Future:
        return self.call(Op.OPTIM_STEP, grads, host=host,
                         exec_estimate=exec_estimate, after=after)

    def update_actor(self, batch, *, exec_estimate: float = 1.0,
                     after: Tuple = ()) -> Future:
        return self.call(Op.UPDATE_ACTOR, batch,
                         exec_estimate=exec_estimate, after=after)

    def sync_weights(self, target: "Deployment", *, target_shardings=None,
                     exec_estimate: float = 1.0, after: Tuple = ()) -> Future:
        tgt = target.wpg if isinstance(target, Deployment) else target
        return self.call(Op.SYNC_WEIGHTS, tgt,
                         target_shardings=target_shardings,
                         exec_estimate=exec_estimate, after=after)

    def save_checkpoint(self, path: str, step: int = 0, *,
                        exec_estimate: float = 1.0,
                        after: Tuple = ()) -> Future:
        return self.call(Op.SAVE_CHECKPOINT, path, step,
                         exec_estimate=exec_estimate, after=after)

    def load_checkpoint(self, path: str, *, exec_estimate: float = 1.0,
                        after: Tuple = ()) -> Future:
        return self.call(Op.LOAD_CHECKPOINT, path,
                         exec_estimate=exec_estimate, after=after)
