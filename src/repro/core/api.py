"""Serviceized execution API (paper §4.2): the narrow remote interface.

Algorithm code (RLController) sees only logical deployments and a small set
of primitive operations; placement, parallelism, state movement, and
ordering are the system's concern.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple


class Op(enum.Enum):
    INIT = "init"                       # deployment lifecycle
    GENERATE = "generate"               # rollout (prefill + decode loop)
    FORWARD = "forward"                 # compute_log_prob / reward model
    FORWARD_BACKWARD = "forward_backward"
    OPTIM_STEP = "optim_step"
    UPDATE_ACTOR = "update_actor"       # fused fwd+bwd+step
    SYNC_WEIGHTS = "sync_weights"
    SAVE_CHECKPOINT = "save_checkpoint"
    LOAD_CHECKPOINT = "load_checkpoint"


@dataclasses.dataclass(frozen=True)
class DeploymentSpec:
    """One logical deployment -> one worker-process group (WPG)."""
    deployment_id: str
    job_id: str
    model_name: str                     # repro.configs registry id
    role: str                           # "train" | "rollout" | "reference" | "critic"
    nodes: int = 1
    parallelism: Tuple[Tuple[str, int], ...] = ()   # e.g. (("data",2),("model",4))
    overrides: Tuple[Tuple[str, Any], ...] = ()     # ModelConfig.replace kwargs


class _CallbackList:
    """Back-compat shim: ``future.callbacks.append(cb)`` must stay race-safe
    now that operations complete on dispatch worker threads, so appends are
    routed through :meth:`Future.add_done_callback`."""

    __slots__ = ("_future",)

    def __init__(self, future: "Future"):
        self._future = future

    def append(self, cb: Callable[["Future"], None]):
        self._future.add_done_callback(cb)


class Future:
    """Thread-safe future for the non-blocking control plane (§5.2.2).

    Completion is signalled through a condition variable so any thread can
    block in :meth:`wait`; callbacks are fired OUTSIDE the internal lock
    because a callback may submit follow-up operations that resolve further
    futures (possibly on other dispatch threads).
    """

    __slots__ = ("_cond", "_done", "_result", "_error", "_callbacks",
                 "callbacks")

    def __init__(self):
        self._cond = threading.Condition()
        self._done = False
        self._result = None
        self._error: Optional[BaseException] = None
        self._callbacks: List[Callable[["Future"], None]] = []
        self.callbacks = _CallbackList(self)

    # ------------------------------------------------------------ resolve
    def _resolve(self, result, error: Optional[BaseException]):
        with self._cond:
            if self._done:
                raise RuntimeError("future already resolved")
            self._result = result
            self._error = error
            self._done = True
            cbs, self._callbacks = self._callbacks, []
            self._cond.notify_all()
        for cb in cbs:
            cb(self)

    def set_result(self, value):
        self._resolve(value, None)

    def set_error(self, err: BaseException):
        self._resolve(None, err)

    # ------------------------------------------------------------ observe
    def add_done_callback(self, cb: Callable[["Future"], None]):
        """Register ``cb(future)``; fires immediately if already resolved."""
        with self._cond:
            if not self._done:
                self._callbacks.append(cb)
                return
        cb(self)

    def done(self) -> bool:
        return self._done

    def wait(self, timeout: Optional[float] = None):
        """Block until resolved, then return :meth:`result` (re-raising the
        operation's error). Raises ``TimeoutError`` if ``timeout`` elapses."""
        with self._cond:
            if not self._cond.wait_for(lambda: self._done, timeout):
                raise TimeoutError(
                    f"operation did not complete within {timeout}s")
        return self.result()

    def result(self):
        if not self._done:
            raise RuntimeError("future not resolved; drive the cluster loop")
        if self._error is not None:
            raise self._error
        return self._result


_req_counter = itertools.count(1)


@dataclasses.dataclass
class QueuedOperation:
    """submit_queued_operation wrapper (§5.2.2): request + future handle."""
    req_id: int
    deployment_id: str
    job_id: str
    op: Op
    args: tuple = ()
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    exec_estimate: float = 1.0
    arrival_time: float = 0.0
    future: Future = dataclasses.field(default_factory=Future)
    prerequisites: Tuple[int, ...] = ()


def make_op(deployment: DeploymentSpec, op: Op, *args,
            exec_estimate: float = 1.0, arrival_time: float = 0.0,
            prerequisites: Tuple[int, ...] = (), **kwargs) -> QueuedOperation:
    return QueuedOperation(
        req_id=next(_req_counter),
        deployment_id=deployment.deployment_id,
        job_id=deployment.job_id,
        op=op, args=args, kwargs=kwargs,
        exec_estimate=exec_estimate,
        arrival_time=arrival_time,
        prerequisites=prerequisites,
    )
