"""Stateless Router: control-plane entry point of the execution service.

§5.1: the Router maps logical deployment ids to WPGs, submits every incoming
operation to the Scheduler for admission, and only then dispatches it. It
owns deployment lifecycle (create / init / teardown) and the automatic
context-switch logic (§5.2.2 ``_handle_job_transition``): when an admitted
operation targets a different job than the one resident on the target group,
offload+load operations are prepended transparently.

Dispatch plane
--------------
All drivers share ONE admission path (HRRS scoring + lock-gated start in
``TaskExecutor``). The plane has a *persistent* serve mode and two bounded
wrappers:

- :meth:`serve` / :meth:`shutdown` — the serviceized runtime: one dispatch
  worker thread per node group parks on the executor's condition variable
  indefinitely while idle and admits work the moment it arrives, so
  independently-arriving jobs multiplex against a continuously running
  service. :meth:`create_deployment` on a new group while serving spawns
  that group's worker dynamically; :meth:`teardown` cancels a departing
  deployment's queued operations (their futures resolve with an error and
  dependents are poisoned) so detach-while-serving terminates cleanly.
- :meth:`run_until_idle` — a bounded session of the same worker loop: the
  workers additionally exit once nothing is queued, running, or firing
  callbacks (batch semantics over the identical admission/execute path).
- :meth:`step` / :meth:`drain` — the serial analogue on the same admission
  path, used for the back-to-back baseline and for deterministic replay
  under a :class:`~repro.core.scheduler.executor.VirtualClock`.

Dataflow arguments: an operation whose arguments embed unresolved
:class:`~repro.core.api.Future`\\ s is held by its auto-registered
prerequisites and the resolved values are spliced in at dispatch time
(``QueuedOperation.resolve_args``), so client code chains ops without
manual req_id wiring.

Failure propagation: an operation that raises resolves its future with the
error, and any queued operation whose prerequisite FAILED is itself failed
("poisoned") instead of waiting forever, so every driver always terminates.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import api
from repro.core.scheduler import hrrs
from repro.core.scheduler.executor import State, Task, TaskExecutor
from repro.core.state_manager import StateManager, Tier
from repro.core.worker import WorkerProcessGroup
from repro.launch.mesh import DevicePlane, env_for_slice
from repro.launch.proc_plane import (GroupProcess, StateManagerProxy,
                                     WPGProxy)

logger = logging.getLogger(__name__)


class Router:
    def __init__(self, now: Callable[[], float] = time.monotonic,
                 policy: str = "hrrs",
                 wpg_factory: Callable[..., object] = WorkerProcessGroup,
                 device_plane: Optional[DevicePlane] = None,
                 devices_per_group: Optional[int] = None,
                 process_plane: bool = False,
                 proc_wpg_factory: Optional[str] = None,
                 shm_transport: Optional[bool] = None,
                 shm_threshold: Optional[int] = None):
        """``process_plane=True`` hosts each node group's WPGs in a separate
        OS process bound to the group's mesh slice (launch/proc_plane.py):
        dispatch crosses an IPC pipe instead of a method call, so groups on
        disjoint slices overlap as real OS-level parallelism instead of
        GIL-bound threads. In-process mode (the default) is bit-identical
        to the pre-process-plane plane — including VirtualClock replay.
        ``proc_wpg_factory`` names the child-side factory as
        "module:callable" (factories cross the spawn boundary by name, not
        pickle); None means the real WorkerProcessGroup.

        ``shm_transport`` controls the process plane's zero-copy
        shared-memory array transport (launch/shm_transport.py): None
        auto-enables it where the host supports it, False forces the
        pickle path. ``shm_threshold`` overrides the per-array size above
        which arrays ride shm (default: the measured crossover)."""
        self.now = now
        self.process_plane = process_plane
        self.proc_wpg_factory = proc_wpg_factory
        self.shm_transport = shm_transport
        self.shm_threshold = shm_threshold
        self.group_procs: Dict[int, GroupProcess] = {}
        # dispatch workers hung inside wpg.execute past their abandon grace
        # (daemon threads we can't kill) — reported, never silently dropped
        self._abandoned: List[threading.Thread] = []
        self.wpgs: Dict[str, object] = {}
        self.deployments: Dict[str, api.DeploymentSpec] = {}
        self.group_of: Dict[str, int] = {}       # deployment -> node group
        self.state_managers: Dict[int, StateManager] = {}
        # the device plane leases each group a disjoint mesh slice; on one
        # default device every group shares the lone slice (legacy view)
        self.device_plane = device_plane or DevicePlane(
            slice_size=devices_per_group)
        # realized migration costs (reshard included), consumed by the
        # PlacementDirector to calibrate the migration-cost floor
        self.migrate_log: List[dict] = []
        self.executor = TaskExecutor(now=now, policy=policy)
        # multi-tenant service layer: per-job tenant binding and HRRS
        # priority weight (rho). Unregistered jobs default to the implicit
        # default tenant at priority 1.0 — the multiplicative identity, so
        # untenanted planes score bit-identically to the pre-tenancy plane.
        self.job_priority: Dict[str, float] = {}
        self.job_tenant: Dict[str, str] = {}
        # set by Cluster when tenancy is wired; tenant_telemetry() merges
        # its accounting snapshot (gpu-seconds, SLO attainment, pending)
        self.tenant_ledger = None
        # per-job queued-op table, keyed by req_id for O(1) finalize
        self.request_queues: Dict[str, Dict[int, api.QueuedOperation]] = {}
        self.pending: Dict[int, api.QueuedOperation] = {}
        self.switch_log: List[dict] = []
        self.wpg_factory = wpg_factory
        # exceptions raised by user callbacks during future resolution; a
        # broken callback must not kill a dispatch thread mid-protocol
        self.callback_errors: List[Tuple[int, BaseException]] = []
        # persistent serve-mode plane: one stop token per group worker (so
        # retire_group can tear one down) plus the plane-wide shutdown token
        self._serving = False
        self._serve_stop = threading.Event()
        self._serve_stops: Dict[int, threading.Event] = {}
        self._serve_threads: Dict[int, threading.Thread] = {}
        self._serve_executed: Dict[int, List[int]] = {}
        self._serve_err_start = 0

    # ----------------------------------------------------------- lifecycle
    def _group_sm(self, group_id: int) -> StateManager:
        """The group's StateManager, creating it (and leasing the group's
        mesh slice from the device plane) on first sight. The slice lease
        is what gives the group hardware affinity: every WPG on the group
        reads ``sm.mesh_slice`` for its jit/sharding mesh. In process mode
        first sight also SPAWNS the group's worker process (launch returns
        immediately; the ready handshake is awaited on first use) and the
        returned object is a :class:`StateManagerProxy` over its pipe."""
        sm = self.state_managers.get(group_id)
        if sm is None:
            sl = self.device_plane.slice_for_group(group_id)
            if self.process_plane:
                sm = self._spawn_group_process(group_id, sl)
            else:
                sm = StateManager(node_id=f"group{group_id}",
                                  clock=self.now, mesh_slice=sl)
            self.state_managers[group_id] = sm
        elif sm.mesh_slice is None:
            sm.mesh_slice = self.device_plane.slice_for_group(group_id)
        return sm

    def _spawn_group_process(self, group_id: int, sl) -> StateManagerProxy:
        gp = GroupProcess(group_id, env=env_for_slice(sl),
                          slice_index=sl.index,
                          wpg_factory=self.proc_wpg_factory,
                          node_id=f"group{group_id}",
                          shm=self.shm_transport,
                          shm_threshold=self.shm_threshold)
        self.group_procs[group_id] = gp
        return StateManagerProxy(gp, mesh_slice=sl,
                                 node_id=f"group{group_id}")

    def mesh_domains(self) -> Dict[int, int]:
        """group id -> mesh-slice index (the placement layer's domain map:
        a move between different domains pays the cross-mesh reshard)."""
        return self.device_plane.domains()

    def create_deployment(self, spec: api.DeploymentSpec, group_id: int = 0,
                          state_manager: Optional[StateManager] = None):
        """Register a deployment (low level; returns the WPG). While serving,
        a deployment on a group without a dispatch worker spawns one, so
        jobs attach to a live plane without a restart."""
        if state_manager is not None and self.process_plane:
            raise RuntimeError("explicit state_manager is incompatible with "
                               "process_plane (state lives in the group's "
                               "worker process)")
        with self.executor.cv:
            sm = state_manager or self._group_sm(group_id)
            self.state_managers[group_id] = sm
        # built OUTSIDE the cv: a slow model build (or the child process's
        # create_deployment round trip) must not stall the dispatch plane
        wpg = WPGProxy(spec, sm) if self.process_plane \
            else self.wpg_factory(spec, sm)
        with self.executor.cv:
            self.wpgs[spec.deployment_id] = wpg
            self.deployments[spec.deployment_id] = spec
            self.group_of[spec.deployment_id] = group_id
            self.request_queues.setdefault(spec.job_id, {})
            # read under the same lock serve() writes it, so an attach
            # concurrent with serve() either lands in serve's group
            # snapshot or observes _serving and spawns the worker itself
            serving = self._serving
        if serving:
            self._ensure_serve_worker(group_id)
        return wpg

    def deploy(self, spec: api.DeploymentSpec, group_id: int = 0,
               state_manager: Optional[StateManager] = None) -> api.Deployment:
        """Client-facing attach: register the deployment and return its bound
        :class:`~repro.core.api.Deployment` handle (the dataflow API)."""
        self.create_deployment(spec, group_id=group_id,
                               state_manager=state_manager)
        return api.Deployment(spec, self)

    def teardown(self, deployment_id: str):
        """Detach a deployment from the (possibly live) plane.

        Its queued operations are cancelled: each resolves its future with a
        teardown error, and anything depending on them is poisoned through
        the normal failure path. An operation already RUNNING completes and
        resolves its future normally. The job's request queue is dropped
        once its last deployment detaches."""
        cancelled: List[Tuple[api.QueuedOperation, Exception]] = []
        ex = self.executor
        with ex.cv:
            wpg = self.wpgs.pop(deployment_id, None)
            spec = self.deployments.pop(deployment_id, None)
            self.group_of.pop(deployment_id, None)
            if spec is not None:
                err = RuntimeError(
                    f"deployment {deployment_id} torn down")
                for qop in list(self.pending.values()):
                    if qop.deployment_id != deployment_id:
                        continue
                    task = self.executor.tasks.get(qop.req_id)
                    if task is None or task.state != State.QUEUED:
                        # RUNNING (possibly admitted but not yet executing):
                        # pin the backend so the op completes normally even
                        # though the wpg table entry is gone
                        qop.pinned_wpg = wpg
                        continue
                    self.executor.finish(task, error=str(err))
                    self._finalize(qop)
                    cancelled.append((qop, err))
                if not any(s.job_id == spec.job_id
                           for s in self.deployments.values()):
                    self.request_queues.pop(spec.job_id, None)
                    self.job_priority.pop(spec.job_id, None)
                    self.job_tenant.pop(spec.job_id, None)
            if cancelled:
                # hold the idle guard across the error callbacks below:
                # finish() already dropped the open count, and a callback
                # may resubmit (same protocol as _reap_and_resolve)
                ex.inflight += 1
        if wpg is not None:
            # an op pinned mid-execute still reads this deployment's managed
            # state: let it drain before the entries are dropped (bounded;
            # submits to the torn-down deployment are rejected, so the set
            # of its pending ops can only shrink)
            with ex.cv:
                ex.cv.wait_for(
                    lambda: not any(q.deployment_id == deployment_id
                                    for q in self.pending.values()),
                    timeout=120.0)
            wpg.sm.unregister(wpg.sm.keys_for(wpg.job_prefix))
            close = getattr(wpg, "close", None)
            if close is not None:       # process mode: drop the child-side WPG
                close()
        if cancelled:
            try:
                for qop, err in cancelled:
                    self._resolve_future(qop, None, err)
            finally:
                with ex.cv:
                    ex.inflight -= 1
                    ex.cv.notify_all()

    # -------------------------------------------------------------- submit
    def register_job_tenant(self, job_id: str, tenant_id: str,
                            priority: float = 1.0):
        """Bind a job to its tenant and HRRS priority weight. Every
        subsequently submitted operation of the job is scored with the
        multiplicative ``priority`` term (1.0 = default tenant, exact
        no-op on the score). Cleared when the job's last deployment
        detaches."""
        with self.executor.cv:
            self.job_tenant[job_id] = tenant_id
            self.job_priority[job_id] = priority

    def submit_queued_operation(self, qop: api.QueuedOperation) -> api.Future:
        """Non-blocking API handler (§5.2.2): wrap + enqueue, return at once.

        Thread-safe: future callbacks submit follow-up operations from
        dispatch worker threads while controllers submit from client
        threads; a live serve plane admits the op the moment its group and
        prerequisites allow."""
        with self.executor.cv:
            if qop.deployment_id not in self.group_of:
                raise RuntimeError(
                    f"unknown deployment {qop.deployment_id!r} "
                    "(never created, or torn down)")
            qop.arrival_time = self.now()
            self.request_queues.setdefault(qop.job_id, {})[qop.req_id] = qop
            req = hrrs.Request(req_id=qop.req_id, job_id=qop.job_id,
                               op=qop.op.value, exec_time=qop.exec_estimate,
                               arrival_time=qop.arrival_time, payload=qop,
                               priority=self.job_priority.get(
                                   qop.job_id, 1.0))
            group = self.group_of[qop.deployment_id]
            self.executor.submit(req, group,
                                 prerequisites=qop.prerequisites)
            self.pending[qop.req_id] = qop
        return qop.future

    # ------------------------------------------------------------ dispatch
    def _handle_job_transition(self, group_id: int, qop: api.QueuedOperation,
                               target_wpg):
        """Automatic context switching: if the group's resident job differs,
        prepend offload(current) + load(target)."""
        sm = self.state_managers[group_id]
        # snapshot the deployment map under the lock: attach/detach may
        # mutate it from other threads while this group switches
        with self.executor.cv:
            resident = [w for d, g in self.group_of.items()
                        if g == group_id and d != qop.deployment_id
                        and (w := self.wpgs.get(d)) is not None
                        and w.spec.job_id != qop.job_id]
        resident = [w for w in resident if w.resident()]
        t_off = 0.0
        for w in resident:
            t_off += w.offload(Tier.HOST)
        t_load = target_wpg.ensure_resident()
        if resident or t_load > 0:
            with self.executor.cv:
                self.switch_log.append({
                    "t": self.now(), "group": group_id, "to_job": qop.job_id,
                    "t_offload": t_off, "t_load": t_load})
        # feed measured setup costs back into HRRS (per group: concurrent
        # groups switch independently)
        nbytes = sm.job_bytes(target_wpg.job_prefix)
        self.executor.set_setup_costs(group_id,
                                      sm.load_time_estimate(nbytes),
                                      sm.offload_time_estimate(nbytes))

    def _resolve_future(self, qop: api.QueuedOperation, result,
                        err: Optional[BaseException]):
        try:
            if err is None:
                qop.future.set_result(result)
            else:
                qop.future.set_error(err)
        except Exception as cb_err:  # noqa: BLE001 - user callback bug
            logger.warning("callback for op %d raised: %r",
                           qop.req_id, cb_err)
            self.callback_errors.append((qop.req_id, cb_err))

    def _raise_callback_errors(self, since: int):
        """Drivers fail loudly at exit if any user callback raised during
        the call (matching the pre-concurrent serial loop, where a callback
        exception propagated out of ``step``) — a broken callback means work
        it was about to submit silently never ran."""
        new = self.callback_errors[since:]
        if new:
            req_id, first = new[0]
            raise RuntimeError(
                f"{len(new)} future callback(s) raised during dispatch; "
                f"first: op {req_id} -> {first!r}") from first

    def _finalize(self, qop: api.QueuedOperation):
        """Drop bookkeeping for a finished request (must hold executor.cv).

        O(1): both tables are keyed by req_id — under a deep queue the old
        per-finish list rebuild made finalization O(n) per op."""
        self.pending.pop(qop.req_id, None)
        queue = self.request_queues.get(qop.job_id)
        if queue is not None:
            queue.pop(qop.req_id, None)

    def _reap_poisoned(self) -> List[Tuple[api.QueuedOperation, Exception]]:
        """FAIL every queued task whose prerequisite FAILED (to fixpoint, so
        chains of dependents collapse in one call). Returns the affected
        (qop, error) pairs; callers fire the futures OUTSIDE the lock."""
        out: List[Tuple[api.QueuedOperation, Exception]] = []
        with self.executor.cv:
            # fast path: the full-table scan below is only worth paying
            # after a failure EVENT (a FAILED transition, or a submission
            # under an already-failed prereq) — dispatch calls this every
            # loop, and on a long-lived serve plane "scan forever after the
            # first failure" would grow per-op cost with plane lifetime
            if not self.executor.poison_dirty:
                return out
            changed = True
            while changed:
                changed = False
                for t in list(self.executor.tasks.values()):
                    if t.state != State.QUEUED:
                        continue
                    bad = self.executor.failed_prereqs(t)
                    if not bad:
                        continue
                    cause = self.executor.tasks[bad[0]].error
                    err = RuntimeError(
                        f"prerequisite op {bad[0]} failed: {cause}")
                    self.executor.finish(t, error=str(err))
                    qop = self.pending.get(t.request.req_id)
                    if qop is not None:
                        self._finalize(qop)
                        out.append((qop, err))
                    changed = True
            # fixpoint reached under the lock: nothing QUEUED has a failed
            # prereq until the next failure event sets the flag again
            self.executor.poison_dirty = False
        return out

    def _reap_and_resolve(self) -> None:
        """Reap poisoned tasks and fire their error callbacks under the
        inflight guard: reaping decrements the open-task count under the
        lock, but the error callbacks (which may resubmit work) fire outside
        it — without the guard another dispatch worker could observe
        ``outstanding == 0 and inflight == 0`` in that window, declare idle,
        and exit before the callback's resubmission arrives."""
        ex = self.executor
        with ex.cv:
            poisoned = self._reap_poisoned()
            if not poisoned:
                return
            ex.inflight += 1
        try:
            for qop, err in poisoned:
                self._resolve_future(qop, None, err)
        finally:
            with ex.cv:
                ex.inflight -= 1
                ex.cv.notify_all()

    def _execute_admitted(self, group_id: int, task: Task) -> None:
        """Run one admitted (RUNNING) operation to completion and resolve its
        future. Shared by the serial driver and the per-group dispatch
        threads; the future is resolved OUTSIDE the executor lock so
        callbacks may submit follow-up operations."""
        with self.executor.cv:
            qop = self.pending[task.request.req_id]
            # an op RUNNING when its deployment tore down keeps executing on
            # the pinned backend, so it still completes (and bills) normally
            wpg = self.wpgs.get(qop.deployment_id) or qop.pinned_wpg
        result, err = None, None
        try:
            # dataflow splice: substitute resolved values for future args
            # (their source ops COMPLETED before this op became admissible)
            qop.resolve_args()
            if wpg is None:
                raise RuntimeError(
                    f"deployment {qop.deployment_id} torn down")
            if qop.op not in (api.Op.INIT,):
                self._handle_job_transition(group_id, qop, wpg)
            result = wpg.execute(qop)
        except Exception as e:  # noqa: BLE001 - surface via future
            err = e
        with self.executor.cv:
            self.executor.finish(task, error=None if err is None
                                 else str(err))
            self._finalize(qop)
        self._resolve_future(qop, result, err)

    # ------------------------------------------------------ serial driver
    def step(self, max_ops: int = 1) -> int:
        """Serial driver on the shared admission path: admit + execute up to
        ``max_ops`` operations inline (the back-to-back baseline, and the
        deterministic path under a virtual clock)."""
        if self._serving:
            raise RuntimeError("serial driver unavailable while serve() "
                               "workers own the plane; shutdown() first")
        err_start = len(self.callback_errors)
        executed = 0
        for _ in range(max_ops):
            progressed = False
            for group_id in sorted(set(self.group_of.values())):
                self._reap_and_resolve()
                with self.executor.cv:
                    task = self.executor.pick_next(group_id)
                    started = (task is not None
                               and self.executor.try_start(task))
                if not started:
                    continue
                self._execute_admitted(group_id, task)
                executed += 1
                progressed = True
            if not progressed:
                break
        self._raise_callback_errors(err_start)
        return executed

    def drain(self, max_steps: int = 100_000) -> int:
        total = 0
        for _ in range(max_steps):
            n = self.step()
            if n == 0:
                break
            total += n
        return total

    # ------------------------------------------------ shared worker loop
    def _worker_loop(self, group_id: int, stop: threading.Event,
                     persistent: bool, executed: List[int], slot: int,
                     deadline: Optional[float] = None):
        """One node group's dispatch worker. Fully signal-driven: the ONLY
        blocking point is an untimed wait on the executor's condition
        variable; every state change that could unblock it notifies —
        submit, finish, inflight decrement, idle detection, and the stop
        token — so an idle dispatcher performs zero wakeups between
        submissions.

        ``persistent`` workers (serve mode) park on the cv when the plane
        is idle; bounded workers (run_until_idle) exit instead."""
        ex = self.executor
        while not stop.is_set():
            self._reap_and_resolve()
            task = None
            with ex.cv:
                if stop.is_set():
                    return
                t = ex.pick_next(group_id)
                if t is not None and ex.try_start(t):
                    ex.inflight += 1
                    task = t
                elif (not persistent and ex.outstanding() == 0
                        and ex.inflight == 0):
                    ex.cv.notify_all()
                    return
                else:
                    ex.cv.wait()
                    # woken by a notification: re-run the reap (the wakeup
                    # may have been a FAILED finish) and re-check
                    # stop/idle/admission from the loop top
                    continue
            try:
                self._execute_admitted(group_id, task)
                executed[slot] += 1
            finally:
                with ex.cv:
                    ex.inflight -= 1
                    ex.cv.notify_all()
            if deadline is not None and time.monotonic() > deadline:
                stop.set()
                with ex.cv:
                    ex.cv.notify_all()

    # ------------------------------------------------------- serve plane
    def _ensure_serve_worker(self, group_id: int):
        with self.executor.cv:
            # re-check under the lock: an attach that observed a live plane
            # may race shutdown(); spawning against the already-set stop
            # token would register a dead worker
            if not self._serving or self._serve_stop.is_set():
                return
            if group_id in self._serve_threads:
                return
            counter = self._serve_executed.setdefault(group_id, [0])
            stop = self._serve_stops[group_id] = threading.Event()
            t = threading.Thread(
                target=self._worker_loop,
                args=(group_id, stop, True, counter, 0),
                name=f"serve-g{group_id}", daemon=True)
            self._serve_threads[group_id] = t
        t.start()

    def serve(self):
        """Start the persistent dispatch plane: one parked worker per known
        node group, new groups joining dynamically via
        :meth:`create_deployment`. Returns immediately; pair with
        :meth:`shutdown` (or use as a context manager)."""
        with self.executor.cv:
            if self._serving:
                raise RuntimeError("already serving")
            self._serve_stop = threading.Event()
            self._serve_stops = {}
            self._serve_threads = {}
            self._serve_executed = {}
            self._serve_err_start = len(self.callback_errors)
            self._serving = True
            groups = sorted(set(self.group_of.values()))
        for g in groups:
            self._ensure_serve_worker(g)

    def shutdown(self, timeout: Optional[float] = None):
        """Stop the serve plane: parked workers exit immediately; a worker
        mid-execute finishes its operation first (bounded by ``timeout`` if
        given, after which it is abandoned as a daemon). Raises at the end
        if any user callback raised while serving."""
        if not self._serving:
            return
        self._serve_stop.set()
        with self.executor.cv:
            for stop in self._serve_stops.values():
                stop.set()
            self.executor.cv.notify_all()
        deadline = None if timeout is None else time.monotonic() + timeout
        for t in self._serve_threads.values():
            t.join(timeout=None if deadline is None
                   else max(0.0, deadline - time.monotonic()))
        leaked = [t for t in self._serve_threads.values() if t.is_alive()]
        with self.executor.cv:
            self._serving = False
            self._serve_threads = {}
            self._serve_stops = {}
            self._abandoned.extend(leaked)
        for t in leaked:
            logger.warning(
                "serve worker %s still hung in execute at shutdown; "
                "abandoned as a daemon (see abandoned_workers())", t.name)
        self._raise_callback_errors(self._serve_err_start)

    def __enter__(self) -> "Router":
        self.serve()
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    @property
    def serving(self) -> bool:
        return self._serving

    def serve_executed(self) -> int:
        """Operations executed by the current/last serve plane."""
        return sum(c[0] for c in self._serve_executed.values())

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until nothing is queued, running, or firing callbacks.
        Usable from any client thread against a live serve plane.
        Returns True once the plane quiesced, False if ``timeout`` elapsed
        first (the caller distinguishes quiesced from timed-out)."""
        ex = self.executor
        with ex.cv:
            return ex.cv.wait_for(
                lambda: ex.outstanding() == 0 and ex.inflight == 0, timeout)

    def abandoned_workers(self) -> List[str]:
        """Names of dispatch workers abandoned while hung in ``execute``
        (bounded drivers give up after their grace; the threads are daemons
        and exit when their op finally returns — entries self-prune here)."""
        with self.executor.cv:
            self._abandoned = [t for t in self._abandoned if t.is_alive()]
            return [t.name for t in self._abandoned]

    # ------------------------------------------------------- process plane
    def process_health(self) -> Dict[int, bool]:
        """group id -> worker-process liveness (process mode; empty dict in
        thread mode)."""
        return {gid: gp.alive() for gid, gp in self.group_procs.items()}

    def respawn_dead_groups(self) -> List[int]:
        """Respawn every dead group worker process in place (deployments
        replayed; managed state lost — device-failure semantics). Called by
        the capacity adjuster each poll; returns the respawned group ids.
        A no-op in thread mode, so VirtualClock replay never sees it.
        Each respawn first reaps the dead incarnation's in-flight shm
        segments (by name prefix) and sweeps its orphaned ``export__*``
        migration spill files, so a crash-looping group never accretes
        ``/dev/shm`` or ``/tmp`` residue (see ``GroupProcess.respawn``)."""
        respawned: List[int] = []
        for gid, gp in list(self.group_procs.items()):
            if not gp.alive():
                logger.warning("group %d worker process died (exitcode %s); "
                               "respawning", gid,
                               None if gp._proc is None else gp._proc.exitcode)
                gp.respawn()
                respawned.append(gid)
        return respawned

    def close_processes(self, timeout: float = 10.0):
        """Shut down every group worker process (graceful protocol shutdown,
        escalating to terminate). Benches/tests call this at exit; children
        are daemons, so an unclosed plane still dies with the parent."""
        for gp in self.group_procs.values():
            gp.shutdown(timeout=timeout)
        self.group_procs.clear()

    # ------------------------------------------- group lifecycle / telemetry
    def known_groups(self) -> List[int]:
        with self.executor.cv:
            return sorted(set(self.group_of.values())
                          | set(self.state_managers)
                          | set(self._serve_threads))

    def ensure_group(self, group_id: int) -> StateManager:
        """Register a node group with the control plane (capacity-adjustment
        spawn, §4.4): its StateManager exists from here on, and while serving
        a dispatch worker is spawned so deployments placed on it are admitted
        the moment they arrive."""
        with self.executor.cv:
            sm = self._group_sm(group_id)
            serving = self._serving
        if serving:
            self._ensure_serve_worker(group_id)
        return sm

    def retire_group(self, group_id: int, timeout: float = 30.0):
        """Capacity-adjustment retire: tear down one group's dispatch worker
        (symmetric to the dynamic spawn in :meth:`create_deployment`) and
        forget its scheduling state. Refuses while the group still hosts
        deployments or open tasks."""
        ex = self.executor
        with ex.cv:
            live = [d for d, g in self.group_of.items() if g == group_id]
            if live:
                raise RuntimeError(
                    f"group {group_id} still hosts deployments {live}")
            stuck = [t.request.req_id for t in ex.tasks.values()
                     if t.group_id == group_id
                     and t.state in (State.QUEUED, State.RUNNING)]
            if stuck:
                raise RuntimeError(
                    f"group {group_id} still has open tasks {stuck}")
            t = self._serve_threads.pop(group_id, None)
            stop = self._serve_stops.pop(group_id, None)
            if stop is not None:
                stop.set()
            ex.cv.notify_all()
        if t is not None:
            t.join(timeout=timeout)
            if t.is_alive():
                raise RuntimeError(
                    f"group {group_id} worker did not exit in {timeout}s")
        try:
            ex.drop_group(group_id)
        except RuntimeError:
            # an attach raced the teardown and submitted work: put the
            # dispatch worker back so the new deployment is not stranded
            if self._serving:
                self._ensure_serve_worker(group_id)
            raise
        gp = None
        with ex.cv:
            # re-check under the lock: an attach that raced past drop_group
            # owns the group again — leave its (empty) StateManager alone
            if not any(g == group_id for g in self.group_of.values()):
                sm = self.state_managers.get(group_id)
                if sm is not None and not sm.entries:
                    del self.state_managers[group_id]
                    # return the group's mesh-slice lease to the plane
                    self.device_plane.release(group_id)
                    gp = self.group_procs.pop(group_id, None)
        if gp is not None:          # outside the cv: shutdown joins the child
            gp.shutdown()

    def group_telemetry(self) -> Dict[int, dict]:
        """Per-group queue-depth / occupancy snapshot (the §4.4 capacity
        adjuster's input). Keys: queue_depth (QUEUED ops), running (op
        currently holding the group lock), busy_seconds (cumulative measured
        execution), resident_job, deployments, worker (live serve thread)."""
        ex = self.executor
        with ex.cv:
            groups = (set(self.group_of.values()) | set(self.state_managers)
                      | set(self._serve_threads))
            out: Dict[int, dict] = {}
            for g in sorted(groups):
                lock = ex.locks.get(g)
                out[g] = {
                    "queue_depth": ex.queued_count.get(g, 0),
                    "running": bool(lock and lock.holder is not None),
                    "busy_seconds": ex.group_busy.get(g, 0.0),
                    "resident_job": ex.resident_job.get(g),
                    "deployments": sorted(
                        d for d, gg in self.group_of.items() if gg == g),
                    "worker": g in self._serve_threads,
                }
        if self.process_plane:
            for g, d in out.items():
                gp = self.group_procs.get(g)
                d["process_alive"] = bool(gp is not None and gp.alive())
        return out

    def tenant_telemetry(self) -> Dict[str, dict]:
        """Per-tenant service snapshot alongside :meth:`group_telemetry`.

        Plane-derived keys (always present): queue_depth (QUEUED ops across
        the tenant's jobs), running (ops currently executing), jobs, groups
        (distinct node groups hosting the tenant's deployments). When a
        :class:`~repro.core.tenancy.TenantLedger` is wired (Cluster does),
        its accounting snapshot is merged in: gpu_seconds, steps_total,
        slo_attainment, step_p95_s, pending_jobs."""
        ex = self.executor
        out: Dict[str, dict] = {}

        def slot(tenant: str) -> dict:
            return out.setdefault(tenant, {
                "queue_depth": 0, "running": 0,
                "jobs": set(), "groups": set()})

        with ex.cv:
            for t in ex.tasks.values():
                if t.state not in (State.QUEUED, State.RUNNING):
                    continue
                tenant = self.job_tenant.get(t.request.job_id, "default")
                s = slot(tenant)
                if t.state == State.QUEUED:
                    s["queue_depth"] += 1
                else:
                    s["running"] += 1
            for dep_id, spec in self.deployments.items():
                tenant = self.job_tenant.get(spec.job_id, "default")
                s = slot(tenant)
                s["jobs"].add(spec.job_id)
                s["groups"].add(self.group_of[dep_id])
            for job_id, tenant in self.job_tenant.items():
                slot(tenant)["jobs"].add(job_id)
        ledger = self.tenant_ledger
        if ledger is not None:
            for tenant, acct in ledger.snapshot().items():
                slot(tenant).update(acct)
        for s in out.values():
            s["jobs"] = sorted(s["jobs"])
            s["groups"] = sorted(s["groups"])
        return out

    # ------------------------------------------------- elastic re-placement
    def migrate_job(self, job_id: str, src_group: int, dst_group: int) -> int:
        """Move a job's managed state across groups (paper §4.5.3). Callers
        quiesce + admission-hold the job first (see :meth:`reassign_job`).
        The bulk byte copy runs OUTSIDE the executor lock — a multi-GB
        migration must not stall dispatch on every other group — which is
        safe because the held job's entries are not unregistered by anyone
        (a concurrent switch may at worst offload them tier-wise, and
        ``StateManager.migrate`` reads either tier consistently); only the
        map swaps (wpg.sm, group_of, resident flag) take the lock.

        The realized cost (reshard included, measured via ``self.now``) is
        appended to :attr:`migrate_log`, which the PlacementDirector drains
        to calibrate its migration-cost floors (same-mesh vs cross-mesh)."""
        with self.executor.cv:
            src = self.state_managers[src_group]
            dst = self._group_sm(dst_group)
            targets = [(d, w) for d, w in self.wpgs.items()
                       if w.spec.job_id == job_id]
        t0 = self.now()
        moved = 0
        cross = False
        for _, wpg in targets:
            moved += src.migrate(wpg.job_prefix, dst)
            if src.last_migrate is not None:
                cross = cross or bool(src.last_migrate.get("cross_mesh"))
        dt = self.now() - t0
        with self.executor.cv:
            for dep_id, wpg in targets:
                wpg.sm = dst
                self.group_of[dep_id] = dst_group
            if self.executor.resident_job.get(src_group) == job_id:
                self.executor.resident_job[src_group] = None
            self.migrate_log.append({
                "job": job_id, "src": src_group, "dst": dst_group,
                "bytes": moved, "seconds": dt, "cross_mesh": cross,
                "t": self.now()})
            if len(self.migrate_log) > 1024:
                del self.migrate_log[:len(self.migrate_log) - 1024]
        return moved

    def reassign_job(self, job_id: str, dst_group: int,
                     timeout: float = 120.0) -> int:
        """Realize a re-placement decision against the live plane: hold the
        job's admissions, wait for its RUNNING ops to drain, migrate managed
        state, re-home its queued ops onto the destination group, release.
        Billing continuity is free — exec logs live on the WPGs (which
        survive) and the billing cursors are keyed by deployment id."""
        ex = self.executor
        ex.hold_job(job_id)
        try:
            with ex.cv:
                ok = ex.cv.wait_for(lambda: not ex.job_running(job_id),
                                    timeout)
            if not ok:
                raise TimeoutError(
                    f"job {job_id} did not quiesce within {timeout}s")
            with ex.cv:
                src_groups = {g for d, g in self.group_of.items()
                              if self.deployments[d].job_id == job_id}
            moved = 0
            for src in src_groups:
                if src != dst_group:
                    moved += self.migrate_job(job_id, src, dst_group)
            if self._serving:
                self._ensure_serve_worker(dst_group)
            ex.rehome_job(job_id, dst_group)
        finally:
            ex.release_job(job_id)
        return moved

    def reassign_jobs(self, moves, timeout: float = 120.0) -> List[tuple]:
        """Realize a batched migration plan (the §4.3.2 repack loop's
        output): each move runs through the :meth:`reassign_job`
        hold → drain → migrate → rehome path, in *dependency order* —
        a move INTO a group is executed after moves OUT of it
        (vacate-before-fill), so a swap never transiently double-books a
        destination. A cyclic batch (pure swap) is broken deterministically
        at the lowest job id; group residency is time-multiplexed, so the
        one overlapping tenancy that creates is safe.

        A failing move is captured in its result slot and the remaining
        moves still execute: the plan is realized partially, but every
        executed move is complete and consistent (the caller rolls the
        failed job's *placement* back). Returns ``(move, moved_bytes,
        error)`` tuples in execution order; ``moves`` may be any objects
        with ``job_id`` / ``src_group`` / ``dst_group`` attributes (e.g.
        :class:`~repro.core.scheduler.placement.JobMove`)."""
        remaining = sorted(moves, key=lambda m: m.job_id)
        ordered = []
        while remaining:
            pick = None
            for m in remaining:
                if not any(o.src_group == m.dst_group
                           for o in remaining if o is not m):
                    pick = m
                    break
            if pick is None:           # cycle: every dst is someone's src
                pick = remaining[0]
            remaining.remove(pick)
            ordered.append(pick)
        results: List[tuple] = []
        for m in ordered:
            try:
                moved = self.reassign_job(m.job_id, m.dst_group,
                                          timeout=timeout)
                results.append((m, moved, None))
            except Exception as e:  # noqa: BLE001 - per-move isolation
                results.append((m, 0, e))
        return results

    # -------------------------------------------------- bounded driver
    def run_until_idle(self, timeout: Optional[float] = None) -> int:
        """A bounded session of the dispatch plane: the same per-group
        worker loop as :meth:`serve`, but workers exit once no operation
        is queued, running, or firing callbacks. Returns the number of
        operations executed.

        ``timeout`` (wall-clock seconds) bounds the whole call; on expiry a
        ``TimeoutError`` is raised with the stuck operations listed. A worker
        blocked INSIDE ``wpg.execute`` cannot be interrupted — after a 1 s
        grace it is abandoned as a daemon thread so the bound still holds.
        """
        if self._serving:
            raise RuntimeError("run_until_idle unavailable while serve() "
                               "workers own the plane; shutdown() first")
        groups = sorted(set(self.group_of.values()))
        if not groups:
            return 0
        err_start = len(self.callback_errors)
        deadline = None if timeout is None else time.monotonic() + timeout
        executed = [0] * len(groups)
        timed_out = threading.Event()
        ex = self.executor

        threads = [threading.Thread(
            target=self._worker_loop,
            args=(g, timed_out, False, executed, i, deadline),
            name=f"dispatch-g{g}", daemon=True)
            for i, g in enumerate(groups)]
        for t in threads:
            t.start()
        for t in threads:
            while t.is_alive():
                if deadline is None:
                    t.join()
                    continue
                remaining = deadline - time.monotonic()
                if remaining > 0 and not timed_out.is_set():
                    # sleep exactly until the deadline (or thread exit) —
                    # workers park on the cv and need no supervision
                    t.join(timeout=remaining)
                    continue
                if not timed_out.is_set():
                    timed_out.set()
                    with ex.cv:
                        ex.cv.notify_all()
                # shutdown signalled: workers parked on the cv exit
                # immediately; one stuck INSIDE wpg.execute (threads cannot
                # be killed) gets a 1 s grace, then is abandoned (daemon) so
                # the timeout still bounds this call — reported below
                t.join(timeout=max(0.0, deadline + 1.0 - time.monotonic()))
                if t.is_alive():
                    # the abandon used to drop the handle on the floor: a
                    # WPG hung in execute leaked its worker invisibly, and
                    # shutdown() had nothing to report. Track it.
                    with ex.cv:
                        self._abandoned.append(t)
                    logger.warning(
                        "dispatch worker %s hung in execute past the "
                        "abandon grace; leaked as a daemon (see "
                        "abandoned_workers())", t.name)
                break
        if timed_out.is_set():
            with ex.cv:
                stuck = [t.request.req_id for t in ex.tasks.values()
                         if t.state in (State.QUEUED, State.RUNNING)]
            # the deadline may have lapsed while the LAST op was finishing;
            # only a run that left work behind is an actual timeout
            if stuck:
                raise TimeoutError(
                    f"run_until_idle exceeded {timeout}s; "
                    f"stuck ops: {stuck}")
        self._raise_callback_errors(err_start)
        return sum(executed)
