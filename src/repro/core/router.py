"""Stateless Router: control-plane entry point of the execution service.

§5.1: the Router maps logical deployment ids to WPGs, submits every incoming
operation to the Scheduler for admission, and only then dispatches it. It
owns deployment lifecycle (create / init / teardown) and the automatic
context-switch logic (§5.2.2 ``_handle_job_transition``): when an admitted
operation targets a different job than the one resident on the target group,
offload+load operations are prepended transparently.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.core import api
from repro.core.scheduler import hrrs
from repro.core.scheduler.executor import State, Task, TaskExecutor
from repro.core.state_manager import StateManager, Tier
from repro.core.worker import WorkerProcessGroup


class Router:
    def __init__(self, now: Callable[[], float] = time.monotonic,
                 policy: str = "hrrs"):
        self.now = now
        self.wpgs: Dict[str, WorkerProcessGroup] = {}
        self.deployments: Dict[str, api.DeploymentSpec] = {}
        self.group_of: Dict[str, int] = {}       # deployment -> node group
        self.state_managers: Dict[int, StateManager] = {}
        self.executor = TaskExecutor(now=now, policy=policy)
        self.request_queues: Dict[str, List[api.QueuedOperation]] = {}
        self.pending: Dict[int, api.QueuedOperation] = {}
        self.switch_log: List[dict] = []

    # ----------------------------------------------------------- lifecycle
    def create_deployment(self, spec: api.DeploymentSpec, group_id: int = 0,
                          state_manager: Optional[StateManager] = None
                          ) -> WorkerProcessGroup:
        sm = state_manager or self.state_managers.setdefault(
            group_id, StateManager(node_id=f"group{group_id}"))
        self.state_managers[group_id] = sm
        wpg = WorkerProcessGroup(spec, sm)
        self.wpgs[spec.deployment_id] = wpg
        self.deployments[spec.deployment_id] = spec
        self.group_of[spec.deployment_id] = group_id
        self.request_queues.setdefault(spec.job_id, [])
        return wpg

    def teardown(self, deployment_id: str):
        wpg = self.wpgs.pop(deployment_id, None)
        if wpg is not None:
            wpg.sm.unregister(wpg.sm.keys_for(wpg.job_prefix))
        self.deployments.pop(deployment_id, None)
        self.group_of.pop(deployment_id, None)

    # -------------------------------------------------------------- submit
    def submit_queued_operation(self, qop: api.QueuedOperation) -> api.Future:
        """Non-blocking API handler (§5.2.2): wrap + enqueue, return at once."""
        qop.arrival_time = self.now()
        self.request_queues[qop.job_id].append(qop)
        req = hrrs.Request(req_id=qop.req_id, job_id=qop.job_id,
                           op=qop.op.value, exec_time=qop.exec_estimate,
                           arrival_time=qop.arrival_time, payload=qop)
        group = self.group_of[qop.deployment_id]
        self.executor.submit(req, group, prerequisites=qop.prerequisites)
        self.pending[qop.req_id] = qop
        return qop.future

    # ------------------------------------------------------------ dispatch
    def _handle_job_transition(self, group_id: int, qop: api.QueuedOperation):
        """Automatic context switching: if the group's resident job differs,
        prepend offload(current) + load(target)."""
        sm = self.state_managers[group_id]
        target_wpg = self.wpgs[qop.deployment_id]
        resident = [d for d, g in self.group_of.items()
                    if g == group_id and d != qop.deployment_id
                    and self.wpgs[d].resident()
                    and self.wpgs[d].spec.job_id != qop.job_id]
        t_off = 0.0
        for dep in resident:
            t_off += self.wpgs[dep].offload(Tier.HOST)
        t_load = target_wpg.ensure_resident()
        if resident or t_load > 0:
            self.switch_log.append({
                "t": self.now(), "group": group_id, "to_job": qop.job_id,
                "t_offload": t_off, "t_load": t_load})
        # feed measured setup costs back into HRRS
        nbytes = sm.job_bytes(target_wpg.job_prefix)
        self.executor.t_load = sm.load_time_estimate(nbytes)
        self.executor.t_offload = sm.offload_time_estimate(nbytes)

    def step(self, max_ops: int = 1) -> int:
        """Drive the control loop: admit + execute up to max_ops operations
        (serially — the single-process analogue of concurrent WPGs)."""
        executed = 0
        for _ in range(max_ops):
            progressed = False
            for group_id in sorted(set(self.group_of.values())):
                task = self.executor.pick_next(group_id)
                if task is None or not self.executor.try_start(task):
                    continue
                qop = self.pending[task.request.req_id]
                if qop.op not in (api.Op.INIT,):
                    self._handle_job_transition(group_id, qop)
                try:
                    result = self.wpgs[qop.deployment_id].execute(qop)
                    self.executor.finish(task, result=result)
                    qop.future.set_result(result)
                except Exception as e:  # noqa: BLE001 - surface via future
                    self.executor.finish(task, error=str(e))
                    qop.future.set_error(e)
                self.request_queues[qop.job_id] = [
                    q for q in self.request_queues[qop.job_id]
                    if q.req_id != qop.req_id]
                executed += 1
                progressed = True
            if not progressed:
                break
        return executed

    def drain(self, max_steps: int = 100_000) -> int:
        total = 0
        for _ in range(max_steps):
            n = self.step()
            if n == 0:
                break
            total += n
        return total
