"""Stateless Router: control-plane entry point of the execution service.

§5.1: the Router maps logical deployment ids to WPGs, submits every incoming
operation to the Scheduler for admission, and only then dispatches it. It
owns deployment lifecycle (create / init / teardown) and the automatic
context-switch logic (§5.2.2 ``_handle_job_transition``): when an admitted
operation targets a different job than the one resident on the target group,
offload+load operations are prepended transparently.

Dispatch plane
--------------
Two drivers share ONE admission path (HRRS scoring + lock-gated start in
``TaskExecutor``):

- :meth:`run_until_idle` — the concurrent, event-driven plane: one worker
  thread per node group blocks on the executor's condition variable, admits
  the group's next operation the moment the group frees up, and executes it
  while other groups run their own operations in parallel (per-group
  ordering is preserved by the exclusive ``GroupLock``; per-WPG execution
  stays serial). This is what lets job A's rollout overlap job B's training
  functions — the multiplexing the paper's §5.1/§5.2 design exists for.
- :meth:`step` / :meth:`drain` — the serial analogue on the same admission
  path, used for the back-to-back baseline and for deterministic replay
  under a :class:`~repro.core.scheduler.executor.VirtualClock`.

Failure propagation: an operation that raises resolves its future with the
error, and any queued operation whose prerequisite FAILED is itself failed
("poisoned") instead of waiting forever, so both drivers always terminate.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import api
from repro.core.scheduler import hrrs
from repro.core.scheduler.executor import State, Task, TaskExecutor
from repro.core.state_manager import StateManager, Tier
from repro.core.worker import WorkerProcessGroup

logger = logging.getLogger(__name__)


class Router:
    def __init__(self, now: Callable[[], float] = time.monotonic,
                 policy: str = "hrrs",
                 wpg_factory: Callable[..., object] = WorkerProcessGroup):
        self.now = now
        self.wpgs: Dict[str, object] = {}
        self.deployments: Dict[str, api.DeploymentSpec] = {}
        self.group_of: Dict[str, int] = {}       # deployment -> node group
        self.state_managers: Dict[int, StateManager] = {}
        self.executor = TaskExecutor(now=now, policy=policy)
        self.request_queues: Dict[str, List[api.QueuedOperation]] = {}
        self.pending: Dict[int, api.QueuedOperation] = {}
        self.switch_log: List[dict] = []
        self.wpg_factory = wpg_factory
        # exceptions raised by user callbacks during future resolution; a
        # broken callback must not kill a dispatch thread mid-protocol
        self.callback_errors: List[Tuple[int, BaseException]] = []

    # ----------------------------------------------------------- lifecycle
    def create_deployment(self, spec: api.DeploymentSpec, group_id: int = 0,
                          state_manager: Optional[StateManager] = None):
        sm = state_manager or self.state_managers.setdefault(
            group_id, StateManager(node_id=f"group{group_id}"))
        self.state_managers[group_id] = sm
        wpg = self.wpg_factory(spec, sm)
        self.wpgs[spec.deployment_id] = wpg
        self.deployments[spec.deployment_id] = spec
        self.group_of[spec.deployment_id] = group_id
        self.request_queues.setdefault(spec.job_id, [])
        return wpg

    def teardown(self, deployment_id: str):
        wpg = self.wpgs.pop(deployment_id, None)
        if wpg is not None:
            wpg.sm.unregister(wpg.sm.keys_for(wpg.job_prefix))
        self.deployments.pop(deployment_id, None)
        self.group_of.pop(deployment_id, None)

    # -------------------------------------------------------------- submit
    def submit_queued_operation(self, qop: api.QueuedOperation) -> api.Future:
        """Non-blocking API handler (§5.2.2): wrap + enqueue, return at once.

        Thread-safe: future callbacks submit follow-up operations from
        dispatch worker threads while the controller submits from its own.
        """
        with self.executor.cv:
            qop.arrival_time = self.now()
            self.request_queues.setdefault(qop.job_id, []).append(qop)
            req = hrrs.Request(req_id=qop.req_id, job_id=qop.job_id,
                               op=qop.op.value, exec_time=qop.exec_estimate,
                               arrival_time=qop.arrival_time, payload=qop)
            group = self.group_of[qop.deployment_id]
            self.executor.submit(req, group,
                                 prerequisites=qop.prerequisites)
            self.pending[qop.req_id] = qop
        return qop.future

    # ------------------------------------------------------------ dispatch
    def _handle_job_transition(self, group_id: int, qop: api.QueuedOperation):
        """Automatic context switching: if the group's resident job differs,
        prepend offload(current) + load(target)."""
        sm = self.state_managers[group_id]
        target_wpg = self.wpgs[qop.deployment_id]
        resident = [d for d, g in self.group_of.items()
                    if g == group_id and d != qop.deployment_id
                    and self.wpgs[d].resident()
                    and self.wpgs[d].spec.job_id != qop.job_id]
        t_off = 0.0
        for dep in resident:
            t_off += self.wpgs[dep].offload(Tier.HOST)
        t_load = target_wpg.ensure_resident()
        if resident or t_load > 0:
            with self.executor.cv:
                self.switch_log.append({
                    "t": self.now(), "group": group_id, "to_job": qop.job_id,
                    "t_offload": t_off, "t_load": t_load})
        # feed measured setup costs back into HRRS (per group: concurrent
        # groups switch independently)
        nbytes = sm.job_bytes(target_wpg.job_prefix)
        self.executor.set_setup_costs(group_id,
                                      sm.load_time_estimate(nbytes),
                                      sm.offload_time_estimate(nbytes))

    def _resolve_future(self, qop: api.QueuedOperation, result,
                        err: Optional[BaseException]):
        try:
            if err is None:
                qop.future.set_result(result)
            else:
                qop.future.set_error(err)
        except Exception as cb_err:  # noqa: BLE001 - user callback bug
            logger.warning("callback for op %d raised: %r",
                           qop.req_id, cb_err)
            self.callback_errors.append((qop.req_id, cb_err))

    def _raise_callback_errors(self, since: int):
        """Drivers fail loudly at exit if any user callback raised during
        the call (matching the pre-concurrent serial loop, where a callback
        exception propagated out of ``step``) — a broken callback means work
        it was about to submit silently never ran."""
        new = self.callback_errors[since:]
        if new:
            req_id, first = new[0]
            raise RuntimeError(
                f"{len(new)} future callback(s) raised during dispatch; "
                f"first: op {req_id} -> {first!r}") from first

    def _finalize(self, qop: api.QueuedOperation):
        """Drop bookkeeping for a finished request (must hold executor.cv).

        Popping ``pending`` here is what bounds memory over long runs — the
        previous control loop only ever read it."""
        self.pending.pop(qop.req_id, None)
        queue = self.request_queues.get(qop.job_id)
        if queue is not None:
            self.request_queues[qop.job_id] = [
                q for q in queue if q.req_id != qop.req_id]

    def _reap_poisoned(self) -> List[Tuple[api.QueuedOperation, Exception]]:
        """FAIL every queued task whose prerequisite FAILED (to fixpoint, so
        chains of dependents collapse in one call). Returns the affected
        (qop, error) pairs; callers fire the futures OUTSIDE the lock."""
        out: List[Tuple[api.QueuedOperation, Exception]] = []
        with self.executor.cv:
            # fast path: the full-table scan below is only worth paying once
            # some task has actually FAILED (dispatch calls this every loop)
            if not self.executor.failed_count:
                return out
            changed = True
            while changed:
                changed = False
                for t in list(self.executor.tasks.values()):
                    if t.state != State.QUEUED:
                        continue
                    bad = self.executor.failed_prereqs(t)
                    if not bad:
                        continue
                    cause = self.executor.tasks[bad[0]].error
                    err = RuntimeError(
                        f"prerequisite op {bad[0]} failed: {cause}")
                    self.executor.finish(t, error=str(err))
                    qop = self.pending.get(t.request.req_id)
                    if qop is not None:
                        self._finalize(qop)
                        out.append((qop, err))
                    changed = True
        return out

    def _reap_and_resolve(self) -> None:
        """Reap poisoned tasks and fire their error callbacks under the
        inflight guard: reaping decrements the open-task count under the
        lock, but the error callbacks (which may resubmit work) fire outside
        it — without the guard another dispatch worker could observe
        ``outstanding == 0 and inflight == 0`` in that window, declare idle,
        and exit before the callback's resubmission arrives."""
        ex = self.executor
        with ex.cv:
            poisoned = self._reap_poisoned()
            if not poisoned:
                return
            ex.inflight += 1
        try:
            for qop, err in poisoned:
                self._resolve_future(qop, None, err)
        finally:
            with ex.cv:
                ex.inflight -= 1
                ex.cv.notify_all()

    def _execute_admitted(self, group_id: int, task: Task) -> None:
        """Run one admitted (RUNNING) operation to completion and resolve its
        future. Shared by the serial driver and the per-group dispatch
        threads; the future is resolved OUTSIDE the executor lock so
        callbacks may submit follow-up operations."""
        with self.executor.cv:
            qop = self.pending[task.request.req_id]
        result, err = None, None
        try:
            if qop.op not in (api.Op.INIT,):
                self._handle_job_transition(group_id, qop)
            result = self.wpgs[qop.deployment_id].execute(qop)
        except Exception as e:  # noqa: BLE001 - surface via future
            err = e
        with self.executor.cv:
            self.executor.finish(task, error=None if err is None
                                 else str(err))
            self._finalize(qop)
        self._resolve_future(qop, result, err)

    # ------------------------------------------------------ serial driver
    def step(self, max_ops: int = 1) -> int:
        """Serial driver on the shared admission path: admit + execute up to
        ``max_ops`` operations inline (the back-to-back baseline, and the
        deterministic path under a virtual clock)."""
        err_start = len(self.callback_errors)
        executed = 0
        for _ in range(max_ops):
            progressed = False
            for group_id in sorted(set(self.group_of.values())):
                self._reap_and_resolve()
                with self.executor.cv:
                    task = self.executor.pick_next(group_id)
                    started = (task is not None
                               and self.executor.try_start(task))
                if not started:
                    continue
                self._execute_admitted(group_id, task)
                executed += 1
                progressed = True
            if not progressed:
                break
        self._raise_callback_errors(err_start)
        return executed

    def drain(self, max_steps: int = 100_000) -> int:
        total = 0
        for _ in range(max_steps):
            n = self.step()
            if n == 0:
                break
            total += n
        return total

    # -------------------------------------------------- concurrent driver
    def run_until_idle(self, timeout: Optional[float] = None) -> int:
        """Event-driven concurrent dispatch: one worker thread per node
        group. Each worker blocks on the executor's condition variable,
        admits its group's next operation as soon as the group frees up
        (per-WPG ordering preserved by the exclusive GroupLock), and runs it
        while other groups execute concurrently. Returns once no operation
        is queued, running, or firing callbacks.

        ``timeout`` (wall-clock seconds) bounds the whole call; on expiry a
        ``TimeoutError`` is raised with the stuck operations listed. A worker
        blocked INSIDE ``wpg.execute`` cannot be interrupted — after a 1 s
        grace it is abandoned as a daemon thread so the bound still holds.
        """
        groups = sorted(set(self.group_of.values()))
        if not groups:
            return 0
        err_start = len(self.callback_errors)
        deadline = None if timeout is None else time.monotonic() + timeout
        executed = [0] * len(groups)
        timed_out = threading.Event()
        ex = self.executor

        def idle() -> bool:
            # under ex.cv: nothing queued/running anywhere AND no worker is
            # between finish() and its future's callbacks (which may submit)
            return ex.outstanding() == 0 and ex.inflight == 0

        def worker(slot: int, group_id: int):
            # Fully signal-driven: the ONLY blocking point is an untimed
            # wait on the executor's condition variable. Every state change
            # that could unblock a worker notifies it — submit, finish,
            # inflight decrement, idle detection, and the shutdown token
            # (timed_out) — so an idle dispatcher performs zero wakeups
            # between submissions (PR 1 used a 50 ms guard timeout here).
            while not timed_out.is_set():
                self._reap_and_resolve()
                task = None
                with ex.cv:
                    t = ex.pick_next(group_id)
                    if t is not None and ex.try_start(t):
                        ex.inflight += 1
                        task = t
                    elif idle():
                        ex.cv.notify_all()
                        return
                    else:
                        ex.cv.wait()
                        # woken by a notification: re-run the reap (the
                        # wakeup may have been a FAILED finish) and re-check
                        # shutdown/idle/admission from the loop top
                        continue
                try:
                    self._execute_admitted(group_id, task)
                    executed[slot] += 1
                finally:
                    with ex.cv:
                        ex.inflight -= 1
                        ex.cv.notify_all()
                if deadline is not None and time.monotonic() > deadline:
                    timed_out.set()
                    with ex.cv:
                        ex.cv.notify_all()

        def signal_shutdown():
            timed_out.set()
            with ex.cv:
                ex.cv.notify_all()

        threads = [threading.Thread(target=worker, args=(i, g),
                                    name=f"dispatch-g{g}", daemon=True)
                   for i, g in enumerate(groups)]
        for t in threads:
            t.start()
        for t in threads:
            while t.is_alive():
                if deadline is None:
                    t.join()
                    continue
                remaining = deadline - time.monotonic()
                if remaining > 0 and not timed_out.is_set():
                    # sleep exactly until the deadline (or thread exit) —
                    # workers park on the cv and need no supervision
                    t.join(timeout=remaining)
                    continue
                if not timed_out.is_set():
                    signal_shutdown()
                # shutdown signalled: workers parked on the cv exit
                # immediately; one stuck INSIDE wpg.execute (threads cannot
                # be killed) gets a 1 s grace, then is abandoned (daemon) so
                # the timeout still bounds this call — reported below
                t.join(timeout=max(0.0, deadline + 1.0 - time.monotonic()))
                break
        if timed_out.is_set():
            with ex.cv:
                stuck = [t.request.req_id for t in ex.tasks.values()
                         if t.state in (State.QUEUED, State.RUNNING)]
            # the deadline may have lapsed while the LAST op was finishing;
            # only a run that left work behind is an actual timeout
            if stuck:
                raise TimeoutError(
                    f"run_until_idle exceeded {timeout}s; "
                    f"stuck ops: {stuck}")
        self._raise_callback_errors(err_start)
        return sum(executed)
