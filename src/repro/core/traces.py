"""Demand-trace profiling and synthetic trace generation.

A job's *trace* is its periodic execution signature: the per-cycle phase
durations (rollout / compute_log_prob / update_actor / sync_weight) plus the
node demand of each phase. Cold-start jobs run isolated while the profiler
records one clean cycle (paper §4.3.2); warm-start jobs are placed by trace
fitting.

``paper_table2_trace`` reproduces the measured cycle anatomy of Table 2
(7B / 30B / 235B), including the 70-81 % bubble ratios; synthetic traces add
long-tail jitter from the tool-stall model (§2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler.placement import JobTrace

# Table 2 (seconds): cycle time and active-phase anatomy.
PAPER_TABLE2 = {
    "7B": {"cycle": 289.03, "compute_log_prob": 9.66, "update_actor": 38.08,
           "sync_weight": 9.76},
    "30B": {"cycle": 284.80, "compute_log_prob": 19.62, "update_actor": 56.35,
            "sync_weight": 7.57},
    "235B": {"cycle": 589.71, "compute_log_prob": 20.11, "update_actor": 82.39,
             "sync_weight": 8.89},
}


def bubble_ratio(entry: Dict[str, float]) -> float:
    """Fraction of the cycle in which the training pool is idle (Tab. 2)."""
    active = (entry["compute_log_prob"] + entry["update_actor"]
              + entry["sync_weight"])
    return 1.0 - active / entry["cycle"]


def paper_table2_trace(size: str, nodes: int = 1) -> JobTrace:
    """JobTrace of the TRAINING pool for a Table-2 job: active segments are
    logprob + update + sync back-to-back after the rollout gap."""
    e = PAPER_TABLE2[size]
    rollout_gap = e["cycle"] - (e["compute_log_prob"] + e["update_actor"]
                                + e["sync_weight"])
    t = rollout_gap
    segs: List[Tuple[float, float]] = []
    for phase in ("compute_log_prob", "update_actor", "sync_weight"):
        segs.append((t, e[phase]))
        t += e[phase]
    return JobTrace(period=e["cycle"], segments=tuple(segs), nodes=nodes)


@dataclasses.dataclass
class PhaseProfile:
    """Mean/σ per phase; sampling yields one cycle's realised durations."""
    rollout_mean: float
    rollout_tail_sigma: float           # lognormal sigma of the tool tail
    logprob: float
    update: float
    sync: float
    nodes: int = 1

    def sample_cycle(self, rng: np.random.Generator) -> Dict[str, float]:
        tail = rng.lognormal(0.0, self.rollout_tail_sigma)
        return {
            "rollout": self.rollout_mean * max(0.25, tail),
            "compute_log_prob": self.logprob * rng.uniform(0.9, 1.1),
            "update_actor": self.update * rng.uniform(0.95, 1.05),
            "sync_weight": self.sync * rng.uniform(0.9, 1.1),
        }

    def mean_trace(self) -> JobTrace:
        t = self.rollout_mean
        segs = [(t, self.logprob), (t + self.logprob, self.update),
                (t + self.logprob + self.update, self.sync)]
        period = t + self.logprob + self.update + self.sync
        return JobTrace(period=period, segments=tuple(segs), nodes=self.nodes)


def synthetic_job_mix(n_jobs: int, seed: int = 0,
                      sizes: Sequence[str] = ("7B", "30B", "235B"),
                      node_counts: Sequence[int] = (1, 2, 8),
                      ) -> List[PhaseProfile]:
    """A cluster-months-style mix: jobs shaped like Table 2 with scaled
    rollout tails (agentic GRPO per §6.3's replay setup)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_jobs):
        i = int(rng.integers(0, len(sizes)))
        e = PAPER_TABLE2[sizes[i]]
        active = e["compute_log_prob"] + e["update_actor"] + e["sync_weight"]
        rollout = (e["cycle"] - active) * rng.uniform(0.7, 1.4)
        out.append(PhaseProfile(
            rollout_mean=rollout,
            rollout_tail_sigma=rng.uniform(0.2, 0.6),
            logprob=e["compute_log_prob"] * rng.uniform(0.8, 1.2),
            update=e["update_actor"] * rng.uniform(0.8, 1.2),
            sync=e["sync_weight"] * rng.uniform(0.8, 1.2),
            nodes=int(node_counts[i]),
        ))
    return out


class Profiler:
    """Cold-start profiler: records phase durations over one isolated cycle
    and emits the JobTrace used for warm placement."""

    def __init__(self):
        self.samples: Dict[str, List[float]] = {}

    def record(self, phase: str, duration: float):
        self.samples.setdefault(phase, []).append(duration)

    def trace(self, nodes: int = 1) -> Optional[JobTrace]:
        needed = ("rollout", "update_actor")
        if not all(p in self.samples for p in needed):
            return None
        mean = {p: float(np.mean(v)) for p, v in self.samples.items()}
        t = mean.get("rollout", 0.0)
        segs = []
        for p in ("compute_log_prob", "update_actor", "sync_weight"):
            if p in mean:
                segs.append((t, mean[p]))
                t += mean[p]
        return JobTrace(period=t, segments=tuple(segs), nodes=nodes)
