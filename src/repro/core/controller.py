"""RLController: the algorithm side of the decoupling (paper §4.1).

Runs on CPU-only nodes, holds no model state, and expresses the RLVR loop
purely through the remote service API: generate -> (verify) -> compute
logprobs -> update actor -> sync weights. Swapping the algorithm (GRPO vs
PPO, sync vs one-step-async) changes ONLY this file — deployment topology,
scheduling and state movement stay in the system layers.

A step is a *straight-line dataflow chain* against the client API: each
``Deployment`` method returns a chainable future, ``.then(fn)`` interposes
controller-side transforms (packing rollouts into train batches, recording
metrics), and passing a future as the next op's argument IS the dependency
edge — the Router gates admission on it and splices the resolved value in
at dispatch. No req_id bookkeeping, no nested completion callbacks.

Controllers run under any driver: the serial ``run()`` convenience loop
(submit + ``drain()``), or ``drive()`` self-pacing against a live
``Router.serve()`` plane from the controller's own client thread (the
multi-tenant regime — jobs attach, progress, and detach against a
continuously running service).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import api
from repro.core.router import Router
from repro.rl import data as data_lib
from repro.rl import reward as reward_lib


@dataclasses.dataclass
class JobConfig:
    job_id: str
    model_name: str
    batch_size: int = 8
    group_size: int = 4
    prompt_len: int = 12
    max_new_tokens: int = 16
    seq_len: int = 32
    steps: int = 4
    async_staleness: int = 0          # 0 = synchronous; 1 = one-step async
    seed: int = 0
    overrides: tuple = ()
    tenant: str = "default"           # owning tenant (tenancy.DEFAULT_TENANT)


class _RLControllerBase:
    """Shared client-side plumbing: one train deployment, the synthetic
    verifiable-math pipeline, rollout packing, and the two driver loops.
    Subclasses implement :meth:`submit_step` as a dataflow chain."""

    role_suffix = "train"

    def __init__(self, cfg: JobConfig, router: Router, group_id: int = 0):
        self.cfg = cfg
        self.router = router
        self.dataset = data_lib.MathDataset(seed=cfg.seed)
        self.batches = self.dataset.batches(cfg.batch_size, cfg.prompt_len,
                                            cfg.group_size)
        self.train_dep = api.DeploymentSpec(
            deployment_id=f"{cfg.job_id}-{self.role_suffix}",
            job_id=cfg.job_id,
            model_name=cfg.model_name, role="train",
            overrides=cfg.overrides)
        # rollout reuses the train deployment in this colpooled runtime;
        # a split deployment would create a second spec with role="rollout".
        self.dep: api.Deployment = router.deploy(self.train_dep,
                                                 group_id=group_id)
        self.metrics_log: List[dict] = []
        self.reward_log: List[float] = []
        self.steps_completed = 0
        self._step_idx = 0
        # step index -> tail future of that step's weight update (the
        # one-step-async gate: a pure-ordering `after=` edge, no payload)
        self._updates: Dict[int, api.Future] = {}

    # ------------------------------------------------------------ pieces
    def submit_init(self) -> api.Future:
        return self.dep.init(self.cfg.seed, exec_estimate=1.0)

    def _pack(self, prompts, answers, gen_result,
              include_rewards: bool = False) -> Dict[str, "np.ndarray"]:
        import jax.numpy as jnp
        toks = np.asarray(gen_result["tokens"])
        logps = np.asarray(gen_result["logprobs"])
        texts = [data_lib.decode(t) for t in toks]
        rewards = reward_lib.batch_rewards(texts, answers)
        self.reward_log.append(float(rewards.mean()))
        batch = data_lib.pack_rollout_batch(
            prompts, toks, logps, rewards,
            self.cfg.group_size, self.cfg.seq_len)
        out = {k: jnp.asarray(v) for k, v in batch.items()}
        if include_rewards:           # critic value targets need raw rewards
            out["rewards"] = jnp.asarray(rewards)
        return out

    def _gate(self) -> tuple:
        """One-step-async staleness gate (§6.3): generation of step k waits
        on the update of step k-1-s, expressed as an `after=` future."""
        gate_idx = self._step_idx - 1 - self.cfg.async_staleness
        # entries older than the gate are dead: prune so a long-running
        # serviceized job holds at most staleness+2 update futures
        for k in [k for k in self._updates if k < gate_idx]:
            del self._updates[k]
        if gate_idx >= 0 and gate_idx in self._updates:
            return (self._updates[gate_idx],)
        return ()

    def _record_metrics(self, metrics: dict) -> dict:
        self.metrics_log.append(metrics)
        return metrics

    # ----------------------------------------------------------- the loop
    def submit_step(self, gen_estimate: float = 1.0,
                    train_estimate: float = 1.0) -> List[api.Future]:
        raise NotImplementedError

    def run(self):
        """Synchronous convenience loop (drives the router inline)."""
        init_f = self.submit_init()
        self.router.drain()
        init_f.result()
        tails: List[api.Future] = []
        if self.cfg.async_staleness:
            # pipeline: keep `staleness+1` steps in flight
            for _ in range(self.cfg.steps):
                tails += self.submit_step()
                self.router.step(max_ops=2)
            self.router.drain()
        else:
            for _ in range(self.cfg.steps):
                tails += self.submit_step()
                self.router.drain()
        for f in tails:
            f.result()          # a lost step is loud, not silently skipped
        self.steps_completed = self.cfg.steps
        return {"rewards": self.reward_log, "metrics": self.metrics_log}

    def drive(self, stop: Optional[threading.Event] = None,
              step_hook: Optional[Callable[[], None]] = None,
              step_timeout: float = 300.0):
        """Self-driving client loop against a live ``Router.serve()`` plane.

        Blocking; meant to run on the job's own client thread. Keeps
        ``async_staleness + 1`` steps in flight and waits on each step's
        tail future. ``stop`` detaches cooperatively: no new steps are
        submitted, and errors from operations the teardown poisoned are
        treated as a clean exit rather than failures."""
        try:
            if self.steps_completed == 0:
                self.submit_init().wait(timeout=step_timeout)
            inflight: collections.deque = collections.deque()
            for _ in range(self.cfg.steps - self.steps_completed):
                if stop is not None and stop.is_set():
                    break
                inflight.append(self.submit_step())
                while len(inflight) > self.cfg.async_staleness:
                    self._finish_step(inflight.popleft(), step_timeout,
                                      step_hook)
            while inflight:
                self._finish_step(inflight.popleft(), step_timeout,
                                  step_hook)
        except Exception:
            if stop is not None and stop.is_set():
                return          # detached mid-flight: poisons are expected
            raise

    def _finish_step(self, tails: List[api.Future], timeout: float,
                     step_hook: Optional[Callable[[], None]]):
        for f in tails:
            f.wait(timeout=timeout)
        self.steps_completed += 1
        if step_hook is not None:
            step_hook()


class RLControllerGRPO(_RLControllerBase):
    """One GRPO RLVR job written against the dataflow client API."""

    def submit_step(self, gen_estimate: float = 1.0,
                    train_estimate: float = 1.0) -> List[api.Future]:
        """Issue one RLVR step's operation chain (non-blocking).

        generate -> pack (controller-side) -> update_actor, as straight-line
        dataflow: the packed batch future is update_actor's argument, so its
        prerequisite edge and value splice are automatic. With
        ``async_staleness = s > 0`` generation is gated only on the update
        of step k-1-s ("asynchronous rollout permits one step of staleness,
        with synchronization enforced at the end of each iteration", §6.3);
        the importance-sampling correction in GRPO absorbs the stale policy.
        """
        cfg = self.cfg
        prompts, problems = next(self.batches)
        answers = [p.answer for p in problems]

        gen_f = self.dep.generate(prompts, max_new_tokens=cfg.max_new_tokens,
                                  exec_estimate=gen_estimate,
                                  after=self._gate())
        batch_f = gen_f.then(
            lambda res: self._pack(prompts, answers, res))
        upd_f = self.dep.update_actor(batch_f, exec_estimate=train_estimate)
        self._updates[self._step_idx] = upd_f
        metrics_f = upd_f.then(self._record_metrics)
        self._step_idx += 1
        return [metrics_f]


class RLControllerPPO(_RLControllerBase):
    """PPO over the same service API as a true TWO-ROLE job: an actor
    (role="train") plus a critic deployment (role="critic", the value head
    of rl/ppo.py), with the fused update split into the primitive ops
    (paper Tab. 2): GENERATE -> FORWARD (behavior logprobs) + critic
    FORWARD (values) -> GAE advantages (client-side transform) -> actor
    FORWARD_BACKWARD (clipped surrogate) + OPTIM_STEP -> cross-deployment
    SYNC_WEIGHTS re-basing the critic onto the updated actor backbone ->
    critic FORWARD_BACKWARD (clipped value loss) + OPTIM_STEP on top of the
    fresh backbone (sync-before-update, so the value step is never
    clobbered). The chain — including the ``gather`` joins — exercises
    every dataflow primitive and the cross-deployment weight-sync path,
    demonstrating that the client API is not GRPO-shaped."""

    def __init__(self, cfg: JobConfig, router: Router, group_id: int = 0):
        super().__init__(cfg, router, group_id=group_id)
        from repro.rl import ppo as ppo_lib
        self.ppo_cfg = ppo_lib.PPOConfig()
        self.critic_spec = api.DeploymentSpec(
            deployment_id=f"{cfg.job_id}-critic", job_id=cfg.job_id,
            model_name=cfg.model_name, role="critic",
            overrides=cfg.overrides)
        self.critic: api.Deployment = router.deploy(self.critic_spec,
                                                    group_id=group_id)

    def submit_init(self) -> api.Future:
        return api.gather(super().submit_init(),
                          self.critic.init(self.cfg.seed, exec_estimate=1.0))

    def _merge_ppo(self, triple):
        """Client-side join: behavior logprobs + critic values -> GAE
        advantages and clipped-value-loss targets."""
        import jax.numpy as jnp
        from repro.rl import ppo as ppo_lib
        batch, logp, values = triple
        toks = np.asarray(batch["tokens"])
        behave = np.zeros(toks.shape, np.float32)
        behave[:, 1:] = np.asarray(logp, np.float32)
        vals = np.asarray(values, np.float32)             # (B, S)
        mask = np.asarray(batch["loss_mask"], np.float32)
        rewards = np.asarray(batch["rewards"], np.float32)  # (B,)
        # terminal verifiable reward at the last response token
        r_seq = np.zeros(toks.shape, np.float32)
        last = (mask * np.arange(toks.shape[1])).argmax(axis=1)
        r_seq[np.arange(toks.shape[0]), last] = rewards
        adv = np.asarray(ppo_lib.gae_advantages(
            jnp.asarray(r_seq), jnp.asarray(vals), jnp.asarray(mask),
            self.ppo_cfg))
        return dict(batch,
                    behavior_logprobs=jnp.asarray(behave),
                    advantages=jnp.asarray(adv),          # token-level
                    value_targets=jnp.asarray(adv + vals),
                    old_values=jnp.asarray(vals))

    def submit_step(self, gen_estimate: float = 1.0,
                    train_estimate: float = 1.0) -> List[api.Future]:
        cfg = self.cfg
        prompts, problems = next(self.batches)
        answers = [p.answer for p in problems]

        gen_f = self.dep.generate(prompts, max_new_tokens=cfg.max_new_tokens,
                                  exec_estimate=gen_estimate,
                                  after=self._gate())
        batch_f = gen_f.then(
            lambda res: self._pack(prompts, answers, res,
                                   include_rewards=True))
        # fresh behavior logprobs under the pre-update policy (standard PPO:
        # the first ratio is exactly 1) and critic values, as scheduled
        # FORWARD ops on the two roles
        logp_f = self.dep.forward(batch_f, exec_estimate=train_estimate)
        vals_f = self.critic.forward(batch_f, output="values",
                                     exec_estimate=train_estimate)
        merged_f = api.gather(batch_f, logp_f, vals_f).then(self._merge_ppo)
        fb_f = self.dep.forward_backward(merged_f, objective="ppo",
                                         exec_estimate=train_estimate)
        opt_f = self.dep.optim_step(fb_f.then(lambda r: r["grads"]),
                                    exec_estimate=train_estimate)
        # cross-deployment SYNC_WEIGHTS: once the actor updated, re-base
        # the critic onto the new backbone (shared-backbone PPO), THEN
        # apply the value step on top — sync-before-update, so the value
        # gradient is never clobbered and the critic ends every cycle as
        # "fresh actor backbone + one value step". (vals_f already ran:
        # old_values were read under the pre-step critic.)
        sync_f = self.dep.sync_weights(self.critic,
                                       exec_estimate=train_estimate,
                                       after=(opt_f,))
        vfb_f = self.critic.forward_backward(merged_f, objective="value",
                                             exec_estimate=train_estimate,
                                             after=(sync_f,))
        vopt_f = self.critic.optim_step(vfb_f.then(lambda r: r["grads"]),
                                        exec_estimate=train_estimate)
        self._updates[self._step_idx] = vopt_f

        def _record(triple):
            fb, opt_res, vfb = triple
            metrics = {k: float(v) for k, v in fb["metrics"].items()}
            metrics["value_loss"] = float(vfb["metrics"]["value_loss"])
            metrics.update(opt_res)
            return self._record_metrics(metrics)

        metrics_f = api.gather(fb_f, opt_f, vfb_f).then(_record)
        self._step_idx += 1
        return [metrics_f, vopt_f]
