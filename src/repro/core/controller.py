"""RLController: the algorithm side of the decoupling (paper §4.1).

Runs on CPU-only nodes, holds no model state, and expresses the RLVR loop
purely through the remote service API: generate -> (verify) -> compute
logprobs -> update actor -> sync weights. Swapping the algorithm (GRPO vs
PPO, sync vs one-step-async) changes ONLY this file — deployment topology,
scheduling and state movement stay in the system layers.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import api
from repro.core.router import Router
from repro.rl import data as data_lib
from repro.rl import reward as reward_lib


@dataclasses.dataclass
class JobConfig:
    job_id: str
    model_name: str
    batch_size: int = 8
    group_size: int = 4
    prompt_len: int = 12
    max_new_tokens: int = 16
    seq_len: int = 32
    steps: int = 4
    async_staleness: int = 0          # 0 = synchronous; 1 = one-step async
    seed: int = 0
    overrides: tuple = ()


class RLControllerGRPO:
    """One RLVR job written against the service API."""

    def __init__(self, cfg: JobConfig, router: Router, group_id: int = 0):
        self.cfg = cfg
        self.router = router
        self.dataset = data_lib.MathDataset(seed=cfg.seed)
        self.batches = self.dataset.batches(cfg.batch_size, cfg.prompt_len,
                                            cfg.group_size)
        self.train_dep = api.DeploymentSpec(
            deployment_id=f"{cfg.job_id}-train", job_id=cfg.job_id,
            model_name=cfg.model_name, role="train",
            overrides=cfg.overrides)
        # rollout reuses the train deployment in this colpooled runtime;
        # a split deployment would create a second spec with role="rollout".
        router.create_deployment(self.train_dep, group_id=group_id)
        self.metrics_log: List[dict] = []
        self.reward_log: List[float] = []
        self._step_idx = 0
        self._update_reqs: Dict[int, int] = {}

    # ------------------------------------------------------------ pieces
    def submit_init(self) -> api.Future:
        return self.router.submit_queued_operation(
            api.make_op(self.train_dep, api.Op.INIT, self.cfg.seed,
                        exec_estimate=1.0))

    def _pack(self, prompts, answers, gen_result) -> Dict[str, np.ndarray]:
        toks = np.asarray(gen_result["tokens"])
        logps = np.asarray(gen_result["logprobs"])
        texts = [data_lib.decode(t) for t in toks]
        rewards = reward_lib.batch_rewards(texts, answers)
        self.reward_log.append(float(rewards.mean()))
        return data_lib.pack_rollout_batch(
            prompts, toks, logps, rewards,
            self.cfg.group_size, self.cfg.seq_len)

    # ----------------------------------------------------------- the loop
    def submit_step(self, gen_estimate: float = 1.0,
                    train_estimate: float = 1.0) -> List[api.Future]:
        """Issue one RLVR step's operation chain (non-blocking).

        With ``async_staleness = s > 0`` the generation of step k is gated
        only on the update of step k-1-s (one-step-async for s=1, §6.3:
        "asynchronous rollout permits one step of staleness, with
        synchronization enforced at the end of each iteration"); the
        importance-sampling correction in GRPO absorbs the stale policy.
        """
        cfg = self.cfg
        prompts, problems = next(self.batches)
        answers = [p.answer for p in problems]

        gate_idx = self._step_idx - 1 - cfg.async_staleness
        prereqs = ()
        if gate_idx >= 0 and gate_idx in self._update_reqs:
            prereqs = (self._update_reqs[gate_idx],)
        gen = api.make_op(self.train_dep, api.Op.GENERATE, prompts,
                          exec_estimate=gen_estimate,
                          max_new_tokens=cfg.max_new_tokens,
                          prerequisites=prereqs)
        gen_f = self.router.submit_queued_operation(gen)
        step_idx = self._step_idx

        def on_gen(fut: api.Future):
            import jax.numpy as jnp
            # a failed generate raises here; the Router records it and the
            # driver (drain / run_until_idle) re-raises at exit, so a lost
            # step is loud rather than silently skipped
            batch = self._pack(prompts, answers, fut.result())
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            upd = api.make_op(self.train_dep, api.Op.UPDATE_ACTOR, batch,
                              exec_estimate=train_estimate,
                              prerequisites=(gen.req_id,))
            self._update_reqs[step_idx] = upd.req_id
            upd_f = self.router.submit_queued_operation(upd)
            upd_f.add_done_callback(
                lambda f: self.metrics_log.append(f.result()))

        # add_done_callback fires immediately if the generate already
        # completed on a dispatch thread — safe under concurrent execution
        gen_f.add_done_callback(on_gen)
        self._step_idx += 1
        return [gen_f]

    def run(self, driver: Optional[Callable[[], None]] = None):
        """Synchronous convenience loop (drives the router inline)."""
        self.submit_init()
        self.router.drain()
        if self.cfg.async_staleness:
            # pipeline: keep `staleness+1` steps in flight
            for _ in range(self.cfg.steps):
                self.submit_step()
                self.router.step(max_ops=2)
            self.router.drain()
        else:
            for _ in range(self.cfg.steps):
                self.submit_step()
                self.router.drain()
        return {"rewards": self.reward_log, "metrics": self.metrics_log}
