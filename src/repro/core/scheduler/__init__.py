"""Cluster scheduler: cyclic horizon, hierarchical resource view, placement
(Eq. 1-2), HRRS runtime ordering (Alg. 1) with an incremental
kinetic-tournament admission index, task-executor FSM."""
