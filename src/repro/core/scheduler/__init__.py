"""Cluster scheduler: cyclic horizon, hierarchical resource view, placement
(Eq. 1-2), HRRS runtime ordering (Alg. 1), task-executor FSM."""
