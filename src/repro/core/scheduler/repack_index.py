"""Incremental repack planning: flat-cost placement re-fitting at fleet
scale.

``PlacementPolicy.plan_repack`` is exact but O(fleet): it deep-copies every
group's free-window list and re-fits every placed job, so planning cost
grows superlinearly with resident count (5.4 ms -> 30 ms -> 180 ms at
4 -> 16 -> 64 jobs in ``BENCH_PR5.json``) — at the thousands of jobs a
production cluster holds, the reconciler cannot even *plan* inside its own
cadence. :class:`RepackIndex` repeats the admission-index trick (the HRRS
kinetic tournament) at the placement layer:

- **Dirty tracking.** Every :class:`~repro.core.scheduler.placement.NodeGroup`
  carries a revision counter (``rev``) bumped on any resident change; the
  index remembers the revision it last planned against, so only groups
  something actually touched — a move, an add/remove, or reconciler-flagged
  occupancy drift via :meth:`RepackIndex.mark_dirty` — contribute
  re-fit candidates. A converged fleet plans in microseconds regardless of
  its size.
- **Delta planning.** Candidate jobs (the residents of dirty groups) are
  re-fitted one at a time in the full planner's order (descending duty,
  then job id) against a copy-on-write overlay: a clean group's possibly
  huge free list is never cloned, only the few groups a decision touches
  are materialized. The result is a delta
  :class:`~repro.core.scheduler.placement.RepackPlan`
  (``incremental=True``) whose ordered ``deltas`` are replayed onto the
  live state move-by-move instead of adopting a wholesale re-fitted clone.
- **Candidate pruning.** Before any exact micro-shift search runs: a job
  whose current interference is already below the migration-cost floor is
  skipped outright (no move can gain more interference than the job
  suffers), and destination groups are screened with a sound duty-overlap
  lower bound — folding both jobs onto a resident's cycle circle, their
  overlap is at least ``|union(cand arcs)| + |union(res arcs)| - period``
  by pigeonhole, and the bound is rotation-invariant, so it holds for
  *every* micro-shift. A destination whose summed bound already eats the
  whole achievable gain is never searched.

With ``max_dest_search=None`` the index searches every surviving
destination and (by construction: same order, same scoring key, same
floor/vacate rules) reproduces the full planner's decisions — the property
tests in ``tests/test_repack_index.py`` pin that agreement under
randomized add/remove/drift/repack sequences. The shipped reconcile path
caps the exact searches per job at ``DirectorConfig.repack_dest_search``
most-promising destinations (ranked by the same lower bound), trading
oracle-exactness for a hard per-pass cost bound; every move it does emit
still clears the same migration-cost floor.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler.intervals import IntervalSet
from repro.core.scheduler.placement import (JobMove, JobTrace, NodeGroup,
                                            Placed, PlacementPolicy,
                                            RepackPlan, best_shift,
                                            phase_interference, wrapped_arcs)


def union_busy(segments: Sequence[Tuple[float, float]], anchor: float,
               period: float) -> float:
    """Measure of the union of ``segments`` anchored at ``anchor`` and
    wrapped onto the circle ``[0, period)``. Rotation-invariant in
    ``anchor`` (wrapping is a measure-preserving bijection), which is what
    makes the pigeonhole bound below shift-independent."""
    arcs: List[Tuple[float, float]] = []
    for a, d in segments:
        arcs.extend(wrapped_arcs(anchor + a, d, period))
    arcs.sort()
    total = 0.0
    cur_lo = cur_hi = None
    for lo, hi in arcs:
        if cur_hi is None or lo > cur_hi:
            if cur_hi is not None:
                total += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    if cur_hi is not None:
        total += cur_hi - cur_lo
    return total


class _Overlay:
    """Copy-on-write view of a policy's groups: reads hit the live objects
    (planning never mutates them), writes materialize a private clone of
    just the touched group. The incremental analogue of
    ``PlacementPolicy.clone`` at O(touched) instead of O(fleet)."""

    def __init__(self, policy: PlacementPolicy, origin: float):
        self.policy = policy
        self.origin = origin
        self._mat: Dict[int, NodeGroup] = {}

    def group(self, group_id: int) -> Optional[NodeGroup]:
        g = self._mat.get(group_id)
        return g if g is not None else self.policy.group(group_id)

    def groups(self, eligible: Optional[frozenset]) -> List[NodeGroup]:
        out = []
        for g in self.policy.groups:
            if eligible is not None and g.group_id not in eligible:
                continue
            out.append(self._mat.get(g.group_id, g))
        return out

    def materialized(self, group_id: int) -> bool:
        return group_id in self._mat

    def materialize(self, group_id: int) -> NodeGroup:
        g = self._mat.get(group_id)
        if g is None:
            live = self.policy.group(group_id)
            g = NodeGroup(live.group_id, live.nodes,
                          IntervalSet(live.free.intervals()),
                          resident=list(live.resident),
                          horizon_end=live.horizon_end,
                          rev=live.rev,
                          interference_scale=live.interference_scale)
            g.advance_to(self.origin)
            self._mat[group_id] = g
        return g


class RepackIndex:
    """Incremental repack planner over one live :class:`PlacementPolicy`.

    Holds no lock of its own — the director serializes calls under its
    decision lock, exactly like the :class:`Reconciler` that owns it."""

    def __init__(self, policy: PlacementPolicy):
        self.policy = policy
        self._seen_rev: Dict[int, int] = {}
        self._forced: set = set()
        # per-group summary cache keyed by rev: rows of
        # (period, |union busy| on own circle) per non-degenerate resident,
        # plus the minimum circle slack (period - busy) for the O(1)
        # zero-bound fast path
        self._summaries: Dict[int, Tuple[int, List[Tuple[float, float]],
                                         float]] = {}
        self.last_stats: Dict[str, int] = {}

    # --------------------------------------------------- dirty tracking
    def mark_dirty(self, group_id: int) -> None:
        """Force a group's residents back into the next pass's candidate
        set even though its placement state did not change — the
        reconciler's hook for occupancy drift (the plan is stale, not the
        placements)."""
        self._forced.add(group_id)

    def dirty_groups(self) -> List[int]:
        """Groups whose residents changed since the last plan (revision
        mismatch), were never planned against, or were force-marked."""
        out = []
        for g in self.policy.groups:
            if (self._seen_rev.get(g.group_id) != g.rev
                    or g.group_id in self._forced):
                out.append(g.group_id)
        return sorted(out)

    # ------------------------------------------------------- summaries
    def _summary(self, g: NodeGroup,
                 cached: bool) -> Tuple[List[Tuple[float, float]], float]:
        if cached:
            hit = self._summaries.get(g.group_id)
            if hit is not None and hit[0] == g.rev:
                return hit[1], hit[2]
        rows = []
        slack_min = float("inf")
        for r in g.resident:
            period = r.trace.period
            if period <= 0.0:
                continue
            busy = union_busy(r.trace.segments, r.origin + r.shift, period)
            rows.append((period, busy))
            slack_min = min(slack_min, period - busy)
        if cached:
            self._summaries[g.group_id] = (g.rev, rows, slack_min)
        return rows, slack_min

    def _dest_bound(self, trace: JobTrace, cand_len: float, g: NodeGroup,
                    overlay: _Overlay, a_cache: Dict[float, float]) -> float:
        """Sound lower bound on ``phase_interference(trace, shift, g)``
        over ALL shifts: per resident circle, overlap >= |union(cand)| +
        |union(res)| - period (pigeonhole), each term rotation-invariant.
        Fast path: when the candidate's total busy fits every resident's
        circle slack, the bound is exactly zero — one comparison."""
        rows, slack_min = self._summary(
            g, cached=not overlay.materialized(g.group_id))
        if not rows or cand_len <= slack_min:
            return 0.0
        lb = 0.0
        for period, busy in rows:
            a_u = a_cache.get(period)
            if a_u is None:
                a_u = union_busy(trace.segments, 0.0, period)
                a_cache[period] = a_u
            lb += max(0.0, a_u + busy - period)
        return lb * g.interference_scale

    # ------------------------------------------------------------ plan
    @staticmethod
    def _floor_for(src: int, dst: int, min_gain: float,
                   cross_min_gain: Optional[float],
                   mesh_of: Optional[Dict[int, int]]) -> float:
        floor = min_gain
        if cross_min_gain is not None and mesh_of is not None:
            src_dom, dst_dom = mesh_of.get(src), mesh_of.get(dst)
            if src_dom is None or dst_dom is None or src_dom != dst_dom:
                floor = max(floor, cross_min_gain)
        return floor

    @staticmethod
    def _snapshot(g: NodeGroup) -> tuple:
        """Cheap undo point for a materialized (private) group clone:
        C-speed list copies, vs re-carving thousands of cycle windows one
        ``subtract`` at a time to put a released candidate back."""
        return (g.free.starts[:], g.free.ends[:], list(g.resident), g.rev)

    @staticmethod
    def _restore(g: NodeGroup, snap: tuple) -> None:
        g.free.starts, g.free.ends, g.resident, g.rev = snap

    def plan(self, origin: float = 0.0,
             groups: Optional[Sequence[int]] = None,
             min_gain: float = 0.0,
             cross_min_gain: Optional[float] = None,
             mesh_of: Optional[Dict[int, int]] = None,
             exclude: frozenset = frozenset(),
             max_dest_search: Optional[int] = None,
             prune_dests: bool = True) -> RepackPlan:
        """Plan a delta repack WITHOUT mutating the live state: re-fit only
        the residents of dirty groups, against a copy-on-write overlay.
        Same candidate order, scoring key, migration-cost floors and
        vacate exemption as ``plan_repack`` — see the module docstring for
        where the two can diverge (``max_dest_search``).

        ``groups`` restricts *destinations* (candidacy is dirtiness);
        ``exclude`` pins jobs (the director's migration cooldown);
        ``max_dest_search`` caps exact micro-shift searches per job
        (None = search every surviving destination); ``prune_dests``
        toggles the duty-overlap bound screen. With ``min_gain=0``,
        ``max_dest_search=None`` and ``prune_dests=False`` the decisions
        are bit-identical to ``plan_repack`` on the same (all-dirty)
        state — the oracle mode the property tests pin. With a positive
        floor the index intentionally deviates in two below-floor ways:
        a job whose interference is under the floor is skipped without
        re-fitting (the oracle may re-anchor it in place — no migration
        either way), and a pruned destination the oracle WOULD have
        picked-then-skipped can let the index take a different move that
        actually clears the floor (gain the oracle leaves on the table).
        Returns an ``incremental=True`` plan; groups planned against are
        marked clean, so the next pass only revisits what the application
        of this plan (or new drift) touches."""
        pol = self.policy
        cfg = pol.cfg
        live_ids = {g.group_id for g in pol.groups}
        for gid in list(self._seen_rev):
            if gid not in live_ids:
                del self._seen_rev[gid]
                self._summaries.pop(gid, None)
        self._forced &= live_ids
        dirty = self.dirty_groups()
        eligible = None if groups is None else frozenset(groups)

        cands: List[Placed] = []
        for gid in dirty:
            for p in pol.group(gid).resident:
                if not p.once and p.job_id not in exclude:
                    cands.append(p)
        cands.sort(key=lambda p: (-p.trace.duty(), p.job_id))

        overlay = _Overlay(pol, origin)
        moves: List[JobMove] = []
        reshifts: List[str] = []
        skipped: List[JobMove] = []
        deltas: List[JobMove] = []
        stats = dict(candidates=len(cands), pruned_jobs=0, pruned_dests=0,
                     searched=0, dirty=len(dirty))

        for old in cands:
            job_id = old.job_id
            src_gid = old.group_id
            src = overlay.group(src_gid)
            if src is None:
                continue
            trace = old.trace
            before = phase_interference(trace, old.shift, src, old.origin,
                                        exclude=job_id)
            was_last = len(src.resident) == 1
            if not was_last and before < min_gain:
                # no destination can gain more than the interference the
                # job currently suffers — same outcome as the oracle's
                # re-fit-then-revert, minus the search
                stats["pruned_jobs"] += 1
                continue
            n = old.n_cycles or max(1, int(cfg.horizon
                                           // max(trace.period, 1e-9)))
            src_m = overlay.materialize(src_gid)
            snap = self._snapshot(src_m)
            src_m.release_resident(old, n)

            a_cache: Dict[float, float] = {}
            cand_len = sum(d for _, d in trace.segments)
            search: List[NodeGroup] = []
            ranked: List[Tuple[Tuple[float, int, int], NodeGroup]] = []
            summaries = self._summaries
            mat = overlay._mat
            flat_floor = cross_min_gain is None or mesh_of is None
            for g in overlay.groups(eligible):
                if g.nodes < trace.nodes:
                    continue
                gid = g.group_id
                if gid == src_gid:
                    search.append(g)   # staying pays no migration: exempt
                    continue
                # zero-bound fast path inlined (the ranking loop runs per
                # fleet group; a clean group with circle slack for the
                # candidate bounds to exactly 0 via one cache hit)
                hit = None if gid in mat else summaries.get(gid)
                if (hit is not None and hit[0] == g.rev
                        and (not hit[1] or cand_len <= hit[2])):
                    lb = 0.0
                else:
                    lb = self._dest_bound(trace, cand_len, g, overlay,
                                          a_cache)
                if prune_dests and not was_last:
                    floor_g = (min_gain if flat_floor else
                               self._floor_for(src_gid, gid, min_gain,
                                               cross_min_gain, mesh_of))
                    if before - lb < floor_g:
                        stats["pruned_dests"] += 1
                        continue
                ranked.append(((lb, -len(g.resident), gid), g))
            ranked.sort(key=lambda t: t[0])
            if max_dest_search is not None:
                ranked = ranked[:max_dest_search]
            search.extend(g for _, g in ranked)

            best: Optional[Tuple[tuple, NodeGroup, float]] = None
            for g in search:
                fit = best_shift(trace, g.free, cfg, origin)
                if fit is None:
                    continue
                stats["searched"] += 1
                delta, cost = fit
                interf = phase_interference(trace, delta, g, origin)
                key = (round(cost, 6), interf, -len(g.resident),
                       0 if g.group_id == src_gid else 1, g.group_id)
                if best is None or key < best[0]:
                    best = (key, g, delta)

            if best is None:
                self._restore(src_m, snap)
                continue
            key, g_best, delta = best
            if g_best.group_id == src_gid:
                if delta != old.shift or origin != old.origin:
                    newp = Placed(job_id, trace, src_gid, delta,
                                  origin=origin, n_cycles=n)
                    src_m.carve_cycles(trace, delta, origin, n)
                    src_m.resident.append(newp)
                    src_m.rev += 1
                    mv = JobMove(job_id, src_gid, src_gid, delta,
                                 origin=origin, gain=0.0,
                                 src_shift=old.shift, src_origin=old.origin,
                                 n_cycles=n)
                    reshifts.append(job_id)
                    deltas.append(mv)
                else:
                    self._restore(src_m, snap)
                continue
            after = key[1]
            move = JobMove(job_id, src_gid, g_best.group_id, delta,
                           origin=origin, gain=before - after,
                           vacates=was_last, src_shift=old.shift,
                           src_origin=old.origin, n_cycles=n)
            floor_g = self._floor_for(src_gid, g_best.group_id, min_gain,
                                      cross_min_gain, mesh_of)
            if not move.vacates and move.gain < floor_g:
                skipped.append(move)
                self._restore(src_m, snap)
                continue
            dst_m = overlay.materialize(g_best.group_id)
            newp = Placed(job_id, trace, dst_m.group_id, delta,
                          origin=origin, n_cycles=n)
            dst_m.carve_cycles(trace, delta, origin, n)
            dst_m.resident.append(newp)
            dst_m.rev += 1
            moves.append(move)
            deltas.append(move)

        for gid in dirty:
            g = pol.group(gid)
            if g is not None:
                self._seen_rev[gid] = g.rev
            self._forced.discard(gid)
        stats["moves"] = len(moves)
        stats["reshifts"] = len(reshifts)
        self.last_stats = stats
        return RepackPlan(origin, tuple(moves), tuple(reshifts),
                          tuple(skipped), fitted=None, incremental=True,
                          deltas=tuple(deltas))
