"""Task Executor: the Scheduler's operational backbone (paper §5.2.3).

A lightweight finite state machine per job/request with three mechanics:

- Priority-based Admission (QUEUED): the pending pool is scored with HRRS
  against current resource availability. The default ``hrrs`` policy keeps
  the pool in an incremental kinetic-tournament index
  (:mod:`~repro.core.scheduler.admission_index`) updated on submit /
  finish / start / setup-recalibration, so ``pick_next`` is amortised
  O(log n) instead of a full O(n log n) re-score; ``pick_next_full`` is the
  unchanged Algorithm-1 oracle the index is property-tested against (and
  the path non-``hrrs`` policies use).
- Lock-Gated Execution (RUNNING): a request transitions to RUNNING only
  after prerequisites finish and the exclusive node-group lock is acquired.
- Lifecycle Teardown (COMPLETED): releases locks and unblocks successors.

The executor is time-source agnostic: a callable ``now()`` lets the SAME
admission path run under wall-clock dispatch (concurrent WPG worker
threads), the discrete-event simulator, or a :class:`VirtualClock` for
deterministic replay. All state transitions are guarded by one re-entrant
mutex whose condition variable (``cv``) doubles as the dispatch-plane wakeup
signal: submissions and completions notify it, so per-group dispatchers
block instead of polling.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import threading
from typing import Callable, Deque, Dict, List, Optional, Sequence

from repro.core.scheduler import hrrs
from repro.core.scheduler.admission_index import GroupAdmissionIndex


class State(enum.Enum):
    QUEUED = "QUEUED"
    RUNNING = "RUNNING"
    COMPLETED = "COMPLETED"
    FAILED = "FAILED"


@dataclasses.dataclass
class Task:
    request: hrrs.Request
    group_id: int
    state: State = State.QUEUED
    prerequisites: tuple = ()          # req_ids that must COMPLETE first
    t_admitted: float = 0.0
    t_started: float = 0.0
    t_finished: float = 0.0
    error: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class PhaseRecord:
    """One completed operation's timing, exported for the online profiler
    (paper §4.3.2: the control plane folds these into a per-job JobTrace)."""
    seq: int                           # global monotonic completion ordinal
    op: str                            # api.Op value ("generate", ...)
    group_id: int
    t_started: float
    t_finished: float

    @property
    def duration(self) -> float:
        return self.t_finished - self.t_started


class VirtualClock:
    """Deterministic, manually-advanced time source.

    Drop-in for ``time.monotonic`` wherever a ``now()`` callable is taken
    (Router, TaskExecutor, simulator), so HRRS admission decisions — which
    depend on waits computed from ``now() - arrival_time`` — replay
    identically across runs regardless of host load.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"virtual clock cannot go backwards ({dt})")
        with self._lock:
            self._t += dt
            return self._t

    def __call__(self) -> float:
        return self.now()


class GroupLock:
    """Exclusive lock per training-services node group (model-swap safety)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.holder: Optional[int] = None

    def acquire(self, req_id: int) -> bool:
        ok = self._lock.acquire(blocking=False)
        if ok:
            self.holder = req_id
        return ok

    def release(self, req_id: int):
        if self.holder == req_id:
            self.holder = None
            self._lock.release()


class TaskExecutor:
    def __init__(self, now: Callable[[], float],
                 t_load: float = 0.0, t_offload: float = 0.0,
                 policy: str = "hrrs", use_admission_index: bool = True,
                 max_settled_tasks: int = 4096, phase_window: int = 256):
        self.now = now
        self.t_load = t_load
        self.t_offload = t_offload
        # admission fallbacks for groups with no measured switch yet; the
        # scalar attributes above drift to "most recently measured anywhere"
        # (telemetry) and must NOT leak into another group's scoring
        self._default_t_load = t_load
        self._default_t_offload = t_offload
        self.policy = policy
        self.tasks: Dict[int, Task] = {}
        self.locks: Dict[int, GroupLock] = {}
        self.resident_job: Dict[int, Optional[str]] = {}
        self.switch_count = 0
        # Per-group measured setup costs (concurrent groups switch
        # independently; a global scalar would race across dispatch threads).
        self.group_t_load: Dict[int, float] = {}
        self.group_t_offload: Dict[int, float] = {}
        # One mutex guards every transition; its condition variable is the
        # dispatch-plane wakeup: submit/finish notify, dispatchers wait.
        self.cv = threading.Condition(threading.RLock())
        self.inflight = 0              # ops started but futures not yet fired
        self._open = 0                 # tasks in QUEUED or RUNNING
        self.failed_count = 0          # lifetime FAILED transitions
        # True whenever some QUEUED task MAY have a failed prerequisite:
        # set on every FAILED transition and on submit-under-failed-prereq,
        # cleared by the router once a poison sweep reaches fixpoint — so a
        # long-lived serve plane pays the full-table reap scan per failure
        # EVENT, not per dispatch iteration forever after the first failure
        self.poison_dirty = False
        # Incremental admission index (hrrs policy only): membership is
        # exactly the runnable set — ready QUEUED tasks — maintained on
        # submit / finish / try_start instead of re-derived per admission.
        self.use_admission_index = use_admission_index and policy == "hrrs"
        self._indexes: Dict[int, GroupAdmissionIndex] = {}
        # prereq req_id -> dependents whose readiness flips when it settles
        self._dependents: Dict[int, List[int]] = {}
        # Bounded retention of settled Task records (telemetry): settled
        # req_ids enter a FIFO ring; beyond ``max_settled_tasks`` the oldest
        # are dropped from ``tasks`` so a week-long serve plane does not grow
        # memory without bound. FAILED records are pinned while a poison
        # sweep may still need their error (poison_dirty).
        self.max_settled_tasks = max_settled_tasks
        self._settled: Deque[int] = collections.deque()
        # FAILED records get their own ring of the same capacity: a late
        # dependent submitted against a pruned FAILED prerequisite would
        # lose its poisoning (unknown prereq ids count as satisfied), so
        # error records are retained for max_settled_tasks *failures*
        # rather than settles — still bounded, far longer-lived
        self._settled_failed: Deque[int] = collections.deque()
        # Per-job phase telemetry for the control plane's online profiler
        # (bounded per job; independent of Task retention).
        self.phase_window = phase_window
        self.phase_log: Dict[str, Deque[PhaseRecord]] = {}
        self._phase_seq = 0
        # Per-group REALIZED busy windows (seq, job_id, t_started,
        # t_finished), bounded per group: the reconciler overlaps these with
        # the plan's predicted windows so occupancy drift is measured, not
        # only predicted.
        self.group_busy_log: Dict[int, Deque[tuple]] = {}
        # Live per-group telemetry the capacity adjuster polls.
        self.queued_count: Dict[int, int] = {}
        self.group_busy: Dict[int, float] = {}
        # per-job RUNNING counter: the migration quiesce predicate is
        # re-evaluated on every cv notification, so it must be O(1)
        self._running_count: Dict[str, int] = {}
        # Jobs under a migration hold: their QUEUED ops are not admissible
        # until release (the drain half of elastic re-placement, §4.5.3).
        self.held_jobs: set = set()

    # -------------------------------------------------------------- index
    def _index_for(self, group_id: int) -> GroupAdmissionIndex:
        idx = self._indexes.get(group_id)
        if idx is None:
            t_load, t_offload = self.setup_costs(group_id)
            idx = self._indexes[group_id] = GroupAdmissionIndex(t_load,
                                                                t_offload)
        return idx

    def _index_insert(self, task: Task):
        r = task.request
        self._index_for(task.group_id).insert(
            r.req_id, r.job_id, r.arrival_time, r.exec_time, self.now(),
            r.priority)

    def _index_remove(self, task: Task):
        idx = self._indexes.get(task.group_id)
        if idx is not None:
            idx.remove(task.request.req_id, self.now())

    # ------------------------------------------------------------- submit
    def submit(self, request: hrrs.Request, group_id: int,
               prerequisites: Sequence[int] = ()) -> Task:
        with self.cv:
            t = Task(request=request, group_id=group_id,
                     prerequisites=tuple(prerequisites),
                     t_admitted=self.now())
            self.tasks[request.req_id] = t
            self.locks.setdefault(group_id, GroupLock())
            self.resident_job.setdefault(group_id, None)
            self._open += 1
            self.queued_count[group_id] = \
                self.queued_count.get(group_id, 0) + 1
            if any(p in self.tasks
                   and self.tasks[p].state == State.FAILED
                   for p in t.prerequisites):
                self.poison_dirty = True   # born poisoned: needs a sweep
            if self.use_admission_index:
                for p in t.prerequisites:
                    pt = self.tasks.get(p)
                    if pt is None or pt.state in (State.QUEUED,
                                                  State.RUNNING):
                        self._dependents.setdefault(p, []).append(
                            request.req_id)
                if self._ready(t):
                    self._index_insert(t)
                # a task counted "ready" only because this req_id was an
                # unknown prerequisite is no longer ready now that the
                # prerequisite exists and is QUEUED (matches _ready, which
                # ignores prereq ids it has never seen)
                for d in self._dependents.get(request.req_id, ()):
                    dt = self.tasks.get(d)
                    if (dt is not None and dt.state == State.QUEUED
                            and not self._ready(dt)):
                        self._index_remove(dt)
            self.cv.notify_all()
            return t

    # ---------------------------------------------------------- admission
    def _ready(self, t: Task) -> bool:
        return (t.state == State.QUEUED
                and t.request.job_id not in self.held_jobs
                and all(self.tasks[p].state == State.COMPLETED
                        for p in t.prerequisites if p in self.tasks))

    def failed_prereqs(self, t: Task) -> List[int]:
        return [p for p in t.prerequisites
                if p in self.tasks and self.tasks[p].state == State.FAILED]

    def runnable(self, group_id: int) -> List[Task]:
        with self.cv:
            return [t for t in self.tasks.values()
                    if t.group_id == group_id and self._ready(t)]

    def setup_costs(self, group_id: int) -> tuple:
        return (self.group_t_load.get(group_id, self._default_t_load),
                self.group_t_offload.get(group_id, self._default_t_offload))

    def set_setup_costs(self, group_id: int, t_load: float, t_offload: float):
        with self.cv:
            self.group_t_load[group_id] = t_load
            self.group_t_offload[group_id] = t_offload
            # keep the scalar view as "most recently measured" for telemetry
            self.t_load = t_load
            self.t_offload = t_offload
            idx = self._indexes.get(group_id)
            if idx is not None:
                idx.set_setup_costs(t_load, t_offload)

    def pick_next(self, group_id: int) -> Optional[Task]:
        """Scored admission for one group. Does not start the task.

        ``hrrs`` policy: O(log n) read of the incremental index — provably
        (property-tested) the same pick as :meth:`pick_next_full`. Other
        policies fall through to the full plan."""
        with self.cv:
            if not self.use_admission_index:
                return self.pick_next_full(group_id)
            idx = self._indexes.get(group_id)
            if idx is None or not len(idx):
                return None
            req_id = idx.pick(self.now(), self.resident_job.get(group_id))
            return None if req_id is None else self.tasks[req_id]

    def pick_next_full(self, group_id: int) -> Optional[Task]:
        """Algorithm 1's full re-score over the runnable pool: the reference
        admission path (and the oracle the index is tested against)."""
        with self.cv:
            cands = self.runnable(group_id)
            if not cands:
                return None
            sched = (hrrs.schedule if self.policy == "hrrs"
                     else hrrs.fcfs_schedule)
            t_load, t_offload = self.setup_costs(group_id)
            plan = sched(None, None, [t.request for t in cands], self.now(),
                         self.resident_job[group_id], t_load, t_offload)
            if not plan:
                return None
            first = plan[0].request
            return self.tasks[first.req_id]

    # -------------------------------------------------------------- start
    def try_start(self, task: Task) -> bool:
        """Lock-gated QUEUED -> RUNNING transition."""
        with self.cv:
            if not self._ready(task):
                return False
            lock = self.locks[task.group_id]
            if not lock.acquire(task.request.req_id):
                return False
            if self.resident_job[task.group_id] not in (None,
                                                        task.request.job_id):
                self.switch_count += 1
            self.resident_job[task.group_id] = task.request.job_id
            task.state = State.RUNNING
            task.t_started = self.now()
            self.queued_count[task.group_id] -= 1
            job = task.request.job_id
            self._running_count[job] = self._running_count.get(job, 0) + 1
            task.request.running = True
            task.request.remaining_time = task.request.exec_time
            if self.use_admission_index:
                self._index_remove(task)
            return True

    # ------------------------------------------------------------- finish
    def finish(self, task: Task, error: Optional[str] = None):
        with self.cv:
            was_open = task.state in (State.QUEUED, State.RUNNING)
            if task.state == State.QUEUED:
                self.queued_count[task.group_id] -= 1
            ran = task.state == State.RUNNING
            if ran:
                job = task.request.job_id
                left = self._running_count.get(job, 1) - 1
                if left <= 0:
                    self._running_count.pop(job, None)
                else:
                    self._running_count[job] = left
            task.state = State.FAILED if error else State.COMPLETED
            task.error = error
            task.t_finished = self.now()
            task.request.running = False
            if ran and not error:
                dt = task.t_finished - task.t_started
                self.group_busy[task.group_id] = \
                    self.group_busy.get(task.group_id, 0.0) + dt
                self._phase_seq += 1
                log = self.phase_log.get(task.request.job_id)
                if log is None:
                    log = self.phase_log[task.request.job_id] = \
                        collections.deque(maxlen=self.phase_window)
                log.append(PhaseRecord(self._phase_seq, task.request.op,
                                       task.group_id, task.t_started,
                                       task.t_finished))
                blog = self.group_busy_log.get(task.group_id)
                if blog is None:
                    blog = self.group_busy_log[task.group_id] = \
                        collections.deque(maxlen=self.phase_window)
                blog.append((self._phase_seq, task.request.job_id,
                             task.t_started, task.t_finished))
            # The Task record is kept for telemetry (states, timings), but
            # the operation payload (args may hold whole rollout batches) is
            # only reachable through the future from here on — retaining it
            # would grow memory without bound over long runs.
            task.request.payload = None
            self.locks[task.group_id].release(task.request.req_id)
            if was_open:
                self._open -= 1
            if error:
                self.failed_count += 1
                self.poison_dirty = True
            if self.use_admission_index:
                # poisoned-while-QUEUED tasks may still be indexed
                self._index_remove(task)
                deps = self._dependents.pop(task.request.req_id, None)
                if deps and not error:
                    for d in deps:
                        dt = self.tasks.get(d)
                        if (dt is not None and dt.state == State.QUEUED
                                and self._ready(dt)):
                            self._index_insert(dt)
                # scrub this task's own registrations under still-pending
                # prereqs (incl. forward-referenced ids that never arrived)
                # so _dependents stays bounded by open tasks
                for p in task.prerequisites:
                    waiters = self._dependents.get(p)
                    if waiters is not None:
                        try:
                            waiters.remove(task.request.req_id)
                        except ValueError:
                            pass
                        if not waiters:
                            del self._dependents[p]
            self._settled.append(task.request.req_id)
            self._prune_settled()
            self.cv.notify_all()

    def _prune_settled(self):
        """Age out the oldest settled Task records beyond the retention cap
        (must hold cv). A FAILED record is pinned while a poison sweep may
        still need its error (``poison_dirty``); once swept it moves to the
        failed ring, which evicts per-failure rather than per-settle."""
        while len(self._settled) > self.max_settled_tasks:
            req_id = self._settled[0]
            t = self.tasks.get(req_id)
            if t is None:
                self._settled.popleft()
                continue
            if t.state == State.FAILED:
                if self.poison_dirty:
                    break
                self._settled.popleft()
                self._settled_failed.append(req_id)
                continue
            self._settled.popleft()
            self.tasks.pop(req_id, None)
        while len(self._settled_failed) > self.max_settled_tasks:
            self.tasks.pop(self._settled_failed.popleft(), None)

    # ------------------------------------------- migration / group lifecycle
    def hold_job(self, job_id: str):
        """Admission hold (the drain half of elastic re-placement): the
        job's QUEUED ops stop being admissible until :meth:`release_job`.
        Already-RUNNING ops complete normally."""
        with self.cv:
            if job_id in self.held_jobs:
                return
            self.held_jobs.add(job_id)
            if self.use_admission_index:
                for t in self.tasks.values():
                    if (t.state == State.QUEUED
                            and t.request.job_id == job_id):
                        self._index_remove(t)
            self.cv.notify_all()

    def release_job(self, job_id: str):
        with self.cv:
            if job_id not in self.held_jobs:
                return
            self.held_jobs.discard(job_id)
            if self.use_admission_index:
                for t in self.tasks.values():
                    if (t.state == State.QUEUED
                            and t.request.job_id == job_id
                            and self._ready(t)):
                        self._index_insert(t)
            self.cv.notify_all()

    def job_running(self, job_id: str) -> bool:
        """True while any of the job's ops is RUNNING. O(1): this is the
        migration quiesce predicate, re-checked per cv notification."""
        with self.cv:
            return self._running_count.get(job_id, 0) > 0

    def rehome_job(self, job_id: str, new_group: int) -> int:
        """Move the job's QUEUED tasks to ``new_group`` (after its state
        migrated there), keeping index membership and per-group counters
        consistent. Returns the number of tasks moved."""
        with self.cv:
            self.locks.setdefault(new_group, GroupLock())
            self.resident_job.setdefault(new_group, None)
            moved = 0
            for t in self.tasks.values():
                if (t.state != State.QUEUED
                        or t.request.job_id != job_id
                        or t.group_id == new_group):
                    continue
                if self.use_admission_index:
                    self._index_remove(t)
                self.queued_count[t.group_id] -= 1
                t.group_id = new_group
                self.queued_count[new_group] = \
                    self.queued_count.get(new_group, 0) + 1
                if self.use_admission_index and self._ready(t):
                    self._index_insert(t)
                moved += 1
            self.cv.notify_all()
            return moved

    def drop_group(self, group_id: int):
        """Forget a retired group's scheduling state. Refuses while any open
        task still targets the group."""
        with self.cv:
            open_tasks = [t.request.req_id for t in self.tasks.values()
                          if t.group_id == group_id
                          and t.state in (State.QUEUED, State.RUNNING)]
            if open_tasks:
                raise RuntimeError(
                    f"group {group_id} still has open tasks {open_tasks}")
            self.locks.pop(group_id, None)
            self.resident_job.pop(group_id, None)
            self._indexes.pop(group_id, None)
            self.queued_count.pop(group_id, None)
            self.group_busy.pop(group_id, None)
            self.group_busy_log.pop(group_id, None)
            self.group_t_load.pop(group_id, None)
            self.group_t_offload.pop(group_id, None)

    def drop_job_telemetry(self, job_id: str):
        with self.cv:
            self.phase_log.pop(job_id, None)

    def phase_records_since(self, job_id: str, seq: int) -> List[PhaseRecord]:
        """Completion records newer than ``seq`` (the profiler's cursor
        read; snapshot under the lock)."""
        with self.cv:
            log = self.phase_log.get(job_id)
            if not log:
                return []
            return [r for r in log if r.seq > seq]

    def group_busy_since(self, group_id: int, seq: int) -> List[tuple]:
        """REALIZED busy windows ``(seq, job_id, t_started, t_finished)`` on
        one group newer than ``seq`` — the reconciler's cursor read for
        measured-vs-planned occupancy drift."""
        with self.cv:
            log = self.group_busy_log.get(group_id)
            if not log:
                return []
            return [r for r in log if r[0] > seq]

    # ------------------------------------------------------------ queries
    def outstanding(self) -> int:
        """Tasks still QUEUED or RUNNING (idle when 0 and inflight == 0)."""
        with self.cv:
            return self._open

    def wait_time(self, task: Task) -> float:
        start = task.t_started if task.t_started else self.now()
        return max(0.0, start - task.t_admitted)
