"""Task Executor: the Scheduler's operational backbone (paper §5.2.3).

A lightweight finite state machine per job/request with three mechanics:

- Priority-based Admission (QUEUED): the pending pool is continuously
  re-scored with HRRS against current resource availability.
- Lock-Gated Execution (RUNNING): a request transitions to RUNNING only
  after prerequisites finish and the exclusive node-group lock is acquired.
- Lifecycle Teardown (COMPLETED): releases locks and unblocks successors.

The executor is time-source agnostic: a callable ``now()`` lets it run under
both the discrete-event simulator and wall-clock execution.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.scheduler import hrrs


class State(enum.Enum):
    QUEUED = "QUEUED"
    RUNNING = "RUNNING"
    COMPLETED = "COMPLETED"
    FAILED = "FAILED"


@dataclasses.dataclass
class Task:
    request: hrrs.Request
    group_id: int
    state: State = State.QUEUED
    prerequisites: tuple = ()          # req_ids that must COMPLETE first
    t_admitted: float = 0.0
    t_started: float = 0.0
    t_finished: float = 0.0
    result: object = None
    error: Optional[str] = None


class GroupLock:
    """Exclusive lock per training-services node group (model-swap safety)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.holder: Optional[int] = None

    def acquire(self, req_id: int) -> bool:
        ok = self._lock.acquire(blocking=False)
        if ok:
            self.holder = req_id
        return ok

    def release(self, req_id: int):
        if self.holder == req_id:
            self.holder = None
            self._lock.release()


class TaskExecutor:
    def __init__(self, now: Callable[[], float],
                 t_load: float = 0.0, t_offload: float = 0.0,
                 policy: str = "hrrs"):
        self.now = now
        self.t_load = t_load
        self.t_offload = t_offload
        self.policy = policy
        self.tasks: Dict[int, Task] = {}
        self.locks: Dict[int, GroupLock] = {}
        self.resident_job: Dict[int, Optional[str]] = {}
        self.switch_count = 0

    # ------------------------------------------------------------- submit
    def submit(self, request: hrrs.Request, group_id: int,
               prerequisites: Sequence[int] = ()) -> Task:
        t = Task(request=request, group_id=group_id,
                 prerequisites=tuple(prerequisites), t_admitted=self.now())
        self.tasks[request.req_id] = t
        self.locks.setdefault(group_id, GroupLock())
        self.resident_job.setdefault(group_id, None)
        return t

    # ---------------------------------------------------------- admission
    def _ready(self, t: Task) -> bool:
        return t.state == State.QUEUED and all(
            self.tasks[p].state == State.COMPLETED
            for p in t.prerequisites if p in self.tasks)

    def runnable(self, group_id: int) -> List[Task]:
        return [t for t in self.tasks.values()
                if t.group_id == group_id and self._ready(t)]

    def pick_next(self, group_id: int) -> Optional[Task]:
        """HRRS-scored admission for one group. Does not start the task."""
        cands = self.runnable(group_id)
        if not cands:
            return None
        sched = hrrs.schedule if self.policy == "hrrs" else hrrs.fcfs_schedule
        plan = sched(None, None, [t.request for t in cands], self.now(),
                     self.resident_job[group_id], self.t_load, self.t_offload)
        if not plan:
            return None
        first = plan[0].request
        return self.tasks[first.req_id]

    # -------------------------------------------------------------- start
    def try_start(self, task: Task) -> bool:
        """Lock-gated QUEUED -> RUNNING transition. Returns switch-occurred
        via ``task.request.payload``-agnostic bookkeeping."""
        if not self._ready(task):
            return False
        lock = self.locks[task.group_id]
        if not lock.acquire(task.request.req_id):
            return False
        if self.resident_job[task.group_id] not in (None, task.request.job_id):
            self.switch_count += 1
        self.resident_job[task.group_id] = task.request.job_id
        task.state = State.RUNNING
        task.t_started = self.now()
        task.request.running = True
        task.request.remaining_time = task.request.exec_time
        return True

    # ------------------------------------------------------------- finish
    def finish(self, task: Task, result=None, error: Optional[str] = None):
        task.state = State.FAILED if error else State.COMPLETED
        task.error = error
        task.result = result
        task.t_finished = self.now()
        task.request.running = False
        self.locks[task.group_id].release(task.request.req_id)

    # ------------------------------------------------------------ queries
    def wait_time(self, task: Task) -> float:
        start = task.t_started if task.t_started else self.now()
        return max(0.0, start - task.t_admitted)
