"""Cyclic time horizon: the Global Capacity Profile C_global(t).

Paper §4.3.1/§5.2.1: a fixed-size ring buffer (28,800 one-second slots for an
8-hour horizon) mapped by modulo arithmetic, with a segment tree for O(log T)
range-min gang-feasibility checks and commit-once atomic reservations.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.scheduler.segment_tree import MinSegmentTree

DEFAULT_SLOTS = 28_800          # 8 h at 1 s granularity
DEFAULT_SLOT_SECONDS = 1.0


class CapacityRing:
    def __init__(self, total_nodes: int, slots: int = DEFAULT_SLOTS,
                 slot_seconds: float = DEFAULT_SLOT_SECONDS):
        self.total_nodes = total_nodes
        self.slots = slots
        self.slot_seconds = slot_seconds
        self.tree = MinSegmentTree([float(total_nodes)] * slots)

    # -------------------------------------------------------------- index
    def idx(self, t_abs: float) -> int:
        """t_idx = t_abs (mod L) — unbounded horizon without array shifts."""
        return int(t_abs / self.slot_seconds) % self.slots

    def _ranges(self, t0: float, duration: float) -> List[Tuple[int, int]]:
        """Wrap an absolute interval onto ring index ranges."""
        a = self.idx(t0)
        n = min(self.slots, max(1, int(round(duration / self.slot_seconds))))
        if a + n <= self.slots:
            return [(a, a + n)]
        return [(a, self.slots), (0, (a + n) % self.slots)]

    # ------------------------------------------------------------ queries
    def min_free(self, t0: float, duration: float) -> float:
        """min free nodes over [t0, t0+duration) — the O(log T) gang check."""
        return min(self.tree.range_min(l, r) for l, r in self._ranges(t0, duration))

    def feasible(self, t0: float, duration: float, nodes: int) -> bool:
        return self.min_free(t0, duration) >= nodes

    def free_at(self, t: float) -> float:
        return self.tree.point(self.idx(t))

    # --------------------------------------------------------- mutations
    def reserve(self, t0: float, duration: float, nodes: int) -> bool:
        """Commit-once atomic reservation (subtract across the horizon).

        Returns False (and reserves nothing) if any slot would go negative.
        """
        if not self.feasible(t0, duration, nodes):
            return False
        for l, r in self._ranges(t0, duration):
            self.tree.add(l, r, -float(nodes))
        return True

    def reserve_periodic(self, t0: float, duration: float, nodes: int,
                         period: float) -> bool:
        """Reserve every period-spaced occurrence across the ring horizon
        (atomic pre-allocation of all future cycles, §4.3.1)."""
        n_rep = max(1, int(self.slots * self.slot_seconds / period))
        offs = [t0 + i * period for i in range(n_rep)]
        if not all(self.feasible(t, duration, nodes) for t in offs):
            return False
        for t in offs:
            for l, r in self._ranges(t, duration):
                self.tree.add(l, r, -float(nodes))
        return True

    def release(self, t0: float, duration: float, nodes: int):
        for l, r in self._ranges(t0, duration):
            self.tree.add(l, r, float(nodes))

    def release_periodic(self, t0: float, duration: float, nodes: int,
                         period: float):
        n_rep = max(1, int(self.slots * self.slot_seconds / period))
        for i in range(n_rep):
            self.release(t0 + i * period, duration, nodes)
