"""Per-node interval sets: sorted disjoint free ranges with bisect fitting.

Paper §5.2.1 "Interval Set Fitting": free windows are kept as sorted disjoint
[s, e) ranges; ``simulate_insert`` verifies a time-shifted segment list fits
via binary search in O(N log M) without mutating state.
"""
from __future__ import annotations

import bisect
from typing import Iterable, List, Sequence, Tuple

Interval = Tuple[float, float]


class IntervalSet:
    """Sorted disjoint free intervals [s, e)."""

    def __init__(self, intervals: Iterable[Interval] = ()):
        ivs = sorted((float(s), float(e)) for s, e in intervals if e > s)
        merged: List[Interval] = []
        for s, e in ivs:
            if merged and s <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], e))
            else:
                merged.append((s, e))
        self.starts = [s for s, _ in merged]
        self.ends = [e for _, e in merged]

    # ------------------------------------------------------------ queries
    def __len__(self):
        return len(self.starts)

    def intervals(self) -> List[Interval]:
        return list(zip(self.starts, self.ends))

    def covers(self, s: float, e: float) -> bool:
        """Is [s, e) fully inside one free window? O(log M) bisect."""
        if e <= s:
            return True
        i = bisect.bisect_right(self.starts, s) - 1
        return i >= 0 and self.ends[i] >= e

    def simulate_insert(self, segments: Sequence[Interval],
                        shift: float = 0.0) -> bool:
        """Eq. 2 feasibility: every shifted segment fits a free window."""
        return all(self.covers(a + shift, a + shift + d) for a, d in segments)

    def next_fit(self, after: float, duration: float) -> float:
        """Earliest start >= after where [start, start+duration) fits.
        Returns inf if none."""
        i = bisect.bisect_right(self.starts, after) - 1
        i = max(i, 0)
        while i < len(self.starts):
            s = max(self.starts[i], after)
            if s + duration <= self.ends[i]:
                return s
            i += 1
        return float("inf")

    def total_free(self, horizon: float = float("inf")) -> float:
        return sum(min(e, horizon) - s for s, e in self.intervals()
                   if s < horizon)

    # --------------------------------------------------------- mutations
    def allocate(self, s: float, e: float) -> bool:
        """Remove [s, e) from the free set. False if it doesn't fit."""
        if not self.covers(s, e):
            return False
        i = bisect.bisect_right(self.starts, s) - 1
        ws, we = self.starts[i], self.ends[i]
        del self.starts[i], self.ends[i]
        pieces = []
        if ws < s:
            pieces.append((ws, s))
        if e < we:
            pieces.append((e, we))
        for j, (ps, pe) in enumerate(pieces):
            self.starts.insert(i + j, ps)
            self.ends.insert(i + j, pe)
        return True

    def subtract(self, s: float, e: float):
        """Remove the intersection of [s, e) from the free set, regardless of
        coverage (live-completion carving: an op's actual busy window may
        straddle windows already consumed by the projected plan)."""
        if e <= s:
            return
        i = max(bisect.bisect_right(self.starts, s) - 1, 0)
        while i < len(self.starts) and self.starts[i] < e:
            ws, we = self.starts[i], self.ends[i]
            if we <= s:
                i += 1
                continue
            lo, hi = max(ws, s), min(we, e)
            del self.starts[i], self.ends[i]
            j = i
            if ws < lo:
                self.starts.insert(j, ws)
                self.ends.insert(j, lo)
                j += 1
            if hi < we:
                self.starts.insert(j, hi)
                self.ends.insert(j, we)
                j += 1
            i = j

    def trim_before(self, t: float):
        """Drop free capacity earlier than ``t`` (the past cannot be
        allocated; idle time behind ``now`` is spent, not banked)."""
        self.subtract(float("-inf"), t)

    def free_many(self, windows: Sequence[Interval]):
        """Return many [s, e) windows to the free set in ONE linear merge —
        equivalent to repeated :meth:`free` but O(N + K) instead of
        O(N * K) (each ``free`` pays a list insert). The bulk path behind
        ``NodeGroup.release_resident``, whose freed-cycle lists run to
        thousands of windows at fleet horizons."""
        add = sorted((s, e) for s, e in windows if e > s)
        if not add:
            return
        out_s: List[float] = []
        out_e: List[float] = []
        starts, ends = self.starts, self.ends
        i = j = 0
        cs: float = 0.0
        ce: float = float("-inf")
        first = True
        while i < len(starts) or j < len(add):
            if j >= len(add) or (i < len(starts)
                                 and starts[i] <= add[j][0]):
                s, e = starts[i], ends[i]
                i += 1
            else:
                s, e = add[j]
                j += 1
            if first:
                cs, ce, first = s, e, False
            elif s <= ce:
                if e > ce:
                    ce = e
            else:
                out_s.append(cs)
                out_e.append(ce)
                cs, ce = s, e
        if not first:
            out_s.append(cs)
            out_e.append(ce)
        self.starts, self.ends = out_s, out_e

    def free(self, s: float, e: float):
        """Return [s, e) to the free set, merging neighbours."""
        if e <= s:
            return
        i = bisect.bisect_left(self.starts, s)
        self.starts.insert(i, s)
        self.ends.insert(i, e)
        # merge around i
        j = max(i - 1, 0)
        while j < len(self.starts) - 1:
            if self.ends[j] >= self.starts[j + 1]:
                self.ends[j] = max(self.ends[j], self.ends[j + 1])
                del self.starts[j + 1], self.ends[j + 1]
            elif j > i:
                break
            else:
                j += 1
