"""Incremental HRRS admission index (kinetic tournament over score lines).

Algorithm 1 (``hrrs.schedule``) re-scores the entire pending pool on every
admission — O(n log n) per pick, which PR 1 measured as the dominant cost of
the dispatch plane's hot path. This module maintains the *same* argmax
incrementally, exploiting the structure of the HRRS score

    P_i(t) = rho_i * (1 + max(0, t - a_i) / s_i),  s_i = max(e_i + C, 1e-9)

where ``C`` is the context-switch surcharge (``t_load + t_offload`` if the
request's job is not resident, else 0) and ``rho_i`` is the request's tenant
priority (1.0 default). For t >= a_i each score is a line in ``t`` with
slope ``rho_i / s_i``; any two lines cross at most once, so the winner of a
pairwise comparison flips at most once in the future — the multiplicative
priority term preserves the kinetic invariant. (Unequal priorities add one
new event class: a risen line crossing the other's flat pre-arrival level
``rho``; with equal priorities that crossing degenerates to the arrival
kink, which was already an event, so default-tenant behaviour is
unchanged.) A *kinetic tournament* — a
flat-array tournament tree in the style of ``segment_tree.MinSegmentTree``,
where every internal node caches its subtree's current winner plus a
*certificate* (the earliest future time any comparison below it may flip) —
therefore supports:

- ``insert`` / ``remove``: one root path, O(log n);
- ``peek(t)``: expired certificates are re-evaluated (amortised O(log^2 n)
  per elapsed crossing, O(1) when nothing crossed), then the root winner is
  exact at ``t``.

Certificates only gate *when* a node is re-compared; every re-comparison uses
the exact ``hrrs.queued_score`` floats and Algorithm 1's full tie-break
``(-score, arrival, req_id)``, so the index's pick is bit-identical to the
full re-score. Crossing times are solved algebraically and widened by a
conservative guard band: firing a certificate early merely costs one extra
O(1) re-comparison, while firing late could miss a flip — so all float error
is pushed to the harmless side.

The switch bit flips for a whole job bucket whenever the group's resident job
changes (every context switch) — far too often to re-key per request. Instead
``GroupAdmissionIndex`` keeps, per job, TWO tournaments over the same
entries: one scored resident (C = 0) and one scored non-resident
(C = setup). A resident-job change then costs *nothing* structurally; the
query just reads each bucket's applicable tournament and reduces the (few)
bucket winners with the exact Algorithm-1 key. Setup-cost recalibration
(``set_setup_costs``) is the one O(n) event: it re-pulls the non-resident
tournaments, and only when the measured value actually changed.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from repro.core.scheduler import hrrs

INF = float("inf")

# Relative half-width of the certificate guard band around an algebraically
# solved crossing time. ~1e9 x the double-precision error of the solve: early
# firing is a spare comparison, late firing would break equivalence.
_GUARD = 1e-7


class Entry:
    """Immutable scoring inputs of one queued request."""

    __slots__ = ("req_id", "job_id", "arrival", "exec_time", "priority")

    def __init__(self, req_id: int, job_id: str, arrival: float,
                 exec_time: float, priority: float = 1.0):
        self.req_id = req_id
        self.job_id = job_id
        self.arrival = arrival
        self.exec_time = exec_time
        self.priority = priority


class KineticTournament:
    """Kinetic tournament over HRRS score lines with a fixed switch bit.

    Flat-array layout like ``MinSegmentTree``: node ``i`` has children
    ``2i``/``2i+1``; leaf ``size + slot`` holds entry ``slot``. ``win[i]`` is
    the winning slot of the subtree (-1 if empty), ``exp[i]`` the earliest
    future time the subtree's winner may change.
    """

    def __init__(self, switch: bool, setup: float, capacity: int = 4):
        self.switch = switch
        self.setup = setup
        self.t_front = -INF            # last time certificates were settled
        self.slot_of: Dict[int, int] = {}
        self._alloc(max(capacity, 2))

    def _alloc(self, capacity: int):
        size = 1
        while size < capacity:
            size *= 2
        self.size = size
        self.win: List[int] = [-1] * (2 * size)
        self.exp: List[float] = [INF] * (2 * size)
        self.entries: List[Optional[Entry]] = [None] * size
        # per-slot service time s_i = max(e_i + C, 1e-9), cached because the
        # surcharge C is fixed per tournament (recomputed on set_setup)
        self.s: List[float] = [1.0] * size
        # per-slot tenant priority rho_i (multiplicative score weight)
        self.prio: List[float] = [1.0] * size
        self._free = list(range(size - 1, -1, -1))

    def __len__(self) -> int:
        return len(self.slot_of)

    # --------------------------------------------------------- comparisons
    def _surcharge(self) -> float:
        return self.setup if self.switch else 0.0

    def _slot_s(self, e: Entry) -> float:
        return max(e.exec_time + self._surcharge(), 1e-9)

    def _score_slot(self, slot: int, t: float) -> float:
        # identical floats to hrrs.queued_score, with s_i precomputed
        # (prio * ((w + s) / s) matches hrrs_score's operation order exactly;
        # 1.0 * x == x bit-for-bit, so default-tenant scores are unchanged)
        s = self.s[slot]
        w = t - self.entries[slot].arrival
        if w < 0.0:
            w = 0.0
        return self.prio[slot] * ((w + s) / s)

    def _beats(self, i: int, j: int, t: float) -> bool:
        """Exact Algorithm-1 comparison of slots i, j at time t."""
        pa = self._score_slot(i, t)
        pb = self._score_slot(j, t)
        if pa != pb:
            return pa > pb
        a, b = self.entries[i], self.entries[j]
        if a.arrival != b.arrival:
            return a.arrival < b.arrival
        return a.req_id < b.req_id

    def _next_event(self, i: int, j: int, t: float) -> float:
        """Earliest time strictly after ``t`` at which the winner among
        slots i, j may change; INF if the order is settled forever.

        The comparator can only change at an arrival kink (a score leaves
        its flat wait=0 region), at the single crossing of the two rising
        lines, or — with unequal tenant priorities — where one risen line
        crosses the other's flat pre-arrival level ``rho`` (with equal
        priorities that point degenerates to the arrival kink, already an
        event). Every crossing is widened to [ts - guard, ts + guard]; if
        ``t`` already sits inside the band the certificate is "immediately
        after t", degrading to one exact re-comparison per query until the
        band is cleared — never to a missed flip.
        """
        a, b = self.entries[i], self.entries[j]
        nxt = INF
        if a.arrival > t:
            nxt = a.arrival
        if t < b.arrival < nxt:
            nxt = b.arrival
        sa = self.s[i]
        sb = self.s[j]
        pa = self.prio[i]
        pb = self.prio[j]
        if pa == pb:
            # equal priorities: the common factor rho cancels from the
            # crossing solve, so keep the original algebra verbatim
            # (bit-identical certificates on the default-tenant path)
            if sa != sb:
                d = sb - sa
                ts = (a.arrival * sb - b.arrival * sa) / d
                if ts != ts:           # NaN-safe: treat as "recheck next"
                    return min(nxt, math.nextafter(t, INF))
                guard = _GUARD * (1.0 + abs(ts)) + _GUARD * (
                    sa * sb + abs(a.arrival) * sb
                    + abs(b.arrival) * sa) / abs(d)
                if ts + guard > t:     # crossing not safely behind us
                    lo = ts - guard
                    cand = lo if lo > t else math.nextafter(t, INF)
                    if cand < nxt:
                        nxt = cand
            return nxt
        # Unequal priorities. Joint crossing of the two rising lines
        # rho_i * (1 + (t - a_i)/s_i): slopes k = rho/s, intercepts solved at
        # each arrival.
        ka = pa / sa
        kb = pb / sb
        if ka != kb:
            d = ka - kb
            ts = (ka * a.arrival - kb * b.arrival + pb - pa) / d
            if ts != ts:               # NaN-safe: treat as "recheck next"
                return min(nxt, math.nextafter(t, INF))
            guard = _GUARD * (1.0 + abs(ts)) + _GUARD * (
                abs(ka * a.arrival) + abs(kb * b.arrival)
                + pa + pb) / abs(d)
            if ts + guard > t:
                lo = ts - guard
                cand = lo if lo > t else math.nextafter(t, INF)
                if cand < nxt:
                    nxt = cand
        # New event class: a risen line reaching the other's flat pre-arrival
        # level rho_other, which can flip the winner strictly before the
        # second arrival kink. Only relevant while the other line is still
        # flat (crossing before its arrival, guard-widened).
        for arr_r, p_r, s_r, arr_o, p_o in (
                (a.arrival, pa, sa, b.arrival, pb),
                (b.arrival, pb, sb, a.arrival, pa)):
            if p_r <= 0.0:
                continue
            tf = arr_r + (p_o - p_r) * s_r / p_r
            if tf != tf:               # NaN-safe
                return min(nxt, math.nextafter(t, INF))
            guard = _GUARD * (1.0 + abs(tf) + abs(arr_r)
                              + abs(p_o - p_r) * s_r / p_r)
            if tf - guard < arr_o and tf + guard > t:
                lo = tf - guard
                cand = lo if lo > t else math.nextafter(t, INF)
                if cand < nxt:
                    nxt = cand
        return nxt

    # ------------------------------------------------------------ internal
    def _pull(self, node: int, t: float):
        l, r = 2 * node, 2 * node + 1
        wl, wr = self.win[l], self.win[r]
        if wl < 0 or wr < 0:
            self.win[node] = wl if wl >= 0 else wr
            self.exp[node] = min(self.exp[l], self.exp[r])
        else:
            self.win[node] = wl if self._beats(wl, wr, t) else wr
            self.exp[node] = min(self.exp[l], self.exp[r],
                                 self._next_event(wl, wr, t))

    def _pull_path(self, slot: int, t: float):
        node = (self.size + slot) // 2
        while node:
            self._pull(node, t)
            node //= 2

    def _rebuild(self, t: float):
        for node in range(self.size - 1, 0, -1):
            self._pull(node, t)

    def _advance_node(self, node: int, t: float):
        if node < self.size and self.exp[node] <= t:
            self._advance_node(2 * node, t)
            self._advance_node(2 * node + 1, t)
            self._pull(node, t)

    def advance(self, t: float):
        """Settle every certificate expiring at or before ``t``."""
        if t < self.t_front:
            # Non-monotonic clock (never the executor's contract, but a
            # correct fallback beats a wrong winner): full re-pull.
            self.t_front = t
            self._rebuild(t)
            return
        self.t_front = t
        self._advance_node(1, t)

    # -------------------------------------------------------------- public
    def insert(self, req_id: int, job_id: str, arrival: float,
               exec_time: float, t: float, priority: float = 1.0):
        if req_id in self.slot_of:
            return
        self.advance(t)
        if not self._free:
            self._grow(t)
        slot = self._free.pop()
        e = Entry(req_id, job_id, arrival, exec_time, priority)
        self.entries[slot] = e
        self.s[slot] = self._slot_s(e)
        self.prio[slot] = e.priority
        self.slot_of[req_id] = slot
        self.win[self.size + slot] = slot
        self._pull_path(slot, t)

    def remove(self, req_id: int, t: float) -> bool:
        slot = self.slot_of.pop(req_id, None)
        if slot is None:
            return False
        self.advance(t)
        self.entries[slot] = None
        self.win[self.size + slot] = -1
        self._free.append(slot)
        self._pull_path(slot, t)
        return True

    def peek(self, t: float) -> Optional[Entry]:
        """The exact Algorithm-1 argmax over the indexed pool at time t."""
        self.advance(t)
        w = self.win[1]
        return None if w < 0 else self.entries[w]

    def set_setup(self, setup: float):
        """Setup-cost recalibration: every certificate and comparison is
        parameterised by it, so re-pull the whole tree (O(n); rare)."""
        self.setup = setup
        for slot, e in enumerate(self.entries):
            if e is not None:
                self.s[slot] = self._slot_s(e)
        self._rebuild(self.t_front)

    def _grow(self, t: float):
        old = self.entries
        self._alloc(self.size * 2)
        for slot, e in enumerate(old):
            if e is not None:
                self.entries[slot] = e
                self.s[slot] = self._slot_s(e)
                self.prio[slot] = e.priority
                self.win[self.size + slot] = slot
        self._free = [s for s in range(self.size - 1, -1, -1)
                      if self.entries[s] is None]
        self._rebuild(t)


class GroupAdmissionIndex:
    """Per-node-group admission index: one job bucket = two tournaments.

    ``pick(now, resident_job)`` reduces each bucket's applicable winner
    (resident bucket -> no-switch tournament, others -> switch tournament)
    with the exact ``hrrs.sort_key``, so the result equals
    ``hrrs.schedule(...)[0]`` over the same pool. O(J + log n) per pick for
    J jobs sharing the group.
    """

    def __init__(self, t_load: float = 0.0, t_offload: float = 0.0):
        self.setup = t_load + t_offload
        self.buckets: Dict[str, Tuple[KineticTournament,
                                      KineticTournament]] = {}
        self._job_of: Dict[int, str] = {}

    def __len__(self) -> int:
        return len(self._job_of)

    def insert(self, req_id: int, job_id: str, arrival: float,
               exec_time: float, now: float, priority: float = 1.0):
        if req_id in self._job_of:
            # upsert: a reused req_id must not leave a ghost entry behind
            # in another job's bucket (unreachable by remove() otherwise)
            self.remove(req_id, now)
        pair = self.buckets.get(job_id)
        if pair is None:
            pair = self.buckets[job_id] = (
                KineticTournament(switch=False, setup=self.setup),
                KineticTournament(switch=True, setup=self.setup))
        for kt in pair:
            kt.insert(req_id, job_id, arrival, exec_time, now, priority)
        self._job_of[req_id] = job_id

    def remove(self, req_id: int, now: float) -> bool:
        job_id = self._job_of.pop(req_id, None)
        if job_id is None:
            return False
        pair = self.buckets[job_id]
        for kt in pair:
            kt.remove(req_id, now)
        if not len(pair[0]):
            del self.buckets[job_id]
        return True

    def set_setup_costs(self, t_load: float, t_offload: float):
        setup = t_load + t_offload
        if setup == self.setup:
            return
        self.setup = setup
        for _, kt_switch in self.buckets.values():
            kt_switch.set_setup(setup)

    def pick(self, now: float, resident_job: Optional[str]) -> Optional[int]:
        """req_id of the next request Algorithm 1 would admit, or None."""
        best_key = None
        best_id = None
        for job_id, (kt_res, kt_sw) in self.buckets.items():
            e = (kt_res if job_id == resident_job else kt_sw).peek(now)
            if e is None:
                continue
            switch = job_id != resident_job
            key = (-hrrs.queued_score(e.exec_time, e.arrival, now,
                                      switch, self.setup, e.priority),
                   e.arrival, e.req_id)
            if best_key is None or key < best_key:
                best_key, best_id = key, e.req_id
        return best_id
