"""Job placement: spatio-temporal trace fitting (paper §4.3.2, Eq. 1-2).

A job's profiled cycle is a list of execution segments S = {(a_i, d_i)} with
period T and a node demand. Placement searches node groups and a Micro-Shift
delta in [0, alpha*T] minimising the Scheduling Cost

    J(delta) = w1 * (t_end(delta) - T)/T  +  w2 * delta/T        (Eq. 1)

subject to every shifted segment fitting a free window (Eq. 2). Candidate
deltas are the alignments of segment starts with free-window starts (the
classic critical-shift set), evaluated with IntervalSet bisects. Ties are
broken by predicted phase interference against resident jobs.

Cold start (no trace): a dedicated group is provisioned for clean profiling.
Warm start: trace fitting as above. A repacking event re-fits all profiled
jobs to raise packing density.

Live-plane operation (the control plane in ``core/control_plane.py``):
fitting takes an ``origin`` — the wall/virtual time the trace's cycle 0
starts — so free windows can be kept in absolute time. ``NodeGroup`` free
state is then maintained *incrementally*: ``note_busy`` carves actually
measured execution out of the free set as completions stream in,
``advance_to`` retires capacity behind ``now``, and ``extend_to`` rolls the
planning horizon forward (projecting resident jobs' periodic segments into
the new span). Groups can be added and removed at runtime
(``PlacementPolicy.add_group`` / ``remove_group``) — the hooks the capacity
adjuster drives.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler.intervals import IntervalSet

Segment = Tuple[float, float]          # (relative offset a_i, duration d_i)


@dataclasses.dataclass(frozen=True)
class JobTrace:
    """Profiled periodic demand: segments are the *active* (GPU-busy)
    execution windows within one period of length T."""
    period: float
    segments: Tuple[Segment, ...]
    nodes: int = 1

    def duty(self) -> float:
        return sum(d for _, d in self.segments) / self.period

    def end(self, shift: float = 0.0) -> float:
        return max((a + shift + d) for a, d in self.segments) if self.segments else 0.0


@dataclasses.dataclass
class NodeGroup:
    group_id: int
    nodes: int
    free: IntervalSet                   # free windows over the planning horizon
    resident: List["Placed"] = dataclasses.field(default_factory=list)
    horizon_end: float = 0.0            # absolute end of the planned span

    def __post_init__(self):
        if self.horizon_end == 0.0 and len(self.free):
            self.horizon_end = self.free.ends[-1]

    def occupancy(self, horizon: float) -> float:
        return 1.0 - self.free.total_free(horizon) / max(horizon * 1.0, 1e-9)

    # ------------------------------------------------- incremental updates
    def note_busy(self, t0: float, t1: float):
        """Carve an actually-measured execution window out of the free set
        (live completion feedback). Safe when the window overlaps segments
        the projected plan already consumed — only the intersection with
        still-free capacity is removed."""
        self.free.subtract(t0, t1)

    def advance_to(self, now: float):
        """Retire capacity behind ``now``: the past cannot be allocated."""
        self.free.trim_before(now)

    def carve_resident(self, p: "Placed", lo: float, hi: float):
        """Subtract ``p``'s planned windows intersecting [lo, hi) from the
        free set (idempotent: already-busy spans stay busy)."""
        period = p.trace.period
        if period <= 0.0:
            return
        anchor = p.origin + p.shift
        c = 0 if p.once else max(0, int((lo - anchor) // period) - 1)
        while True:
            base = anchor + c * period
            if base > hi:
                break
            for a, d in p.trace.segments:
                s, e = base + a, base + a + d
                if e > lo and s < hi:
                    self.free.subtract(max(s, lo), min(e, hi))
            if p.once:
                break                 # one-shot reservations do not repeat
            c += 1

    def extend_to(self, new_end: float):
        """Roll the planning horizon forward to ``new_end``: the new span is
        freed, then every resident job's *periodic* segments are projected
        into it (one-shot cold reservations do not repeat)."""
        if new_end <= self.horizon_end:
            return
        old_end = self.horizon_end
        self.free.free(old_end, new_end)
        for p in self.resident:
            self.carve_resident(p, old_end, new_end)
        self.horizon_end = new_end


@dataclasses.dataclass
class Placed:
    job_id: str
    trace: JobTrace
    group_id: int
    shift: float
    origin: float = 0.0                # absolute time of cycle 0's start
    once: bool = False                 # one-shot reservation (cold profiling)
    n_cycles: int = 0                  # cycles actually allocated


@dataclasses.dataclass(frozen=True)
class PlacementConfig:
    w1: float = 1.0                     # completion-delay weight
    w2: float = 0.25                    # start-shift weight
    alpha: float = 1.0                  # shift search range [0, alpha*T]
    horizon: float = 28_800.0
    max_candidates: int = 256


def scheduling_cost(trace: JobTrace, shift: float,
                    cfg: PlacementConfig) -> float:
    """Eq. 1."""
    t_end = trace.end(shift)
    return (cfg.w1 * (t_end - trace.period) / trace.period
            + cfg.w2 * shift / trace.period)


def candidate_shifts(trace: JobTrace, free: IntervalSet,
                     cfg: PlacementConfig, origin: float = 0.0) -> List[float]:
    """delta = window_start - segment_offset alignments, clipped to range.
    ``origin`` translates the trace into the free set's absolute frame."""
    cands = {0.0}
    limit = cfg.alpha * trace.period
    for (a, _), (ws, _) in itertools.product(trace.segments, free.intervals()):
        d = ws - a - origin
        if 0.0 <= d <= limit:
            cands.add(d)
    out = sorted(cands)
    if len(out) > cfg.max_candidates:
        step = len(out) / cfg.max_candidates
        out = [out[int(i * step)] for i in range(cfg.max_candidates)]
    return out


def best_shift(trace: JobTrace, free: IntervalSet,
               cfg: PlacementConfig,
               origin: float = 0.0) -> Optional[Tuple[float, float]]:
    """Min-cost feasible micro-shift for one group. (shift, cost) or None."""
    best: Optional[Tuple[float, float]] = None
    for delta in candidate_shifts(trace, free, cfg, origin):
        if not free.simulate_insert(trace.segments, origin + delta):
            continue
        cost = scheduling_cost(trace, delta, cfg)
        if best is None or cost < best[1]:
            best = (delta, cost)
    return best


def phase_interference(trace: JobTrace, shift: float,
                       group: NodeGroup, origin: float = 0.0) -> float:
    """Predicted overlap of the shifted active segments with resident jobs'
    active segments over one hyper-cycle (lower = better, §4.3.2)."""
    total = 0.0
    for placed in group.resident:
        for a, d in trace.segments:
            s0 = (origin + a + shift) % placed.trace.period
            for ra, rd in placed.trace.segments:
                rs = (placed.origin + ra + placed.shift) % placed.trace.period
                lo = max(s0, rs)
                hi = min(s0 + d, rs + rd)
                total += max(0.0, hi - lo)
    return total


class PlacementPolicy:
    """Dual-phase (cold/warm) placement over a set of node groups.

    Groups are dynamic: ``add_group`` / ``remove_group`` let a live capacity
    adjuster grow and shrink the fleet between fits."""

    def __init__(self, groups: Sequence[NodeGroup],
                 cfg: PlacementConfig = PlacementConfig()):
        self.groups = list(groups)
        self._by_id: Dict[int, NodeGroup] = {g.group_id: g for g in self.groups}
        self.cfg = cfg
        self.placed: Dict[str, Placed] = {}

    # ------------------------------------------------------ group registry
    def group(self, group_id: int) -> Optional[NodeGroup]:
        return self._by_id.get(group_id)

    def add_group(self, group: NodeGroup) -> NodeGroup:
        if group.group_id in self._by_id:
            raise ValueError(f"group {group.group_id} already registered")
        self.groups.append(group)
        self._by_id[group.group_id] = group
        return group

    def remove_group(self, group_id: int) -> NodeGroup:
        g = self._by_id.get(group_id)
        if g is None:
            raise KeyError(f"unknown group {group_id}")
        if g.resident:
            raise RuntimeError(
                f"group {group_id} still hosts {[p.job_id for p in g.resident]}")
        del self._by_id[group_id]
        self.groups = [x for x in self.groups if x.group_id != group_id]
        return g

    def _eligible(self, only: Optional[Sequence[int]]) -> List[NodeGroup]:
        if only is None:
            return self.groups
        allowed = set(only)
        return [g for g in self.groups if g.group_id in allowed]

    # ------------------------------------------------------------- place
    def place_cold(self, job_id: str, nodes: int,
                   expected_duration: float, origin: float = 0.0,
                   groups: Optional[Sequence[int]] = None) -> Optional[Placed]:
        """Cold start: dedicated group for clean profiling (no sharing)."""
        for g in self._eligible(groups):
            if g.nodes >= nodes and not g.resident and \
                    g.free.covers(origin, origin + expected_duration):
                g.free.allocate(origin, origin + expected_duration)
                p = Placed(job_id, JobTrace(expected_duration,
                                            ((0.0, expected_duration),),
                                            nodes), g.group_id, 0.0,
                           origin=origin, once=True, n_cycles=1)
                g.resident.append(p)
                self.placed[job_id] = p
                return p
        return None

    def place_warm(self, job_id: str, trace: JobTrace,
                   n_cycles: Optional[int] = None, origin: float = 0.0,
                   groups: Optional[Sequence[int]] = None) -> Optional[Placed]:
        """Warm start: micro-shift trace fitting over eligible groups."""
        cfg = self.cfg
        n_cycles = n_cycles or max(1, int(cfg.horizon // trace.period))
        scored: List[Tuple[float, float, NodeGroup, float]] = []
        for g in self._eligible(groups):
            if g.nodes < trace.nodes:
                continue
            fit = best_shift(trace, g.free, cfg, origin)
            if fit is None:
                continue
            delta, cost = fit
            interf = phase_interference(trace, delta, g, origin)
            scored.append((cost, interf, g, delta))
        if not scored:
            return None
        scored.sort(key=lambda t: (round(t[0], 6), t[1], t[2].group_id))
        cost, _, g, delta = scored[0]
        for c in range(n_cycles):
            base = origin + c * trace.period + delta
            for a, d in trace.segments:
                # subtract, not allocate: feasibility was checked for the
                # aligned cycle, but on a LIVE group later cycles may
                # partially overlap windows already carved by measured
                # completions (note_busy) — the window must end up busy
                # either way, never silently stay free
                g.free.subtract(base + a, base + a + d)
        p = Placed(job_id, trace, g.group_id, delta, origin=origin,
                   n_cycles=n_cycles)
        g.resident.append(p)
        self.placed[job_id] = p
        return p

    # ------------------------------------------------------------ remove
    def remove(self, job_id: str, n_cycles: Optional[int] = None):
        p = self.placed.pop(job_id, None)
        if p is None:
            return
        g = self._by_id.get(p.group_id)
        if g is None:
            return                     # group already retired
        g.resident = [r for r in g.resident if r.job_id != job_id]
        n_cycles = p.n_cycles or n_cycles or max(
            1, int(self.cfg.horizon // p.trace.period))
        freed_from = p.origin
        for c in range(n_cycles):
            base = p.origin + c * p.trace.period + p.shift
            for a, d in p.trace.segments:
                g.free.free(base + a, base + a + d)
        # projected cycles beyond the allocated block (extend_to carvings)
        if not p.once:
            anchor = p.origin + p.shift
            c = n_cycles
            while anchor + c * p.trace.period <= g.horizon_end:
                base = anchor + c * p.trace.period
                for a, d in p.trace.segments:
                    if base + a < g.horizon_end:
                        g.free.free(base + a, min(base + a + d, g.horizon_end))
                c += 1
        # the blanket free() above may have returned windows that OTHER
        # residents also occupy (overlapping projections are possible
        # beyond the feasibility-checked blocks): re-carve every remaining
        # resident over the affected span so their reservations survive
        for other in g.resident:
            g.carve_resident(other, freed_from, g.horizon_end)

    # ----------------------------------------------------------- repack
    def repack(self, origin: float = 0.0,
               groups: Optional[Sequence[int]] = None) -> int:
        """Repacking event (§4.3.2): re-fit all placed jobs by descending
        duty ratio. Returns the number of jobs that moved."""
        jobs = sorted(self.placed.items(),
                      key=lambda kv: -kv[1].trace.duty())
        for job_id, _ in jobs:
            self.remove(job_id)
        moved = 0
        for job_id, old in jobs:
            p = self.place_warm(job_id, old.trace, origin=origin,
                                groups=groups)
            if p is None:  # should not happen: it fitted before
                p = self.place_warm(job_id, old.trace, n_cycles=1,
                                    origin=origin, groups=groups)
            if p and (p.group_id != old.group_id or p.shift != old.shift):
                moved += 1
        return moved
