"""Job placement: spatio-temporal trace fitting (paper §4.3.2, Eq. 1-2).

A job's profiled cycle is a list of execution segments S = {(a_i, d_i)} with
period T and a node demand. Placement searches node groups and a Micro-Shift
delta in [0, alpha*T] minimising the Scheduling Cost

    J(delta) = w1 * (t_end(delta) - T)/T  +  w2 * delta/T        (Eq. 1)

subject to every shifted segment fitting a free window (Eq. 2). Candidate
deltas are the alignments of segment starts with free-window starts (the
classic critical-shift set), evaluated with IntervalSet bisects. Ties are
broken by predicted phase interference against resident jobs.

Cold start (no trace): a dedicated group is provisioned for clean profiling.
Warm start: trace fitting as above. A repacking event re-fits all profiled
jobs to raise packing density.

Live-plane operation (the control plane in ``core/control_plane.py``):
fitting takes an ``origin`` — the wall/virtual time the trace's cycle 0
starts — so free windows can be kept in absolute time. ``NodeGroup`` free
state is then maintained *incrementally*: ``note_busy`` carves actually
measured execution out of the free set as completions stream in,
``advance_to`` retires capacity behind ``now``, and ``extend_to`` rolls the
planning horizon forward (projecting resident jobs' periodic segments into
the new span). Groups can be added and removed at runtime
(``PlacementPolicy.add_group`` / ``remove_group``) — the hooks the capacity
adjuster drives.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler.intervals import IntervalSet

Segment = Tuple[float, float]          # (relative offset a_i, duration d_i)


@dataclasses.dataclass(frozen=True)
class JobTrace:
    """Profiled periodic demand: segments are the *active* (GPU-busy)
    execution windows within one period of length T."""
    period: float
    segments: Tuple[Segment, ...]
    nodes: int = 1

    def duty(self) -> float:
        return sum(d for _, d in self.segments) / self.period

    def end(self, shift: float = 0.0) -> float:
        return max((a + shift + d) for a, d in self.segments) if self.segments else 0.0


@dataclasses.dataclass
class NodeGroup:
    group_id: int
    nodes: int
    free: IntervalSet                   # free windows over the planning horizon
    resident: List["Placed"] = dataclasses.field(default_factory=list)
    horizon_end: float = 0.0            # absolute end of the planned span
    rev: int = 0                        # bumped on every resident change —
    #   the incremental repack planner's dirty-tracking signal
    interference_scale: float = 1.0     # EWMA correction the reconciler feeds
    #   back from realized busy overlap; multiplies phase_interference

    def __post_init__(self):
        if self.horizon_end == 0.0 and len(self.free):
            self.horizon_end = self.free.ends[-1]

    def occupancy(self, horizon: float) -> float:
        return 1.0 - self.free.total_free(horizon) / max(horizon * 1.0, 1e-9)

    # ------------------------------------------------- incremental updates
    def note_busy(self, t0: float, t1: float):
        """Carve an actually-measured execution window out of the free set
        (live completion feedback). Safe when the window overlaps segments
        the projected plan already consumed — only the intersection with
        still-free capacity is removed."""
        self.free.subtract(t0, t1)

    def advance_to(self, now: float):
        """Retire capacity behind ``now``: the past cannot be allocated."""
        self.free.trim_before(now)

    @staticmethod
    def _projected(p: "Placed", lo: float, hi: float):
        """Yield ``p``'s planned busy windows clipped to [lo, hi): the
        periodic projection of its trace segments from its anchor (one-shot
        cold reservations do not repeat). Single source of truth for both
        the free-set carving and the reconciler's drift measurement."""
        period = p.trace.period
        if period <= 0.0:
            return
        anchor = p.origin + p.shift
        c = 0 if p.once else max(0, int((lo - anchor) // period) - 1)
        while True:
            base = anchor + c * period
            if base > hi:
                break
            for a, d in p.trace.segments:
                s, e = base + a, base + a + d
                if e > lo and s < hi:
                    yield (max(s, lo), min(e, hi))
            if p.once:
                break
            c += 1

    def carve_resident(self, p: "Placed", lo: float, hi: float):
        """Subtract ``p``'s planned windows intersecting [lo, hi) from the
        free set (idempotent: already-busy spans stay busy)."""
        for s, e in self._projected(p, lo, hi):
            self.free.subtract(s, e)

    def carve_cycles(self, trace: JobTrace, shift: float, origin: float,
                     n_cycles: int, once: bool = False):
        """Subtract ``n_cycles`` of ``trace``'s segments anchored at
        ``origin + shift`` from the free set (``subtract``, not
        ``allocate``: on a live group later cycles may partially overlap
        windows already carved by measured completions — the span must end
        up busy either way). Single implementation behind ``place_warm``,
        ``place_at`` and the incremental planner's overlay."""
        for c in range(n_cycles):
            base = origin + c * trace.period + shift
            for a, d in trace.segments:
                self.free.subtract(base + a, base + a + d)
            if once:
                break

    def release_resident(self, p: "Placed", n_cycles: int):
        """Drop ``p`` from the residents and return its windows to the free
        set: the allocated cycle block plus the projected cycles beyond it
        (``extend_to`` carvings), MINUS the spans surviving residents'
        projections still occupy. Computed as one batched interval sweep
        (freed-union minus survivor-union, then ``free_many``) — the naive
        free-everything-then-re-carve-survivors version paid one bisecting
        list insert per window and dominated repack planning at fleet
        horizons. The group-local half of :meth:`PlacementPolicy.remove`,
        shared with the incremental planner's copy-on-write overlay."""
        self.resident = [r for r in self.resident if r.job_id != p.job_id]
        self.rev += 1
        freed: List[Tuple[float, float]] = []
        for c in range(n_cycles):
            base = p.origin + c * p.trace.period + p.shift
            for a, d in p.trace.segments:
                freed.append((base + a, base + a + d))
            if p.once:
                break
        if not p.once:
            anchor = p.origin + p.shift
            c = n_cycles
            while anchor + c * p.trace.period <= self.horizon_end:
                base = anchor + c * p.trace.period
                for a, d in p.trace.segments:
                    if base + a < self.horizon_end:
                        freed.append((base + a,
                                      min(base + a + d, self.horizon_end)))
                c += 1
        if not freed:
            return
        freed.sort()
        lo, hi = freed[0][0], max(e for _, e in freed)
        occupied: List[Tuple[float, float]] = []
        # survivors' planned windows clipped to [lo, hi) — the _projected
        # generator inlined: this loop enumerates every surviving window in
        # the span and generator frames double its cost at fleet horizons
        for other in self.resident:
            period = other.trace.period
            if period <= 0.0:
                continue
            anchor = other.origin + other.shift
            segs = other.trace.segments
            c = 0 if other.once else max(0, int((lo - anchor) // period) - 1)
            while True:
                base = anchor + c * period
                if base > hi:
                    break
                for a, d in segs:
                    s, e = base + a, base + a + d
                    if e > lo and s < hi:
                        occupied.append((s if s > lo else lo,
                                         e if e < hi else hi))
                if other.once:
                    break
                c += 1
        occupied.sort()

        def _union(ws):
            u: List[Tuple[float, float]] = []
            for s, e in ws:
                if u and s <= u[-1][1]:
                    if e > u[-1][1]:
                        u[-1] = (u[-1][0], e)
                else:
                    u.append((s, e))
            return u

        fu, ou = _union(freed), _union(occupied)
        give: List[Tuple[float, float]] = []
        j = 0
        for s, e in fu:
            cur = s
            while j < len(ou) and ou[j][1] <= cur:
                j += 1
            k = j
            while k < len(ou) and ou[k][0] < e:
                os_, oe = ou[k]
                if os_ > cur:
                    give.append((cur, os_))
                cur = oe
                if oe >= e:
                    break
                k += 1
            if cur < e:
                give.append((cur, e))
        self.free.free_many(give)

    def extend_to(self, new_end: float):
        """Roll the planning horizon forward to ``new_end``: the new span is
        freed, then every resident job's *periodic* segments are projected
        into it (one-shot cold reservations do not repeat)."""
        if new_end <= self.horizon_end:
            return
        old_end = self.horizon_end
        self.free.free(old_end, new_end)
        for p in self.resident:
            self.carve_resident(p, old_end, new_end)
        self.horizon_end = new_end

    def planned_windows(self, lo: float, hi: float) -> List[Tuple[float, float]]:
        """The PLAN's predicted busy windows over [lo, hi): the union of
        every resident's projected segments (merged, clipped). The live
        reconciler compares measured execution against this to detect
        realized-vs-planned occupancy drift."""
        out: List[Tuple[float, float]] = []
        for p in self.resident:
            out.extend(self._projected(p, lo, hi))
        return IntervalSet(out).intervals()

    def planned_overlap(self, t0: float, t1: float) -> float:
        """Seconds of [t0, t1) covered by the plan's predicted busy windows."""
        total = 0.0
        for s, e in self.planned_windows(t0, t1):
            total += max(0.0, min(e, t1) - max(s, t0))
        return total


@dataclasses.dataclass
class Placed:
    job_id: str
    trace: JobTrace
    group_id: int
    shift: float
    origin: float = 0.0                # absolute time of cycle 0's start
    once: bool = False                 # one-shot reservation (cold profiling)
    n_cycles: int = 0                  # cycles actually allocated


@dataclasses.dataclass(frozen=True)
class JobMove:
    """One planned live migration: re-fit ``job_id`` from ``src_group`` to
    ``dst_group`` at the new anchor (origin + shift). Carries the predicted
    interference delta and the pre-move placement so a failed realization
    can roll back exactly."""
    job_id: str
    src_group: int
    dst_group: int
    shift: float
    origin: float = 0.0
    gain: float = 0.0              # predicted interference reduction (s)
    vacates: bool = False          # last resident leaving src (consolidation)
    src_shift: float = 0.0
    src_origin: float = 0.0
    n_cycles: int = 0


@dataclasses.dataclass
class RepackPlan:
    """Result of :meth:`PlacementPolicy.plan_repack`: an ordered set of job
    moves (with predicted interference deltas) plus the same-group
    re-anchors, computed WITHOUT mutating the live placement state. Apply
    with :meth:`PlacementPolicy.apply_repack`, realize the moves through
    ``Router.reassign_jobs``."""
    origin: float
    moves: Tuple[JobMove, ...] = ()
    reshifts: Tuple[str, ...] = ()      # jobs re-anchored on their own group
    skipped: Tuple[JobMove, ...] = ()   # gain below the migration-cost floor
    fitted: Optional["PlacementPolicy"] = None   # the re-fitted state
    incremental: bool = False           # delta plan (RepackIndex): applied
    #   move-by-move via ``deltas`` instead of adopting a fitted clone
    deltas: Tuple[JobMove, ...] = ()    # ordered re-anchor sequence (cross-
    #   group moves AND same-group reshifts, in planning order)

    def __bool__(self) -> bool:
        return bool(self.moves or self.reshifts)


@dataclasses.dataclass(frozen=True)
class PlacementConfig:
    w1: float = 1.0                     # completion-delay weight
    w2: float = 0.25                    # start-shift weight
    alpha: float = 1.0                  # shift search range [0, alpha*T]
    horizon: float = 28_800.0
    max_candidates: int = 256


def scheduling_cost(trace: JobTrace, shift: float,
                    cfg: PlacementConfig) -> float:
    """Eq. 1."""
    t_end = trace.end(shift)
    return (cfg.w1 * (t_end - trace.period) / trace.period
            + cfg.w2 * shift / trace.period)


def candidate_shifts(trace: JobTrace, free: IntervalSet,
                     cfg: PlacementConfig, origin: float = 0.0) -> List[float]:
    """delta = window_start - segment_offset alignments, clipped to range.
    ``origin`` translates the trace into the free set's absolute frame."""
    cands = {0.0}
    limit = cfg.alpha * trace.period
    starts = free.starts
    for a, _ in trace.segments:
        # only window starts in [origin + a, origin + a + limit] can yield
        # an in-range delta — bisect the sorted starts instead of scanning
        # every free window (the free list grows with the horizon; the
        # search range is one period)
        lo = bisect.bisect_left(starts, origin + a)
        hi = bisect.bisect_right(starts, origin + a + limit)
        for ws in starts[lo:hi]:
            cands.add(ws - a - origin)
    out = sorted(cands)
    if len(out) > cfg.max_candidates:
        step = len(out) / cfg.max_candidates
        out = [out[int(i * step)] for i in range(cfg.max_candidates)]
    return out


def best_shift(trace: JobTrace, free: IntervalSet,
               cfg: PlacementConfig,
               origin: float = 0.0) -> Optional[Tuple[float, float]]:
    """Min-cost feasible micro-shift for one group. (shift, cost) or None."""
    best: Optional[Tuple[float, float]] = None
    for delta in candidate_shifts(trace, free, cfg, origin):
        if not free.simulate_insert(trace.segments, origin + delta):
            continue
        cost = scheduling_cost(trace, delta, cfg)
        if best is None or cost < best[1]:
            best = (delta, cost)
    return best


def wrapped_arcs(start: float, dur: float,
                 period: float) -> Tuple[Tuple[float, float], ...]:
    """The linear pieces of the arc ``[start, start+dur)`` on the circle
    ``[0, period)``: one piece when it fits, two when it crosses the period
    boundary, the whole circle when the duration covers it."""
    start %= period
    if dur >= period:
        return ((0.0, period),)
    end = start + dur
    if end <= period:
        return ((start, end),)
    return ((start, period), (0.0, end - period))


def phase_interference(trace: JobTrace, shift: float,
                       group: NodeGroup, origin: float = 0.0,
                       exclude: Optional[str] = None) -> float:
    """Predicted overlap of the shifted active segments with resident jobs'
    active segments over one hyper-cycle (lower = better, §4.3.2).

    Overlap is measured on each RESIDENT's cycle circle: both the
    candidate's shifted segments and the resident's anchored segments are
    wrapped at the resident's period boundary, so a segment crossing the
    cycle edge contributes its wrapped tail. (The pre-fix code clipped the
    overlap to ``[s0, s0+d) ∩ [rs, rs+rd)`` linearly, silently dropping
    anything past the boundary — interference near the cycle edge was
    systematically undercounted.) Mixed periods keep the paper's
    one-hyper-cycle approximation: the candidate is folded onto the
    resident's circle, i.e. the score is the overlap within one
    representative resident cycle, not the exact steady-state average over
    the full (possibly enormous) joint hyper-period.

    The sum is scaled by ``group.interference_scale`` — the reconciler's
    EWMA correction from realized busy overlap (1.0 = trust the
    prediction; a drifting group scores pessimistically so planners prefer
    placements with slack there).

    ``exclude`` skips one resident by job id — the form used when scoring a
    job that is itself already placed on the group (repack / shed ranking).
    """
    total = 0.0
    for placed in group.resident:
        if exclude is not None and placed.job_id == exclude:
            continue
        period = placed.trace.period
        if period <= 0.0:
            continue
        cand_arcs = [arc for a, d in trace.segments
                     for arc in wrapped_arcs(origin + a + shift, d, period)]
        for ra, rd in placed.trace.segments:
            for r_lo, r_hi in wrapped_arcs(placed.origin + ra + placed.shift,
                                           rd, period):
                for s_lo, s_hi in cand_arcs:
                    total += max(0.0, min(s_hi, r_hi) - max(s_lo, r_lo))
    return total * group.interference_scale


def group_duty(group: NodeGroup) -> float:
    """Aggregate duty demand of a group's residents in node-duty units."""
    return sum(p.trace.duty() * p.trace.nodes for p in group.resident)


def least_interfering_group(trace: JobTrace, groups: Sequence[NodeGroup],
                            duty_cap: float = 1.0,
                            origin: float = 0.0) -> Optional[NodeGroup]:
    """Shared §4.3.2 ranking consumed by BOTH the offline simulator
    (``ClusterSim._choose_group``) and the live reconciler: the group
    minimising (predicted phase interference, duty load, id) among those
    with duty headroom for the trace. None when no group has headroom."""
    best, best_key = None, None
    for g in groups:
        duty = group_duty(g)
        if duty + trace.duty() * trace.nodes > g.nodes * duty_cap:
            continue
        key = (phase_interference(trace, 0.0, g, origin), duty, g.group_id)
        if best_key is None or key < best_key:
            best, best_key = g, key
    return best


class PlacementPolicy:
    """Dual-phase (cold/warm) placement over a set of node groups.

    Groups are dynamic: ``add_group`` / ``remove_group`` let a live capacity
    adjuster grow and shrink the fleet between fits."""

    def __init__(self, groups: Sequence[NodeGroup],
                 cfg: PlacementConfig = PlacementConfig()):
        self.groups = list(groups)
        self._by_id: Dict[int, NodeGroup] = {g.group_id: g for g in self.groups}
        self.cfg = cfg
        self.placed: Dict[str, Placed] = {}

    # ------------------------------------------------------ group registry
    def group(self, group_id: int) -> Optional[NodeGroup]:
        return self._by_id.get(group_id)

    def add_group(self, group: NodeGroup) -> NodeGroup:
        if group.group_id in self._by_id:
            raise ValueError(f"group {group.group_id} already registered")
        self.groups.append(group)
        self._by_id[group.group_id] = group
        return group

    def remove_group(self, group_id: int) -> NodeGroup:
        g = self._by_id.get(group_id)
        if g is None:
            raise KeyError(f"unknown group {group_id}")
        if g.resident:
            raise RuntimeError(
                f"group {group_id} still hosts {[p.job_id for p in g.resident]}")
        del self._by_id[group_id]
        self.groups = [x for x in self.groups if x.group_id != group_id]
        return g

    def _eligible(self, only: Optional[Sequence[int]]) -> List[NodeGroup]:
        if only is None:
            return self.groups
        allowed = set(only)
        return [g for g in self.groups if g.group_id in allowed]

    # ------------------------------------------------------------- place
    def place_cold(self, job_id: str, nodes: int,
                   expected_duration: float, origin: float = 0.0,
                   groups: Optional[Sequence[int]] = None) -> Optional[Placed]:
        """Cold start: dedicated group for clean profiling (no sharing)."""
        for g in self._eligible(groups):
            if g.nodes >= nodes and not g.resident and \
                    g.free.covers(origin, origin + expected_duration):
                g.free.allocate(origin, origin + expected_duration)
                p = Placed(job_id, JobTrace(expected_duration,
                                            ((0.0, expected_duration),),
                                            nodes), g.group_id, 0.0,
                           origin=origin, once=True, n_cycles=1)
                g.resident.append(p)
                g.rev += 1
                self.placed[job_id] = p
                return p
        return None

    def place_warm(self, job_id: str, trace: JobTrace,
                   n_cycles: Optional[int] = None, origin: float = 0.0,
                   groups: Optional[Sequence[int]] = None,
                   pack: bool = False,
                   prefer: Optional[int] = None) -> Optional[Placed]:
        """Warm start: micro-shift trace fitting over eligible groups.

        ``pack`` breaks score ties toward groups already hosting residents
        (repacking density) and ``prefer`` toward one group id (a repack
        keeping a job where it is costs no migration); both only reorder
        EQUAL (cost, interference) candidates, so default fits are
        unchanged."""
        cfg = self.cfg
        n_cycles = n_cycles or max(1, int(cfg.horizon // trace.period))
        scored: List[Tuple[tuple, NodeGroup, float]] = []
        for g in self._eligible(groups):
            if g.nodes < trace.nodes:
                continue
            fit = best_shift(trace, g.free, cfg, origin)
            if fit is None:
                continue
            delta, cost = fit
            interf = phase_interference(trace, delta, g, origin)
            key = (round(cost, 6), interf,
                   -len(g.resident) if pack else 0,
                   0 if g.group_id == prefer else 1,
                   g.group_id)
            scored.append((key, g, delta))
        if not scored:
            return None
        scored.sort(key=lambda t: t[0])
        _, g, delta = scored[0]
        g.carve_cycles(trace, delta, origin, n_cycles)
        p = Placed(job_id, trace, g.group_id, delta, origin=origin,
                   n_cycles=n_cycles)
        g.resident.append(p)
        g.rev += 1
        self.placed[job_id] = p
        return p

    def place_at(self, job_id: str, trace: JobTrace, group_id: int,
                 shift: float, origin: float = 0.0, n_cycles: int = 0,
                 once: bool = False) -> Placed:
        """Pin a job at an EXACT (group, shift, origin) — no search. Used to
        restore a placement (failed-migration rollback, plan restore) and to
        realize a planned assignment verbatim. Windows are carved with
        ``subtract`` so re-pinning over partially measured spans is safe."""
        g = self._by_id[group_id]
        n = n_cycles or max(1, int(self.cfg.horizon
                                   // max(trace.period, 1e-9)))
        g.carve_cycles(trace, shift, origin, n, once=once)
        p = Placed(job_id, trace, group_id, shift, origin=origin, once=once,
                   n_cycles=n)
        g.resident.append(p)
        g.rev += 1
        self.placed[job_id] = p
        return p

    def clone(self) -> "PlacementPolicy":
        """Deep copy of the placement state (free windows, residents,
        placed map). ``Placed`` records are shared — they are treated as
        immutable everywhere — so a clone is cheap: two float lists per
        group. ``plan_repack`` fits against a clone so planning never
        mutates the live state."""
        groups = []
        for g in self.groups:
            c = NodeGroup(g.group_id, g.nodes,
                          IntervalSet(g.free.intervals()),
                          resident=list(g.resident),
                          horizon_end=g.horizon_end,
                          rev=g.rev,
                          interference_scale=g.interference_scale)
            groups.append(c)
        out = PlacementPolicy(groups, self.cfg)
        out.placed = dict(self.placed)
        return out

    # ------------------------------------------------------------ remove
    def remove(self, job_id: str, n_cycles: Optional[int] = None):
        p = self.placed.pop(job_id, None)
        if p is None:
            return
        g = self._by_id.get(p.group_id)
        if g is None:
            return                     # group already retired
        n_cycles = p.n_cycles or n_cycles or max(
            1, int(self.cfg.horizon // p.trace.period))
        g.release_resident(p, n_cycles)

    # ----------------------------------------------------------- repack
    def plan_repack(self, origin: float = 0.0,
                    groups: Optional[Sequence[int]] = None,
                    min_gain: float = 0.0,
                    cross_min_gain: Optional[float] = None,
                    mesh_of: Optional[Dict[int, int]] = None,
                    exclude: frozenset = frozenset()) -> RepackPlan:
        """Plan a repacking event (§4.3.2) WITHOUT mutating the live state.

        Jobs are re-fitted one at a time on a clone, by descending duty
        ratio, against live absolute-time windows (``origin`` = now). The
        result is an ordered migration plan: group-changing moves carry
        their predicted interference delta, and a move whose gain is below
        the migration-cost floor is skipped — unless it vacates its source
        group, since retiring a whole group always beats a
        millisecond-scale migration. One-shot cold reservations are pinned
        and never repacked.

        The floor is mesh-domain-aware: ``min_gain`` applies to moves
        within one mesh domain (fed from the measured
        ``placement/repack_migrate_s`` bench), while a move that crosses
        domains in ``mesh_of`` (group id -> mesh-slice index) must clear
        ``cross_min_gain`` — the realized cross-mesh reshard cost the
        director measures from ``Router.migrate_log``. Unknown groups are
        treated as crossing (the conservative floor).

        ``exclude`` pins jobs in place without re-fitting them — the
        director feeds it the recently-migrated set so the cooldown
        hysteresis also holds for full repacks."""
        clone = self.clone()
        for g in clone.groups:
            g.advance_to(origin)
        jobs = sorted(((j, p) for j, p in clone.placed.items()
                       if not p.once and j not in exclude),
                      key=lambda kv: (-kv[1].trace.duty(), kv[0]))
        moves: List[JobMove] = []
        reshifts: List[str] = []
        skipped: List[JobMove] = []
        for job_id, old in jobs:
            g_old = clone.group(old.group_id)
            if g_old is None:
                continue
            before = phase_interference(old.trace, old.shift, g_old,
                                        old.origin, exclude=job_id)
            was_last = len(g_old.resident) == 1
            clone.remove(job_id)
            p = clone.place_warm(job_id, old.trace,
                                 n_cycles=old.n_cycles or None,
                                 origin=origin, groups=groups,
                                 pack=True, prefer=old.group_id)
            if p is None:
                clone.place_at(job_id, old.trace, old.group_id, old.shift,
                               origin=old.origin, n_cycles=old.n_cycles)
                continue
            if p.group_id == old.group_id:
                if p.shift != old.shift or p.origin != old.origin:
                    reshifts.append(job_id)
                continue
            after = phase_interference(old.trace, p.shift,
                                       clone.group(p.group_id), origin,
                                       exclude=job_id)
            move = JobMove(job_id, old.group_id, p.group_id, p.shift,
                           origin=origin, gain=before - after,
                           vacates=was_last, src_shift=old.shift,
                           src_origin=old.origin, n_cycles=p.n_cycles)
            floor = min_gain
            if cross_min_gain is not None and mesh_of is not None:
                src_dom = mesh_of.get(old.group_id)
                dst_dom = mesh_of.get(p.group_id)
                if src_dom is None or dst_dom is None or src_dom != dst_dom:
                    floor = max(floor, cross_min_gain)
            if not move.vacates and move.gain < floor:
                clone.remove(job_id)
                clone.place_at(job_id, old.trace, old.group_id, old.shift,
                               origin=old.origin, n_cycles=old.n_cycles)
                skipped.append(move)
            else:
                moves.append(move)
        return RepackPlan(origin, tuple(moves), tuple(reshifts),
                          tuple(skipped), fitted=clone)

    def apply_repack(self, plan: RepackPlan):
        """Adopt a plan's re-fitted placement state. Call under the same
        lock / quiescence the plan was computed under — the plan's windows
        are a re-fit of the state as of ``plan.origin``.

        An incremental plan (``plan.incremental``) carries no fitted clone;
        its ordered ``deltas`` are replayed move-by-move (remove + pin at
        the planned anchor). A delta whose job has since vanished or moved
        off the planned source group is stale and skipped."""
        if plan.incremental:
            for m in plan.deltas:
                cur = self.placed.get(m.job_id)
                if cur is None or cur.group_id != m.src_group:
                    continue           # stale: state changed since planning
                if self.group(m.dst_group) is None:
                    continue
                self.remove(m.job_id)
                self.place_at(m.job_id, cur.trace, m.dst_group, m.shift,
                              origin=m.origin, n_cycles=m.n_cycles)
            return
        if plan.fitted is None:
            raise ValueError("plan has no fitted state (already applied?)")
        src = plan.fitted
        self.groups = src.groups
        self._by_id = src._by_id
        self.placed = src.placed
        plan.fitted = None

    def repack(self, origin: float = 0.0,
               groups: Optional[Sequence[int]] = None,
               min_gain: float = 0.0,
               cross_min_gain: Optional[float] = None,
               mesh_of: Optional[Dict[int, int]] = None) -> int:
        """Repacking event (§4.3.2), plan-then-apply: re-fit all placed jobs
        by descending duty ratio. Returns the number of jobs whose
        assignment changed (moved groups or re-anchored)."""
        plan = self.plan_repack(origin=origin, groups=groups,
                                min_gain=min_gain,
                                cross_min_gain=cross_min_gain,
                                mesh_of=mesh_of)
        self.apply_repack(plan)
        return len(plan.moves) + len(plan.reshifts)
