"""Job placement: spatio-temporal trace fitting (paper §4.3.2, Eq. 1-2).

A job's profiled cycle is a list of execution segments S = {(a_i, d_i)} with
period T and a node demand. Placement searches node groups and a Micro-Shift
delta in [0, alpha*T] minimising the Scheduling Cost

    J(delta) = w1 * (t_end(delta) - T)/T  +  w2 * delta/T        (Eq. 1)

subject to every shifted segment fitting a free window (Eq. 2). Candidate
deltas are the alignments of segment starts with free-window starts (the
classic critical-shift set), evaluated with IntervalSet bisects. Ties are
broken by predicted phase interference against resident jobs.

Cold start (no trace): a dedicated group is provisioned for clean profiling.
Warm start: trace fitting as above. A repacking event re-fits all profiled
jobs to raise packing density.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler.intervals import IntervalSet

Segment = Tuple[float, float]          # (relative offset a_i, duration d_i)


@dataclasses.dataclass(frozen=True)
class JobTrace:
    """Profiled periodic demand: segments are the *active* (GPU-busy)
    execution windows within one period of length T."""
    period: float
    segments: Tuple[Segment, ...]
    nodes: int = 1

    def duty(self) -> float:
        return sum(d for _, d in self.segments) / self.period

    def end(self, shift: float = 0.0) -> float:
        return max((a + shift + d) for a, d in self.segments) if self.segments else 0.0


@dataclasses.dataclass
class NodeGroup:
    group_id: int
    nodes: int
    free: IntervalSet                   # free windows over the planning horizon
    resident: List["Placed"] = dataclasses.field(default_factory=list)

    def occupancy(self, horizon: float) -> float:
        return 1.0 - self.free.total_free(horizon) / max(horizon * 1.0, 1e-9)


@dataclasses.dataclass
class Placed:
    job_id: str
    trace: JobTrace
    group_id: int
    shift: float


@dataclasses.dataclass(frozen=True)
class PlacementConfig:
    w1: float = 1.0                     # completion-delay weight
    w2: float = 0.25                    # start-shift weight
    alpha: float = 1.0                  # shift search range [0, alpha*T]
    horizon: float = 28_800.0
    max_candidates: int = 256


def scheduling_cost(trace: JobTrace, shift: float,
                    cfg: PlacementConfig) -> float:
    """Eq. 1."""
    t_end = trace.end(shift)
    return (cfg.w1 * (t_end - trace.period) / trace.period
            + cfg.w2 * shift / trace.period)


def candidate_shifts(trace: JobTrace, free: IntervalSet,
                     cfg: PlacementConfig) -> List[float]:
    """delta = window_start - segment_offset alignments, clipped to range."""
    cands = {0.0}
    limit = cfg.alpha * trace.period
    for (a, _), (ws, _) in itertools.product(trace.segments, free.intervals()):
        d = ws - a
        if 0.0 <= d <= limit:
            cands.add(d)
    out = sorted(cands)
    if len(out) > cfg.max_candidates:
        step = len(out) / cfg.max_candidates
        out = [out[int(i * step)] for i in range(cfg.max_candidates)]
    return out


def best_shift(trace: JobTrace, free: IntervalSet,
               cfg: PlacementConfig) -> Optional[Tuple[float, float]]:
    """Min-cost feasible micro-shift for one group. (shift, cost) or None."""
    best: Optional[Tuple[float, float]] = None
    for delta in candidate_shifts(trace, free, cfg):
        if not free.simulate_insert(trace.segments, delta):
            continue
        cost = scheduling_cost(trace, delta, cfg)
        if best is None or cost < best[1]:
            best = (delta, cost)
    return best


def phase_interference(trace: JobTrace, shift: float,
                       group: NodeGroup) -> float:
    """Predicted overlap of the shifted active segments with resident jobs'
    active segments over one hyper-cycle (lower = better, §4.3.2)."""
    total = 0.0
    for placed in group.resident:
        for a, d in trace.segments:
            s0 = (a + shift) % placed.trace.period
            for ra, rd in placed.trace.segments:
                rs = (ra + placed.shift) % placed.trace.period
                lo = max(s0, rs)
                hi = min(s0 + d, rs + rd)
                total += max(0.0, hi - lo)
    return total


class PlacementPolicy:
    """Dual-phase (cold/warm) placement over a set of node groups."""

    def __init__(self, groups: Sequence[NodeGroup],
                 cfg: PlacementConfig = PlacementConfig()):
        self.groups = list(groups)
        self.cfg = cfg
        self.placed: Dict[str, Placed] = {}

    # ------------------------------------------------------------- place
    def place_cold(self, job_id: str, nodes: int,
                   expected_duration: float) -> Optional[Placed]:
        """Cold start: dedicated group for clean profiling (no sharing)."""
        for g in self.groups:
            if g.nodes >= nodes and not g.resident and \
                    g.free.covers(0.0, expected_duration):
                g.free.allocate(0.0, expected_duration)
                p = Placed(job_id, JobTrace(expected_duration,
                                            ((0.0, expected_duration),),
                                            nodes), g.group_id, 0.0)
                g.resident.append(p)
                self.placed[job_id] = p
                return p
        return None

    def place_warm(self, job_id: str, trace: JobTrace,
                   n_cycles: Optional[int] = None) -> Optional[Placed]:
        """Warm start: micro-shift trace fitting over eligible groups."""
        cfg = self.cfg
        n_cycles = n_cycles or max(1, int(cfg.horizon // trace.period))
        scored: List[Tuple[float, float, NodeGroup, float]] = []
        for g in self.groups:
            if g.nodes < trace.nodes:
                continue
            fit = best_shift(trace, g.free, cfg)
            if fit is None:
                continue
            delta, cost = fit
            interf = phase_interference(trace, delta, g)
            scored.append((cost, interf, g, delta))
        if not scored:
            return None
        scored.sort(key=lambda t: (round(t[0], 6), t[1], t[2].group_id))
        cost, _, g, delta = scored[0]
        for c in range(n_cycles):
            base = c * trace.period
            for a, d in trace.segments:
                g.free.allocate(base + a + delta, base + a + delta + d)
        p = Placed(job_id, trace, g.group_id, delta)
        g.resident.append(p)
        self.placed[job_id] = p
        return p

    # ------------------------------------------------------------ remove
    def remove(self, job_id: str, n_cycles: Optional[int] = None):
        p = self.placed.pop(job_id, None)
        if p is None:
            return
        g = next(g for g in self.groups if g.group_id == p.group_id)
        g.resident = [r for r in g.resident if r.job_id != job_id]
        n_cycles = n_cycles or max(1, int(self.cfg.horizon // p.trace.period))
        for c in range(n_cycles):
            base = c * p.trace.period
            for a, d in p.trace.segments:
                g.free.free(base + a + p.shift, base + a + p.shift + d)

    # ----------------------------------------------------------- repack
    def repack(self) -> int:
        """Repacking event (§4.3.2): re-fit all placed jobs by descending
        duty ratio. Returns the number of jobs that moved."""
        jobs = sorted(self.placed.items(),
                      key=lambda kv: -kv[1].trace.duty())
        for job_id, _ in jobs:
            self.remove(job_id)
        moved = 0
        for job_id, old in jobs:
            p = self.place_warm(job_id, old.trace)
            if p is None:  # should not happen: it fitted before
                p = self.place_warm(job_id, old.trace, n_cycles=1)
            if p and (p.group_id != old.group_id or p.shift != old.shift):
                moved += 1
        return moved
