"""HRRS — Highest Response Ratio with Setup (paper §4.4, Algorithm 1).

Extends HRRN with the context-switch setup cost in the denominator:

    P_i(t) = rho_i * (W_i(t) + S_i(t)) / S_i(t)
           = rho_i * (1 + W_i / (E_i + 1_switch * C_setup))

which batches same-deployment requests to amortise offload/load cycles while
ageing prevents starvation. ``rho_i`` is the request's *tenant priority*
(multi-tenant service layer): a multiplicative weight on the whole score
line, 1.0 for the default tenant. The multiplicative form is deliberate —
for t >= a_i each score stays a LINE in t (slope rho/s, intercept rho at
arrival), so any two scores still cross at most once and the kinetic
tournament in ``admission_index.py`` remains a valid incremental argmax.
A priority-2 tenant's requests age twice as fast; starvation-freedom is
preserved because every line has positive slope. ``schedule`` is the
faithful Algorithm 1: score all requests (running + queued + new), sort by
score, then replay them onto a cursor timeline, prepending offload+load
whenever the job changes.

Scoring is side-effect free: ``queued_score``/``score_request`` are pure
functions of (request, now, resident job, setup cost), and ``schedule`` no
longer writes ``Request.score`` — so the incremental admission index
(``admission_index.py``) and this full-re-score oracle can score the SAME
request pool without interfering with each other. ``Request.score`` is kept
as an informational field for callers that want to stash a score, but nothing
in this module reads or writes it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Request:
    req_id: int
    job_id: str
    op: str                      # generate / forward / forward_backward / ...
    exec_time: float             # E_i estimate (profiled)
    arrival_time: float
    remaining_time: float = 0.0  # for the running request
    running: bool = False
    payload: object = None       # opaque: closure / simulated work descriptor
    priority: float = 1.0        # tenant priority rho (multiplicative score
                                 # weight; 1.0 = default tenant)
    score: float = 0.0           # informational scratch only; scoring is pure
                                 # (schedule never reads or writes this)


@dataclasses.dataclass
class Assignment:
    request: Request
    t_start: float
    t_end: float
    switched: bool


def hrrs_score(wait: float, exec_time: float, switch: bool,
               setup_cost: float, priority: float = 1.0) -> float:
    s = exec_time + (setup_cost if switch else 0.0)
    s = max(s, 1e-9)
    return priority * ((wait + s) / s)


def queued_score(exec_time: float, arrival_time: float, now: float,
                 switch: bool, setup: float, priority: float = 1.0) -> float:
    """Pure P_i(t) for a queued request: the one scoring formula shared by
    Algorithm 1's full re-score and the incremental admission index (both
    must produce bit-identical floats for the equivalence guarantee).
    ``priority`` multiplies the whole score; the default 1.0 is exact
    (``1.0 * x == x`` bit-for-bit) so untenanted callers are unchanged."""
    return hrrs_score(max(0.0, now - arrival_time), exec_time, switch, setup,
                      priority)


def score_request(r: Request, now: float, current_job: Optional[str],
                  setup: float) -> float:
    """Pure Algorithm-1 score for ``r`` (does NOT mutate ``r``)."""
    if r.running:
        return queued_score(r.remaining_time, r.arrival_time, now,
                            switch=False, setup=0.0, priority=r.priority)
    return queued_score(r.exec_time, r.arrival_time, now,
                        switch=r.job_id != current_job, setup=setup,
                        priority=r.priority)


def sort_key(r: Request, now: float, current_job: Optional[str],
             setup: float) -> Tuple[float, float, int]:
    """Algorithm 1's total admission order (highest score first; ties by
    arrival, then req_id). Exported so the admission index can break
    cross-bucket ties with the exact same key."""
    return (-score_request(r, now, current_job, setup),
            r.arrival_time, r.req_id)


def schedule(new_request: Optional[Request],
             running: Optional[Request],
             queued: Sequence[Request],
             now: float,
             current_job: Optional[str],
             t_load: float,
             t_offload: float) -> List[Assignment]:
    """Algorithm 1. Returns the re-planned timeline (V')."""
    omega: List[Request] = []
    if new_request is not None:
        omega.append(new_request)
    if running is not None:
        omega.append(running)
    omega.extend(queued)

    setup = t_load + t_offload
    omega.sort(key=lambda r: sort_key(r, now, current_job, setup))

    plan: List[Assignment] = []
    cursor = now
    resident = current_job
    first = True
    for r in omega:
        switched = False
        if r.running:
            dur = r.remaining_time
        else:
            if first and running is not None and r is not running:
                # preempting the running request costs its offload too
                switched = True
            elif r.job_id != resident:
                switched = True
            dur = r.exec_time
        if switched:
            cursor += setup
        t_start = cursor
        t_end = t_start + dur
        plan.append(Assignment(r, t_start, t_end, switched))
        cursor = t_end
        resident = r.job_id
        first = False
    return plan


def fcfs_schedule(new_request: Optional[Request],
                  running: Optional[Request],
                  queued: Sequence[Request],
                  now: float,
                  current_job: Optional[str],
                  t_load: float,
                  t_offload: float) -> List[Assignment]:
    """First-come-first-served baseline (paper §4.4's strawman)."""
    omega: List[Request] = []
    if running is not None:
        omega.append(running)
    omega.extend(queued)
    if new_request is not None:
        omega.append(new_request)
    omega.sort(key=lambda r: (not r.running, r.arrival_time, r.req_id))
    plan: List[Assignment] = []
    cursor = now
    resident = current_job
    setup = t_load + t_offload
    for r in omega:
        switched = (not r.running) and r.job_id != resident
        if switched:
            cursor += setup
        dur = r.remaining_time if r.running else r.exec_time
        plan.append(Assignment(r, cursor, cursor + dur, switched))
        cursor += dur
        resident = r.job_id
    return plan


def total_switches(plan: Sequence[Assignment]) -> int:
    return sum(1 for a in plan if a.switched)


def makespan(plan: Sequence[Assignment]) -> float:
    return plan[-1].t_end if plan else 0.0
