"""Lazy range-add / range-min segment tree over the capacity ring buffer.

Backs the Global Capacity Profile's O(log T) gang-feasibility pruning
(paper §5.2.1: "Segment Tree Pruning ... filters out over 80% of the search
space before accessing granular states").
"""
from __future__ import annotations

from typing import List


class MinSegmentTree:
    """Range-add, range-min, point-query segment tree (lazy propagation)."""

    def __init__(self, values: List[float]):
        self.n = len(values)
        size = 1
        while size < self.n:
            size *= 2
        self.size = size
        inf = float("inf")
        self.mn = [inf] * (2 * size)
        self.lz = [0.0] * (2 * size)
        for i, v in enumerate(values):
            self.mn[size + i] = float(v)
        for i in range(size - 1, 0, -1):
            self.mn[i] = min(self.mn[2 * i], self.mn[2 * i + 1])

    # ------------------------------------------------------------ internal
    def _push(self, node: int):
        if self.lz[node]:
            for child in (2 * node, 2 * node + 1):
                self.mn[child] += self.lz[node]
                self.lz[child] += self.lz[node]
            self.lz[node] = 0.0

    def _add(self, node, node_l, node_r, l, r, delta):
        if r <= node_l or node_r <= l:
            return
        if l <= node_l and node_r <= r:
            self.mn[node] += delta
            self.lz[node] += delta
            return
        self._push(node)
        mid = (node_l + node_r) // 2
        self._add(2 * node, node_l, mid, l, r, delta)
        self._add(2 * node + 1, mid, node_r, l, r, delta)
        self.mn[node] = min(self.mn[2 * node], self.mn[2 * node + 1])

    def _min(self, node, node_l, node_r, l, r) -> float:
        if r <= node_l or node_r <= l:
            return float("inf")
        if l <= node_l and node_r <= r:
            return self.mn[node]
        self._push(node)
        mid = (node_l + node_r) // 2
        return min(self._min(2 * node, node_l, mid, l, r),
                   self._min(2 * node + 1, mid, node_r, l, r))

    # ------------------------------------------------------------- public
    def add(self, l: int, r: int, delta: float):
        """values[l:r] += delta."""
        if l < r:
            self._add(1, 0, self.size, l, r, delta)

    def range_min(self, l: int, r: int) -> float:
        """min(values[l:r])."""
        if l >= r:
            return float("inf")
        return self._min(1, 0, self.size, l, r)

    def point(self, i: int) -> float:
        return self.range_min(i, i + 1)
