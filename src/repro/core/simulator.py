"""Trace-driven discrete-event cluster simulator (paper §6.3, Fig. 8).

Replays a job mix through four scheduling policies:

- ``isolated``        — job-local reservation: a job holds `nodes` dedicated
                        nodes for its entire lifetime; arrivals queue FIFO.
- ``pack``            — shared groups, densest-first placement, FIFO wake
                        (head-of-line blocking preserved).
- ``spread``          — placement minimises predicted phase interference
                        against resident jobs (PlacementPolicy ranking).
- ``spread_backfill`` — spread + backfill: on wake, scan the whole wait
                        queue and start anything that fits.

Per §6.3's setup: function invocations within a job are strictly serial
(modulo optional one-step async rollout), and rollout runs on per-job
capacity while the shared pool serves the training-side functions.

Outputs: per-job normalised queueing delay (wait / ideal duration), makespan,
per-pool busy time (for GPU-hour billing), switch counts.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler.placement import (
    JobTrace, NodeGroup, PlacementConfig, PlacementPolicy, group_duty,
    least_interfering_group)
from repro.core.scheduler.intervals import IntervalSet
from repro.core.traces import PhaseProfile

PHASES = ("rollout", "compute_log_prob", "update_actor", "sync_weight")
SHARED = {"compute_log_prob", "update_actor", "sync_weight"}


@dataclasses.dataclass
class SimJob:
    job_id: str
    profile: PhaseProfile
    steps: int
    arrival: float
    # runtime state
    group: Optional[int] = None
    step_idx: int = 0
    phase_idx: int = 0
    t_admitted: float = -1.0
    t_done: float = -1.0
    wait_time: float = 0.0
    busy_shared: float = 0.0
    busy_rollout: float = 0.0
    switch_overhead: float = 0.0
    cycles: List[Dict[str, float]] = dataclasses.field(default_factory=list)

    def ideal_duration(self) -> float:
        return sum(sum(c.values()) for c in self.cycles)


@dataclasses.dataclass
class SimResult:
    policy: str
    jobs: List[SimJob]
    makespan: float
    shared_busy: float
    shared_capacity_time: float

    def norm_delays(self) -> np.ndarray:
        out = []
        for j in self.jobs:
            ideal = max(j.ideal_duration(), 1e-9)
            out.append(j.wait_time / ideal)
        return np.array(out)

    def utilization(self) -> float:
        return self.shared_busy / max(self.shared_capacity_time, 1e-9)


class _Group:
    def __init__(self, gid: int, capacity: int):
        self.gid = gid
        self.capacity = capacity
        self.free = capacity
        self.queue: List[Tuple[float, int, "SimJob", str, float, int]] = []
        self.resident_job: Optional[str] = None
        self.switches = 0


class ClusterSim:
    def __init__(self, total_nodes: int = 32, group_size: int = 8,
                 policy: str = "spread_backfill", seed: int = 0,
                 switch_cost: float = 4.0, horizon: float = 28_800.0,
                 duty_cap: float = 0.9):
        assert total_nodes % group_size == 0
        self.policy = policy
        self.switch_cost = switch_cost
        self.duty_cap = duty_cap
        self.rng = np.random.default_rng(seed)
        self.groups = [_Group(i, group_size)
                       for i in range(total_nodes // group_size)]
        self.placer = PlacementPolicy(
            [NodeGroup(g.gid, group_size, IntervalSet([(0.0, horizon)]))
             for g in self.groups],
            PlacementConfig(horizon=horizon))
        self._events: List[Tuple[float, int, object, tuple]] = []
        self._eseq = itertools.count()
        self.now = 0.0
        self._iso_free = total_nodes
        self._iso_queue: List[SimJob] = []
        self._busy_shared = 0.0

    # ---------------------------------------------------------- event core
    def _push(self, t: float, fn, *args):
        heapq.heappush(self._events, (t, next(self._eseq), fn, args))

    def run(self, jobs: Sequence[SimJob]) -> SimResult:
        for j in jobs:
            # pre-sample every cycle for determinism across policies
            j.cycles = [j.profile.sample_cycle(self.rng)
                        for _ in range(j.steps)]
            self._push(j.arrival, self._on_arrival, j)
        while self._events:
            t, _, fn, args = heapq.heappop(self._events)
            self.now = max(self.now, t)
            fn(*args)
        makespan = max((j.t_done for j in jobs), default=0.0) - \
            min((j.arrival for j in jobs), default=0.0)
        cap_time = sum(g.capacity for g in self.groups) * max(makespan, 1e-9)
        return SimResult(self.policy, list(jobs), makespan,
                         self._busy_shared, cap_time)

    # ------------------------------------------------------------ arrival
    def _on_arrival(self, job: SimJob):
        if self.policy == "isolated":
            self._iso_queue.append(job)
            self._try_admit_isolated()
            return
        group = self._choose_group(job)
        job.group = group.gid
        job.t_admitted = self.now
        self._start_phase(job)

    def _try_admit_isolated(self):
        while self._iso_queue:
            job = self._iso_queue[0]
            if job.profile.nodes > self._iso_free:
                break
            self._iso_queue.pop(0)
            self._iso_free -= job.profile.nodes
            job.group = 0
            job.t_admitted = self.now
            job.wait_time += self.now - job.arrival
            self._start_phase(job, isolated=True)

    def _choose_group(self, job: SimJob) -> _Group:
        trace = job.profile.mean_trace()
        if self.policy == "pack":
            # densest-first: the most-loaded group that still fits
            def load(g: _Group):
                return group_duty(self.placer.groups[g.gid])
            cands = [g for g in self.groups if g.capacity >= job.profile.nodes]
            cands.sort(key=lambda g: (-load(g), g.gid))
            for g in cands:
                duty = load(g) + trace.duty() * trace.nodes
                if duty <= g.capacity:
                    break
            else:
                g = min(self.groups, key=load)
        else:
            # spread / spread_backfill: min predicted interference — the
            # SAME ranking (placement.least_interfering_group) the live
            # reconciler uses, so simulation and the serve plane can never
            # disagree on this scoring
            ng = least_interfering_group(trace, self.placer.groups,
                                         duty_cap=self.duty_cap)
            g = (self.groups[ng.group_id] if ng is not None
                 else min(self.groups, key=lambda gg: group_duty(
                     self.placer.groups[gg.gid])))
        from repro.core.scheduler.placement import Placed
        self.placer.groups[g.gid].resident.append(
            Placed(job.job_id, trace, g.gid, 0.0))
        return g

    # ------------------------------------------------------------- phases
    def _phase_info(self, job: SimJob) -> Tuple[str, float]:
        cycle = job.cycles[job.step_idx]
        name = PHASES[job.phase_idx]
        return name, cycle[name]

    def _start_phase(self, job: SimJob, isolated: bool = False):
        if job.step_idx >= job.steps:
            self._finish_job(job, isolated)
            return
        name, dur = self._phase_info(job)
        if name == "rollout" or isolated:
            # rollout pool is per-job (or the whole reservation if isolated)
            self._push(self.now + dur, self._end_phase, job, name, dur,
                       isolated)
            return
        self._request_shared(job, name, dur)

    def _request_shared(self, job: SimJob, name: str, dur: float):
        g = self.groups[job.group]
        need = job.profile.nodes
        if g.free >= need:
            self._run_shared(g, job, name, dur)
        else:
            g.queue.append((self.now, next(self._eseq), job, name, dur, need))

    def _run_shared(self, g: _Group, job: SimJob, name: str, dur: float):
        need = job.profile.nodes
        g.free -= need
        extra = 0.0
        if g.resident_job not in (None, job.job_id):
            extra = self.switch_cost
            g.switches += 1
            job.switch_overhead += extra
        g.resident_job = job.job_id
        job.busy_shared += dur + extra
        self._busy_shared += (dur + extra) * need
        self._push(self.now + dur + extra, self._end_shared, g, job, name, dur)

    def _end_shared(self, g: _Group, job: SimJob, name: str, dur: float):
        g.free += job.profile.nodes
        self._wake(g)
        self._end_phase(job, name, dur, False)

    def _wake(self, g: _Group):
        if not g.queue:
            return
        if self.policy == "spread_backfill":
            i = 0
            while i < len(g.queue):
                t_q, _, job, name, dur, need = g.queue[i]
                if need <= g.free:
                    g.queue.pop(i)
                    job.wait_time += self.now - t_q
                    self._run_shared(g, job, name, dur)
                else:
                    i += 1
        else:  # FIFO with head-of-line blocking
            while g.queue:
                t_q, _, job, name, dur, need = g.queue[0]
                if need > g.free:
                    break
                g.queue.pop(0)
                job.wait_time += self.now - t_q
                self._run_shared(g, job, name, dur)

    def _end_phase(self, job: SimJob, name: str, dur: float, isolated: bool):
        if name == "rollout":
            job.busy_rollout += dur
        elif isolated:
            job.busy_shared += dur
            self._busy_shared += dur * job.profile.nodes
        job.phase_idx += 1
        if job.phase_idx >= len(PHASES):
            job.phase_idx = 0
            job.step_idx += 1
        self._start_phase(job, isolated)

    def _finish_job(self, job: SimJob, isolated: bool):
        job.t_done = self.now
        if isolated:
            self._iso_free += job.profile.nodes
            self._try_admit_isolated()
        else:
            self.placer.groups[job.group].resident = [
                p for p in self.placer.groups[job.group].resident
                if p.job_id != job.job_id]


def run_policy_comparison(profiles: Sequence[PhaseProfile], steps: int = 20,
                          arrival_rate: float = 1 / 600.0, seed: int = 0,
                          total_nodes: int = 32, group_size: int = 8,
                          policies: Sequence[str] = ("isolated", "pack",
                                                     "spread",
                                                     "spread_backfill"),
                          ) -> Dict[str, SimResult]:
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1 / arrival_rate,
                                         size=len(profiles)))
    out = {}
    for pol in policies:
        jobs = [SimJob(f"job{i}", p, steps, float(arrivals[i]))
                for i, p in enumerate(profiles)]
        sim = ClusterSim(total_nodes=total_nodes, group_size=group_size,
                         policy=pol, seed=seed)
        out[pol] = sim.run(jobs)
    return out
