"""PlexCluster: the runnable binding of Scheduler + Router + StateManagers.

Runs REAL model execution (CPU devices here; mesh slices on a pod):
multiple RLVR jobs share node groups, HRRS orders their function requests,
and context switches move model state through the StateManager tiers.

Two operating modes:

- :meth:`run` — batch: every registered job is driven to completion under
  shared scheduling (the isolated/multiplexed comparisons of
  examples/multiplex_rlvr.py, and the fault-tolerance tests).
- :meth:`serve` — serviceized (the paper's §4.1 regime): the Router's
  persistent dispatch plane runs continuously, :meth:`add_job` attaches a
  job mid-flight (each controller self-drives on its own client thread),
  :meth:`remove_job` detaches one (queued ops cancel, in-flight ops
  resolve), and billing stays incremental throughout.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

from repro.core import api
from repro.core import tenancy
from repro.core.control_plane import DirectorConfig, PlacementDirector
from repro.core.controller import (JobConfig, RLControllerGRPO,
                                   RLControllerPPO, _RLControllerBase)
from repro.core.router import Router
from repro.core.state_manager import Tier

CONTROLLER_TYPES = {"grpo": RLControllerGRPO, "ppo": RLControllerPPO}


@dataclasses.dataclass
class BillingRecord:
    job_id: str
    busy_seconds: float = 0.0         # execution attributed to the job
    switch_seconds: float = 0.0       # setup overhead it caused
    steps: int = 0

    def gpu_seconds_per_step(self) -> float:
        return (self.busy_seconds + self.switch_seconds) / max(self.steps, 1)


class PlexCluster:
    def __init__(self, n_groups: int = 1, policy: str = "hrrs",
                 wpg_factory=None,
                 director_cfg: Optional[DirectorConfig] = None,
                 devices_per_group: Optional[int] = None,
                 process_plane: bool = False,
                 proc_wpg_factory: Optional[str] = None):
        kwargs = {} if wpg_factory is None else {"wpg_factory": wpg_factory}
        self.router = Router(policy=policy,
                             devices_per_group=devices_per_group,
                             process_plane=process_plane,
                             proc_wpg_factory=proc_wpg_factory, **kwargs)
        self.controllers: Dict[str, _RLControllerBase] = {}
        self.billing: Dict[str, BillingRecord] = {}
        # incremental billing cursors: exec-log offset per deployment and
        # consumed prefix of the router's switch log
        self._billed_ops: Dict[str, int] = {}
        self._billed_switches = 0
        self._bill_lock = threading.Lock()
        # serve mode
        self._serving = False
        # serializes client-thread launches against serve() startup so a
        # concurrent add_job can never double-drive one controller
        self._serve_lock = threading.RLock()
        self._job_threads: Dict[str, Tuple[threading.Thread,
                                           threading.Event]] = {}
        self._removed_jobs: set = set()
        self.client_errors: Dict[str, BaseException] = {}
        for g in range(n_groups):
            # ensure_group leases each group its mesh slice from the
            # router's device plane (disjoint hardware per group when the
            # process has enough devices; shared lone slice otherwise)
            self.router.ensure_group(g)
        # multi-tenant service layer: registry (who exists), ledger
        # (per-tenant accounting + SLO windows), admission controller
        # (quotas + pending queues). The default tenant is implicit, so an
        # untenanted cluster behaves exactly as before.
        dcfg = director_cfg or DirectorConfig()
        self.tenants = tenancy.TenantRegistry()
        self.tenant_ledger = tenancy.TenantLedger(
            self.tenants, slo_window=dcfg.slo_window,
            slo_min_samples=dcfg.slo_min_samples)
        self.admission = tenancy.AdmissionController(self.tenants,
                                                     self.tenant_ledger)
        self.router.tenant_ledger = self.tenant_ledger
        # the live control plane: online profiler + automatic placement +
        # capacity adjustment over this router's node groups (tenancy gives
        # it the SLO-preemption trigger's inputs)
        self.director = PlacementDirector(self.router, cfg=director_cfg,
                                          initial_groups=range(n_groups),
                                          tenancy=self.tenant_ledger)

    # ------------------------------------------------------------- jobs
    def register_tenant(self, spec: tenancy.TenantSpec) -> tenancy.TenantSpec:
        """Register (or replace — how an operator tightens a live SLO) a
        tenant's policy. Jobs name their tenant via ``JobConfig.tenant``."""
        return self.tenants.register(spec)

    def add_job(self, cfg: JobConfig, group_id: Optional[int] = 0,
                algo: str = "grpo",
                queue_on_deny: bool = False) -> Optional[_RLControllerBase]:
        """Attach a job. Outside serve mode it is registered for the next
        :meth:`run`; against a live :meth:`serve` plane it starts making
        progress immediately on its own client thread (spawning a dispatch
        worker for ``group_id`` if the group is new).

        ``group_id=None`` routes placement through the control plane: the
        :class:`~repro.core.control_plane.PlacementDirector` cold-places the
        job on a dedicated profiling group (spawning one if needed), then —
        after one clean profiled cycle — re-fits it by micro-shift trace
        fitting and migrates it onto a shared group automatically.

        Every submission passes tenancy admission first: a job whose tenant
        is at quota (groups or gpu-seconds) or for which no feasible
        placement exists is rejected with a typed
        :class:`~repro.core.tenancy.AdmissionDenied` — or, with
        ``queue_on_deny=True``, parked in its tenant's pending queue
        (returns None) and replayed automatically when :meth:`remove_job`
        frees capacity. Unknown tenants are always a hard denial."""
        tenant_id = getattr(cfg, "tenant", tenancy.DEFAULT_TENANT)
        reason = self.admission.check(
            tenant_id, cfg.job_id, self.director.placement_feasible())
        if reason is not None:
            if queue_on_deny and reason != tenancy.REASON_UNKNOWN_TENANT:
                self.admission.enqueue(tenant_id, tenancy.PendingJob(
                    cfg=cfg, group_id=group_id, algo=algo,
                    enqueued_t=self.router.now()))
                return None
            raise tenancy.AdmissionDenied(tenant_id, cfg.job_id, reason)
        self.admission.admit(tenant_id, cfg.job_id)
        return self._launch_admitted(cfg, group_id, algo)

    def _launch_admitted(self, cfg: JobConfig, group_id: Optional[int],
                         algo: str) -> _RLControllerBase:
        """Attach a job whose admission is already decided (quota
        reserved): bind its tenant, stamp its HRRS priority, place, and
        launch. Shared by :meth:`add_job` and the pending-queue drain."""
        tenant_id = getattr(cfg, "tenant", tenancy.DEFAULT_TENANT)
        spec = self.tenants.get(tenant_id) or tenancy.default_spec()
        self.tenant_ledger.bind_job(cfg.job_id, tenant_id)
        self.router.register_job_tenant(cfg.job_id, tenant_id,
                                        priority=spec.priority)
        if group_id is None:
            group_id = self.director.assign(cfg.job_id)
        ctl = CONTROLLER_TYPES[algo](cfg, self.router, group_id=group_id)
        self.controllers[cfg.job_id] = ctl
        # a re-attached job keeps accruing on its existing bill — charges
        # from before a detach are an invoice, not scratch state
        self.billing.setdefault(cfg.job_id, BillingRecord(cfg.job_id))
        self._removed_jobs.discard(cfg.job_id)
        with self._serve_lock:
            # under the lock serve() uses for its own launch sweep: the
            # controller is registered above, so a racing serve() either
            # sweeps it up or we observe _serving here — never neither,
            # and _launch_client's registry check means never both
            if self._serving:
                self._launch_client(ctl)
        return ctl

    def remove_job(self, job_id: str) -> Optional[_RLControllerBase]:
        """Detach a job mid-flight (callable from any thread while serving).

        The client thread stops submitting, the job's deployments tear down
        (queued ops cancel with an error; a RUNNING op completes and
        resolves its future), and everything the job executed — including
        work finished during the detach — is billed."""
        with self._serve_lock:
            entry = self._job_threads.pop(job_id, None)
            self._removed_jobs.add(job_id)
        if entry is not None:
            entry[1].set()
        with self.router.executor.cv:
            dead = {d: self.router.wpgs[d]
                    for d, s in self.router.deployments.items()
                    if s.job_id == job_id}
        for dep_id in dead:
            self.router.teardown(dep_id)
        if entry is not None:
            entry[0].join(timeout=120.0)
        # teardown already drained each dead deployment's in-flight ops
        # before returning (their exec-log entries exist), and this is the
        # LAST billing pass that can see the torn-out WPGs
        with self._bill_lock:
            self._bill_from_logs(extra_wpgs=dead)
            # drop the dead deployments' billing cursors: a later add_job
            # under the same job_id creates FRESH WPGs with empty exec logs
            # under the same deployment ids, and a stale cursor would skip
            # their first N ops
            for dep_id in dead:
                self._billed_ops.pop(dep_id, None)
        # control plane: release the job's placement and retire any group
        # the departure left idle (no-op for jobs it never managed)
        self.director.on_job_removed(job_id)
        # tenancy: drop the quota reservation (after billing, so the final
        # gpu-seconds land on the right tenant), then replay any pending
        # submissions the freed capacity now admits
        self.admission.release(job_id)
        self.tenant_ledger.unbind_job(job_id)
        for pending in self.admission.drain(self.director.placement_feasible):
            self._launch_admitted(pending.cfg, pending.group_id, pending.algo)
        return self.controllers.get(job_id)

    # ------------------------------------------------------------ serve
    @contextlib.contextmanager
    def serve(self):
        """Persistent serve mode: ``with cluster.serve(): ...``.

        Jobs added before or during the block self-drive against the live
        plane; the block body attaches/detaches jobs or does other work. On
        exit, remaining client threads are joined (jobs run to completion),
        the plane shuts down, and any client-thread failure is re-raised.
        """
        if self._serving:
            raise RuntimeError("already serving")
        self.router.serve()
        self.client_errors = {}
        with self._serve_lock:
            self._serving = True
            controllers = list(self.controllers.values())
            for ctl in controllers:
                # relaunch guard: a removed job stays detached and a job
                # that already completed every step is not re-driven by a
                # later serve session (its deployment state persists)
                if (ctl.cfg.job_id in self._removed_jobs
                        or ctl.steps_completed >= ctl.cfg.steps):
                    continue
                self._launch_client(ctl)
        body_failed = False
        try:
            yield self
            # join to quiescence: a job attached from another thread WHILE
            # we were joining must also complete, so loop until no client
            # thread is alive and close the attach window (_serving=False)
            # under the same lock add_job uses before breaking out
            while True:
                for t, _ in list(self._job_threads.values()):
                    t.join()
                with self._serve_lock:
                    if all(not t.is_alive()
                           for t, _ in self._job_threads.values()):
                        self._serving = False
                        break
        except BaseException:
            body_failed = True
            with self._serve_lock:
                self._serving = False     # stop accepting new launches
            # body failed: detach every still-driving job so its client
            # thread unblocks promptly (teardown poisons outstanding ops)
            # instead of being orphaned against a dead plane
            for job_id in list(self._job_threads):
                try:
                    self.remove_job(job_id)
                except Exception:       # noqa: BLE001 - best-effort detach
                    pass
            raise
        finally:
            self._serving = False
            self._job_threads = {}
            try:
                self.router.shutdown()
            except RuntimeError as shut_err:
                # shutdown reports user-callback errors; never let that
                # REPLACE an exception already propagating from the body
                if not body_failed:
                    raise
                self.client_errors.setdefault("<callbacks>", shut_err)
            with self._bill_lock:
                self._bill_from_logs()
        if self.client_errors:
            job, err = next(iter(self.client_errors.items()))
            raise RuntimeError(
                f"job {job!r} client thread failed: {err!r}") from err

    def _launch_client(self, ctl: _RLControllerBase):
        job_id = ctl.cfg.job_id
        with self._serve_lock:
            if job_id in self._job_threads:
                return                # already driven (serve/add_job race)
            stop = threading.Event()
            rec = self.billing[job_id]

            def step_hook():
                with self._bill_lock:
                    rec.steps += 1
                    self._bill_from_logs()
                # control-plane tick OUTSIDE the billing lock: it may block
                # on a migration drain (profiling -> warm re-placement)
                self.director.on_job_step(job_id)

            def client():
                try:
                    ctl.drive(stop=stop, step_hook=step_hook)
                except BaseException as e:  # noqa: BLE001 - surfaced at exit
                    self.client_errors[job_id] = e

            t = threading.Thread(target=client, name=f"client-{job_id}",
                                 daemon=True)
            self._job_threads[job_id] = (t, stop)
        t.start()

    # -------------------------------------------------------------- run
    def run(self, interleave: bool = True,
            concurrent: bool = False) -> Dict[str, BillingRecord]:
        """Run every job to completion under shared scheduling.

        With ``interleave`` the controllers submit steps round-robin so the
        HRRS queue actually multiplexes; without it jobs run back-to-back
        (the 'isolated' baseline on the same hardware). With ``concurrent``
        the router's event-driven dispatch plane executes different node
        groups on parallel worker threads (``run_until_idle``), so jobs
        placed on different groups genuinely overlap in wall-clock time;
        otherwise the serial driver (``drain``) is used.
        """
        def drive():
            if concurrent:
                self.router.run_until_idle()
            else:
                self.router.drain()
            with self._bill_lock:
                self._bill_from_logs()

        # jobs detached by remove_job stay detached (their deployments are
        # gone), and a job a prior serve() session already completed is not
        # re-driven; partially-driven jobs resume from where they stopped
        active = {j: c for j, c in self.controllers.items()
                  if j not in self._removed_jobs
                  and c.steps_completed < c.cfg.steps}
        tails: List[api.Future] = []
        for ctl in active.values():
            if ctl.steps_completed == 0:       # resumed jobs keep weights
                tails.append(ctl.submit_init())
        drive()

        remaining = {j: c.cfg.steps - c.steps_completed
                     for j, c in active.items()}
        order = list(active)
        while any(v > 0 for v in remaining.values()):
            stepped: List[str] = []
            for job_id in order:
                if remaining[job_id] <= 0:
                    continue
                tails += active[job_id].submit_step()
                remaining[job_id] -= 1
                if not interleave:
                    drive()
                    self.director.on_job_step(job_id)
                else:
                    stepped.append(job_id)
            if interleave:
                drive()
                for job_id in stepped:
                    self.director.on_job_step(job_id)
        drive()
        for f in tails:
            f.result()                # surface failed steps loudly
        for job_id, ctl in active.items():
            ctl.steps_completed = ctl.cfg.steps
            self.billing[job_id].steps = ctl.cfg.steps
        return self.billing

    def _bill_from_logs(self, extra_wpgs: Optional[Dict[str, object]] = None):
        """Attribute measured execution time per job from WPG exec logs and
        switch overheads from the router's switch log (unified provisioning:
        §7.2 — users pay for the computation they consume).

        Incremental: only log entries beyond each cursor are consumed, and
        busy time ACCUMULATES across a job's deployments (a job with split
        train/rollout WPGs is billed for both). ``extra_wpgs`` lets a detach
        bill a deployment that was already torn out of the router. Callers
        hold ``_bill_lock`` (client threads bill concurrently)."""
        with self.router.executor.cv:
            items = list(self.router.wpgs.items())
        if extra_wpgs:
            seen = {d for d, _ in items}
            items += [(d, w) for d, w in extra_wpgs.items() if d not in seen]
        for dep_id, wpg in items:
            rec = self.billing.get(wpg.spec.job_id)
            if rec is None:
                continue
            start = self._billed_ops.get(dep_id, 0)
            log = wpg.exec_log
            if hasattr(log, "since"):      # bounded ring: absolute cursors
                new, cursor = log.since(start)
            else:                          # plain list (test/bench stubs)
                new = log[start:]
                cursor = start + len(new)
            self._billed_ops[dep_id] = cursor
            busy = sum(dt for _, dt in new)
            rec.busy_seconds += busy
            # tenant fold of the same cursors: billing and quota read one
            # meter (a preempted job's RUNNING op completes, logs, and is
            # billed here like any other — preemption never strands charges)
            self.tenant_ledger.add_gpu_seconds(
                self.tenant_ledger.tenant_of(wpg.spec.job_id), busy)
        for ev in self.router.switch_log[self._billed_switches:]:
            rec = self.billing.get(ev["to_job"])
            if rec is not None:
                rec.switch_seconds += ev["t_offload"] + ev["t_load"]
                self.tenant_ledger.add_gpu_seconds(
                    self.tenant_ledger.tenant_of(ev["to_job"]),
                    ev["t_offload"] + ev["t_load"])
        self._billed_switches = len(self.router.switch_log)

    # --------------------------------------------------- fault tolerance
    def fail_node(self, group_id: int):
        """Simulate a node failure: device-tier state on the group is lost.
        Jobs must restart from their last checkpoint (or re-init)."""
        sm = self.router.state_managers[group_id]
        lost = [k for k, e in sm.entries.items() if e.tier == Tier.DEVICE]
        for k in lost:
            sm.unregister([k])
        return lost

    def checkpoint_all(self, base_dir: str) -> Dict[str, str]:
        paths = {}
        for dep_id, wpg in self.router.wpgs.items():
            path = f"{base_dir}/{dep_id}"
            paths[dep_id] = wpg._op_save_checkpoint(path)
        return paths

    def restore_all(self, paths: Dict[str, str]):
        for dep_id, path in paths.items():
            self.router.wpgs[dep_id]._op_load_checkpoint(path)

    def migrate_job(self, job_id: str, src_group: int, dst_group: int):
        """Elastic re-placement: move a job's managed state across groups
        (paper §4.5.3 cross-node migration). Lives on the Router now; kept
        here as the historical entry point."""
        return self.router.migrate_job(job_id, src_group, dst_group)

    def reassign_job(self, job_id: str, dst_group: int,
                     timeout: float = 120.0) -> int:
        """Live re-placement: drain the job's in-flight ops, migrate its
        state, re-home its queued ops (billing stays continuous)."""
        return self.router.reassign_job(job_id, dst_group, timeout=timeout)

    # ------------------------------------------------------ reconciliation
    def reconcile(self, force: bool = True):
        """Run the control plane's reconcile pass now (§4.3.2's repacking
        loop): measure realized-vs-planned occupancy, plan an incremental
        repack, and realize its moves as batched live migrations. The
        per-step hooks run the same pass on its periodic cadence; this is
        the explicit entry point for external control loops / operators.
        Returns the list of realized moves."""
        return self.director.reconcile_now(force=force)

    def cluster_plan(self):
        """The declarative desired state (job → (group, shift, trace) plus
        the group set), versioned per placement change."""
        return self.director.cluster_plan()
