"""PlexCluster: the runnable binding of Scheduler + Router + StateManagers.

Runs REAL model execution (CPU devices here; mesh slices on a pod):
multiple RLVR jobs share node groups, HRRS orders their function requests,
and context switches move model state through the StateManager tiers. This
is what examples/multiplex_rlvr.py drives to demonstrate the paper's
two-job packing gain end-to-end, and what the fault-tolerance tests use for
checkpoint/restart and migration.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import api
from repro.core.controller import JobConfig, RLControllerGRPO
from repro.core.router import Router
from repro.core.state_manager import StateManager, Tier


@dataclasses.dataclass
class BillingRecord:
    job_id: str
    busy_seconds: float = 0.0         # execution attributed to the job
    switch_seconds: float = 0.0       # setup overhead it caused
    steps: int = 0

    def gpu_seconds_per_step(self) -> float:
        return (self.busy_seconds + self.switch_seconds) / max(self.steps, 1)


class PlexCluster:
    def __init__(self, n_groups: int = 1, policy: str = "hrrs"):
        self.router = Router(policy=policy)
        self.controllers: Dict[str, RLControllerGRPO] = {}
        self.billing: Dict[str, BillingRecord] = {}
        # incremental billing cursors: exec-log offset per deployment and
        # consumed prefix of the router's switch log
        self._billed_ops: Dict[str, int] = {}
        self._billed_switches = 0
        for g in range(n_groups):
            self.router.state_managers[g] = StateManager(node_id=f"group{g}")

    # ------------------------------------------------------------- jobs
    def add_job(self, cfg: JobConfig, group_id: int = 0) -> RLControllerGRPO:
        ctl = RLControllerGRPO(cfg, self.router, group_id=group_id)
        self.controllers[cfg.job_id] = ctl
        self.billing[cfg.job_id] = BillingRecord(cfg.job_id)
        return ctl

    # -------------------------------------------------------------- run
    def run(self, interleave: bool = True,
            concurrent: bool = False) -> Dict[str, BillingRecord]:
        """Run every job to completion under shared scheduling.

        With ``interleave`` the controllers submit steps round-robin so the
        HRRS queue actually multiplexes; without it jobs run back-to-back
        (the 'isolated' baseline on the same hardware). With ``concurrent``
        the router's event-driven dispatch plane executes different node
        groups on parallel worker threads (``run_until_idle``), so jobs
        placed on different groups genuinely overlap in wall-clock time;
        otherwise the serial driver (``drain``) is used.
        """
        def drive():
            if concurrent:
                self.router.run_until_idle()
            else:
                self.router.drain()
            self._bill_from_logs()

        for ctl in self.controllers.values():
            ctl.submit_init()
        drive()

        remaining = {j: c.cfg.steps for j, c in self.controllers.items()}
        order = list(self.controllers)
        while any(v > 0 for v in remaining.values()):
            for job_id in order:
                if remaining[job_id] <= 0:
                    continue
                self.controllers[job_id].submit_step()
                remaining[job_id] -= 1
                if not interleave:
                    drive()
            if interleave:
                drive()
        drive()
        for job_id, ctl in self.controllers.items():
            self.billing[job_id].steps = ctl.cfg.steps
        return self.billing

    def _bill_from_logs(self):
        """Attribute measured execution time per job from WPG exec logs and
        switch overheads from the router's switch log (unified provisioning:
        §7.2 — users pay for the computation they consume).

        Incremental: only log entries beyond each cursor are consumed, and
        busy time ACCUMULATES across a job's deployments (a job with split
        train/rollout WPGs is billed for both, where the previous version
        kept only whichever deployment iterated last)."""
        for dep_id, wpg in self.router.wpgs.items():
            rec = self.billing.get(wpg.spec.job_id)
            if rec is None:
                continue
            start = self._billed_ops.get(dep_id, 0)
            new = wpg.exec_log[start:]
            self._billed_ops[dep_id] = start + len(new)
            rec.busy_seconds += sum(dt for _, dt in new)
        for ev in self.router.switch_log[self._billed_switches:]:
            rec = self.billing.get(ev["to_job"])
            if rec is not None:
                rec.switch_seconds += ev["t_offload"] + ev["t_load"]
        self._billed_switches = len(self.router.switch_log)

    # --------------------------------------------------- fault tolerance
    def fail_node(self, group_id: int):
        """Simulate a node failure: device-tier state on the group is lost.
        Jobs must restart from their last checkpoint (or re-init)."""
        sm = self.router.state_managers[group_id]
        lost = [k for k, e in sm.entries.items() if e.tier == Tier.DEVICE]
        for k in lost:
            sm.unregister([k])
        return lost

    def checkpoint_all(self, base_dir: str) -> Dict[str, str]:
        paths = {}
        for dep_id, wpg in self.router.wpgs.items():
            path = f"{base_dir}/{dep_id}"
            paths[dep_id] = wpg._op_save_checkpoint(path)
        return paths

    def restore_all(self, paths: Dict[str, str]):
        for dep_id, path in paths.items():
            self.router.wpgs[dep_id]._op_load_checkpoint(path)

    def migrate_job(self, job_id: str, src_group: int, dst_group: int):
        """Elastic re-placement: move a job's managed state across groups
        (paper §4.5.3 cross-node migration)."""
        src = self.router.state_managers[src_group]
        dst = self.router.state_managers.setdefault(
            dst_group, StateManager(node_id=f"group{dst_group}"))
        moved = 0
        for dep_id, wpg in self.router.wpgs.items():
            if wpg.spec.job_id != job_id:
                continue
            moved += src.migrate(wpg.job_prefix, dst)
            wpg.sm = dst
            self.router.group_of[dep_id] = dst_group
        return moved
