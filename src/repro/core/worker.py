"""Worker-process group (WPG): one logical deployment's execution backend.

A WPG owns the jitted step functions for its model and executes admitted
operations SERIALLY (the per-WPG ordering guarantee of §4.2/§5.1); different
WPGs may run concurrently when the Scheduler admits them. Parameters and
optimizer state live under the node's StateManager as canonical entries, so
context switching (offload/load) and weight sync never touch worker code.

A WPG binds its node group's mesh slice (launch/mesh.py, read off the
group's StateManager): parameters and optimizer state are laid out with the
model's sharding rules against THAT mesh, so the jitted primitives are
per-group — two groups holding disjoint slices execute on disjoint
hardware, and migrating a WPG across groups reshards its state onto the
destination slice.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import api
from repro.core.state_manager import StateManager, Tier
from repro.models import sharding as shd
from repro.models.registry import Model, build_model
from repro.rl import grpo, ppo as ppo_lib, rollout as rollout_lib
from repro.train import optimizer as opt, train_state as tstate
from repro.train.train_state import TrainState


class ExecLog:
    """Bounded execution log with ABSOLUTE offsets.

    Billing consumes the log through incremental cursors; an unbounded list
    leaks one tuple per op on a week-long serve plane (same failure shape
    as the executor's settled-task table before ``max_settled_tasks``).
    The ring drops the oldest entries past ``maxlen`` while ``offset``
    tracks the absolute index of the first retained entry, so cursors keep
    meaning "ops billed so far" across trims. ``len``/iteration/indexing
    cover the RETAINED window (what observability consumers want);
    :meth:`since` is the billing protocol."""

    def __init__(self, maxlen: int = 4096):
        self.maxlen = maxlen
        self.offset = 0                      # absolute index of _items[0]
        self._items: List[Tuple[str, float]] = []

    def append(self, item):
        self._items.append(item)
        if len(self._items) > self.maxlen:
            drop = len(self._items) - self.maxlen
            del self._items[:drop]
            self.offset += drop

    def since(self, cursor: int) -> Tuple[List[Tuple[str, float]], int]:
        """Entries at absolute index >= ``cursor`` (clamped to the retained
        window) and the new cursor. Entries already trimmed are gone — the
        ring must be sized above the billing cadence."""
        start = max(int(cursor), self.offset)
        return self._items[start - self.offset:], self.offset + len(self._items)

    def total(self) -> int:
        return self.offset + len(self._items)

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        return iter(self._items)

    def __getitem__(self, i):
        return self._items[i]


def _value_readout(logits):
    """Critic value estimate per token without a dedicated value head: the
    free-energy (logsumexp) of the logits, squashed to (-1, 1). It is
    differentiable w.r.t. the whole backbone, so the clipped value loss
    trains a role="critic" deployment through the same FORWARD_BACKWARD /
    OPTIM_STEP primitives as the actor."""
    v = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    return jnp.tanh(v / jnp.sqrt(logits.shape[-1] * 1.0))


class WorkerProcessGroup:
    def __init__(self, spec: api.DeploymentSpec, state_manager: StateManager,
                 rng_seed: int = 0, grpo_cfg: Optional[grpo.GRPOConfig] = None,
                 adamw_cfg: Optional[opt.AdamWConfig] = None):
        self.spec = spec
        self.sm = state_manager
        cfg = get_config(spec.model_name)
        if spec.overrides:
            cfg = cfg.replace(**dict(spec.overrides))
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.grpo_cfg = grpo_cfg or grpo.GRPOConfig()
        self.ppo_cfg = ppo_lib.PPOConfig()
        self.adamw_cfg = adamw_cfg or opt.AdamWConfig()
        self._rng = jax.random.PRNGKey(rng_seed)
        self._initialized = False
        self._keys: Dict[str, list] = {}
        self.exec_log = ExecLog()
        # per-WPG state shardings, cached per mesh slice (rebuilt after a
        # cross-slice migration swaps self.sm)
        self._shard_cache: Optional[tuple] = None
        # jitted primitives (built lazily)
        self._update_actor = None
        self._logprob = None
        self._values = None
        self._ppo_grads = None
        self._value_grads = None

    # -------------------------------------------------------------- state
    @property
    def job_prefix(self) -> str:
        return f"{self.spec.job_id}:{self.spec.deployment_id}"

    # ---------------------------------------------------------- mesh slice
    @property
    def mesh_slice(self):
        """The node group's MeshSlice, read off the group's StateManager so
        a migration that swaps ``self.sm`` rebinds the WPG to the new
        group's hardware automatically."""
        return getattr(self.sm, "mesh_slice", None)

    def state_shardings(self) -> Optional[TrainState]:
        """NamedShardings for (params, opt_state, step) on THIS group's
        mesh slice — per-WPG, not global. None without a slice (legacy
        single-view execution). Cached per slice; jit re-specializes on
        sharding change, so no explicit invalidation is needed."""
        sl = self.mesh_slice
        if sl is None:
            return None
        if self._shard_cache is None or self._shard_cache[0] is not sl.mesh:
            self._shard_cache = (sl.mesh, tstate.shardings(
                self.model, sl.mesh, shd.named_rules("tp")))
        return self._shard_cache[1]

    def param_shardings(self):
        st = self.state_shardings()
        return None if st is None else st.params

    def _params_template(self):
        return self.model.abstract_params()

    def params(self):
        return self.sm.gather(self.job_prefix, self._params_template(),
                              "params")

    def host_params(self):
        """Params gathered to host numpy — the process plane's cross-process
        weight-sync export (the tree must pickle across the group pipe, so
        no jax.Array leaves may remain)."""
        import numpy as np
        return jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                            self.params())

    def opt_state(self) -> opt.AdamWState:
        tmpl = opt.abstract_state(self._params_template(), self.adamw_cfg)
        return self.sm.gather(self.job_prefix, tmpl, "opt")

    def _store(self, params=None, opt_state=None):
        if params is not None:
            for k in self.sm.keys_for(self.job_prefix, "params"):
                self.sm.unregister([k])
            self._keys["params"] = self.sm.register(
                self.job_prefix, params, Tier.DEVICE, "params")
        if opt_state is not None:
            for k in self.sm.keys_for(self.job_prefix, "opt"):
                self.sm.unregister([k])
            self._keys["opt"] = self.sm.register(
                self.job_prefix, opt_state, Tier.DEVICE, "opt")

    def resident(self) -> bool:
        keys = self.sm.keys_for(self.job_prefix)
        return bool(keys) and all(
            self.sm.entries[k].tier == Tier.DEVICE for k in keys)

    def ensure_resident(self) -> float:
        """Load this WPG's state to device (the 'load' half of a context
        switch). Returns elapsed seconds."""
        keys = self.sm.keys_for(self.job_prefix)
        return self.sm.prefetch(keys)

    def offload(self, to: Tier = Tier.HOST) -> float:
        return self.sm.offload(self.sm.keys_for(self.job_prefix), to)

    # --------------------------------------------------------------- ops
    def execute(self, qop: api.QueuedOperation):
        """Serial execution of one admitted operation."""
        t0 = time.monotonic()
        handler = {
            api.Op.INIT: self._op_init,
            api.Op.GENERATE: self._op_generate,
            api.Op.FORWARD: self._op_forward,
            api.Op.FORWARD_BACKWARD: self._op_forward_backward,
            api.Op.OPTIM_STEP: self._op_optim_step,
            api.Op.UPDATE_ACTOR: self._op_update_actor,
            api.Op.SYNC_WEIGHTS: self._op_sync_weights,
            api.Op.SAVE_CHECKPOINT: self._op_save_checkpoint,
            api.Op.LOAD_CHECKPOINT: self._op_load_checkpoint,
        }[qop.op]
        result = handler(*qop.args, **qop.kwargs)
        self.exec_log.append((qop.op.value, time.monotonic() - t0))
        return result

    # ------------------------------------------------------ op handlers
    def _op_init(self, seed: int = 0):
        params = self.model.init_params(jax.random.PRNGKey(seed))
        st = self.state_shardings()
        if st is not None:
            # lay the state out on this group's mesh slice (per-WPG
            # shardings); the StateManager records each leaf's spec so
            # later prefetch/migrate rebuilds the layout
            params = jax.device_put(params, st.params)
        if self.spec.role in ("train", "critic"):
            # critic deployments run their own optim_step (value updates)
            opt_state = opt.init(params, self.adamw_cfg)
            if st is not None:
                opt_state = jax.device_put(opt_state, st.opt_state)
            self._store(params=params, opt_state=opt_state)
        else:
            self._store(params=params)
        self._initialized = True
        return {"params": self.model.param_count()}

    def _op_generate(self, prompt_tokens, max_new_tokens: int = 32,
                     temperature: float = 1.0, extra_inputs=None):
        params = self.params()
        self._rng, k = jax.random.split(self._rng)
        toks, logps, alive = rollout_lib.rollout(
            self.model, params, jnp.asarray(prompt_tokens), k,
            rollout_lib.RolloutConfig(max_new_tokens=max_new_tokens,
                                      temperature=temperature),
            extra_inputs=extra_inputs)
        return {"tokens": toks, "logprobs": logps, "alive": alive}

    def _op_forward(self, batch, output: str = "logprobs"):
        """Forward-only primitive. ``output`` selects the readout:
        "logprobs" (compute_log_prob, default) or "values" (critic value
        estimates per token)."""
        if output == "values":
            if self._values is None:
                def _vals(p, b):
                    logits, _ = self.model.forward(p, b, None)[:2]
                    return _value_readout(logits)
                self._values = jax.jit(_vals)
            return self._values(self.params(), batch)
        if output != "logprobs":
            raise ValueError(f"unknown forward output {output!r}")
        if self._logprob is None:
            self._logprob = jax.jit(grpo.make_compute_log_prob(self.model))
        return self._logprob(self.params(), batch)

    def _op_forward_backward(self, batch, objective: str = "grpo"):
        """Split-phase gradient computation. ``objective`` selects the loss
        family: "grpo" (default), "ppo" (rl/ppo.py's clipped surrogate), or
        "value" (the clipped critic loss for role="critic" deployments), so
        multi-algorithm / multi-role jobs share one WPG primitive."""
        params = self.params()
        if objective == "value":
            if self._value_grads is None:
                def _vgrads(p, b):
                    def _loss(pp):
                        logits, aux = self.model.forward(pp, b, None)[:2]
                        values = _value_readout(logits)
                        vl = ppo_lib.value_loss(
                            values, b["value_targets"], b["old_values"],
                            b["loss_mask"], self.ppo_cfg)
                        return vl + 0.01 * aux, vl
                    return jax.value_and_grad(_loss, has_aux=True)(p)
                self._value_grads = jax.jit(_vgrads)
            (loss, vl), grads = self._value_grads(params, batch)
            return {"grads": grads,
                    "metrics": {"value_loss": vl, "loss": loss}}
        if objective == "ppo":
            if self._ppo_grads is None:
                def _grads(p, b):
                    return jax.value_and_grad(ppo_lib.ppo_loss, has_aux=True)(
                        p, self.model, b, self.ppo_cfg, None)
                self._ppo_grads = jax.jit(_grads)
            (loss, metrics), grads = self._ppo_grads(params, batch)
            return {"grads": grads, "metrics": dict(metrics, loss=loss)}
        if objective != "grpo":
            raise ValueError(f"unknown objective {objective!r}")
        grads, metrics = grpo.compute_grads(params, self.model, batch,
                                            self.grpo_cfg, None)
        return {"grads": grads, "metrics": metrics}

    def _op_optim_step(self, grads, host: bool = False):
        if host:
            # §4.5.4: CPU optimizer over host-resident canonical state
            step = self.sm.host_optimizer_step(
                self.job_prefix, grads, self._params_template(),
                lr=self.adamw_cfg.lr, b1=self.adamw_cfg.b1,
                b2=self.adamw_cfg.b2, eps=self.adamw_cfg.eps)
            return {"step": step, "host": True}
        params = self.params()
        state = self.opt_state()
        new_params, new_state, metrics = opt.update(grads, state, params,
                                                    self.adamw_cfg)
        self._store(params=new_params, opt_state=new_state)
        return {"step": int(new_state.step), **{k: float(v) for k, v in
                                                metrics.items()}}

    def _op_update_actor(self, batch):
        if self._update_actor is None:
            self._update_actor = jax.jit(grpo.make_update_actor(
                self.model, self.grpo_cfg, self.adamw_cfg))
        params = self.params()
        state = TrainState(params, self.opt_state(),
                           jnp.asarray(0, jnp.int32))
        new_state, metrics = self._update_actor(state, batch)
        self._store(params=new_state.params, opt_state=new_state.opt_state)
        return {k: float(v) for k, v in metrics.items()}

    def _op_sync_weights(self, target_wpg: "WorkerProcessGroup",
                         target_shardings=None):
        """Materialise training-visible weights into the rollout deployment's
        layout (zero-redundancy resharding via StateManager). By default the
        target layout is the TARGET WPG's own per-group shardings — a
        rollout deployment on a different mesh slice receives the weights
        resharded onto ITS slice, not this group's."""
        if target_shardings is None and hasattr(target_wpg, "param_shardings"):
            target_shardings = target_wpg.param_shardings()
        tree = self.sm.sync_weights(self.job_prefix, self._params_template(),
                                    target_shardings)
        target_wpg._store(params=tree)
        return {"synced_bytes": self.sm.job_bytes(self.job_prefix)}

    def _op_save_checkpoint(self, path: str, step: int = 0):
        return self.sm.materialize_checkpoint(
            self.job_prefix, self._params_template(), path, step)

    def _op_load_checkpoint(self, path: str):
        from repro.train import checkpoint as ckpt
        tree, meta = ckpt.restore(path, self._params_template())
        self._store(params=tree)
        return meta
