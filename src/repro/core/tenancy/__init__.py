"""core.tenancy — multi-tenant service layer: tenant registry, quotas,
priority-weighted admission, SLO enforcement inputs, per-tenant accounting.

Mechanism lives in the scheduler / placement / control planes; this package
is the *policy* layer threaded through them (MARLaaS's framing: RL as a
multi-tenant service where the missing piece is policy, not mechanism).
"""
from repro.core.tenancy.accounting import TenantLedger, p95
from repro.core.tenancy.admission import (REASON_GPU_QUOTA,
                                          REASON_GROUP_QUOTA,
                                          REASON_NO_PLACEMENT,
                                          REASON_UNKNOWN_TENANT,
                                          AdmissionController,
                                          AdmissionDenied, PendingJob)
from repro.core.tenancy.model import (DEFAULT_TENANT, TenantClass,
                                      TenantRegistry, TenantSpec,
                                      default_spec)

__all__ = [
    "DEFAULT_TENANT",
    "TenantClass",
    "TenantRegistry",
    "TenantSpec",
    "default_spec",
    "TenantLedger",
    "p95",
    "AdmissionController",
    "AdmissionDenied",
    "PendingJob",
    "REASON_GROUP_QUOTA",
    "REASON_GPU_QUOTA",
    "REASON_NO_PLACEMENT",
    "REASON_UNKNOWN_TENANT",
]
