"""Quota-aware admission control for the multi-tenant service layer.

``Cluster.add_job`` routes every submission through here before anything is
placed or spawned. Denials are *typed outcomes*, not stack traces: an
``AdmissionDenied`` carries the tenant, job, and machine-readable reason so
a service frontend can surface "your org is at quota" versus "the cluster
is full" distinctly. A denied job can instead be parked in its tenant's
pending queue (``queue_on_deny``); quota release on ``remove_job`` drains
the queues in priority order so freed capacity flows to the most-entitled
waiting tenant first.

Feasibility is checked against the placement plane's *duty slack* (can any
existing group absorb another duty share, or may a new group still be
spawned under ``max_groups``) rather than by optimistically spawning — the
unbounded-spawn hole this subsystem exists to close.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Set

from repro.core.tenancy.accounting import TenantLedger
from repro.core.tenancy.model import TenantRegistry

# Machine-readable denial reasons (the full closed set).
REASON_UNKNOWN_TENANT = "unknown-tenant"
REASON_GROUP_QUOTA = "group-quota"
REASON_GPU_QUOTA = "gpu-quota"
REASON_NO_PLACEMENT = "no-feasible-placement"


class AdmissionDenied(Exception):
    """Typed admission denial: tenant + job + one of the REASON_* codes."""

    def __init__(self, tenant_id: str, job_id: str, reason: str):
        self.tenant_id = tenant_id
        self.job_id = job_id
        self.reason = reason
        super().__init__(
            f"admission denied for job {job_id!r} "
            f"(tenant {tenant_id!r}): {reason}")


@dataclasses.dataclass
class PendingJob:
    """A submission parked at quota, replayed verbatim on drain."""
    cfg: object                  # controller.JobConfig
    group_id: Optional[int]
    algo: str
    enqueued_t: float


class AdmissionController:
    """Per-tenant quota bookkeeping + pending queues.

    Tracks which jobs are *active* per tenant (admitted, not yet removed);
    each active job counts one group reservation against
    ``quota_groups``. ``quota_gpu_s`` is an admission-time gate on the
    tenant's lifetime billed gpu-seconds (ledger cursor) — already-running
    jobs are never killed for it, matching billing semantics elsewhere.
    """

    def __init__(self, registry: TenantRegistry, ledger: TenantLedger):
        self.registry = registry
        self.ledger = ledger
        self._lock = threading.Lock()
        self._active: Dict[str, Set[str]] = {}
        self._pending: Dict[str, Deque[PendingJob]] = {}

    # ------------------------------------------------------------- queries
    def active_count(self, tenant_id: str) -> int:
        with self._lock:
            return len(self._active.get(tenant_id, ()))

    def pending_depth(self, tenant_id: str) -> int:
        with self._lock:
            return len(self._pending.get(tenant_id, ()))

    def check(self, tenant_id: str, job_id: str,
              feasible: bool) -> Optional[str]:
        """Denial reason for admitting ``job_id`` now, or None if clear.
        ``feasible`` is the placement plane's duty-slack verdict."""
        spec = self.registry.get(tenant_id)
        if spec is None:
            return REASON_UNKNOWN_TENANT
        with self._lock:
            active = len(self._active.get(tenant_id, ()))
        if spec.quota_groups is not None and active >= spec.quota_groups:
            return REASON_GROUP_QUOTA
        if (spec.quota_gpu_s is not None
                and self.ledger.gpu_seconds(tenant_id) >= spec.quota_gpu_s):
            return REASON_GPU_QUOTA
        if not feasible:
            return REASON_NO_PLACEMENT
        return None

    # ----------------------------------------------------------- mutation
    def admit(self, tenant_id: str, job_id: str):
        with self._lock:
            self._active.setdefault(tenant_id, set()).add(job_id)

    def release(self, job_id: str) -> Optional[str]:
        """Drop the job's quota reservation; returns its tenant (or None
        if the job was never admitted through this controller)."""
        with self._lock:
            for tenant_id, jobs in self._active.items():
                if job_id in jobs:
                    jobs.discard(job_id)
                    return tenant_id
        return None

    def enqueue(self, tenant_id: str, pending: PendingJob):
        with self._lock:
            self._pending.setdefault(tenant_id, deque()).append(pending)
        self.ledger.set_pending(tenant_id, self.pending_depth(tenant_id))

    def drain(self, feasible: Callable[[], bool]) -> List[PendingJob]:
        """Pop every pending job that can be admitted *now*.

        Tenants are visited in priority-desc (then tenant_id) order so
        freed capacity flows to the most-entitled queue first; within a
        tenant the queue is FIFO and draining stops at the first job that
        still fails its check (quota or feasibility) — admission order
        within a tenant is preserved, no queue-jumping.
        The caller launches the returned jobs and must ``admit`` each
        (this method reserves quota itself to keep check+admit atomic).
        """
        ready: List[PendingJob] = []
        with self._lock:
            tenants = sorted(
                (t for t, q in self._pending.items() if q),
                key=lambda t: (-(self.registry.get(t).priority
                                 if self.registry.get(t) else 0.0), t))
        for tenant_id in tenants:
            while True:
                with self._lock:
                    q = self._pending.get(tenant_id)
                    if not q:
                        break
                    head = q[0]
                reason = self.check(tenant_id, head.cfg.job_id, feasible())
                if reason is not None:
                    break
                with self._lock:
                    q.popleft()
                    self._active.setdefault(tenant_id, set()).add(
                        head.cfg.job_id)
                ready.append(head)
            self.ledger.set_pending(tenant_id,
                                    self.pending_depth(tenant_id))
        return ready
