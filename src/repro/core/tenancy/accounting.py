"""Per-tenant accounting: gpu-seconds, step latency, SLO attainment.

The billing plane already meters per-group busy/switch seconds from the
executor's logs; this module folds those cursors up to the *tenant* — the
unit that is actually quota'd and billed in a multi-tenant service. It also
owns the rolling step-latency window the director's SLO trigger reads:
step walls are folded from the existing ``PhaseRecord`` stream (one wall =
one closed train cycle), appended here per tenant, and summarised as a
rolling p95.

Thread-safety: the executor's completion path, the cluster's billing sweep,
and the director's fold all touch the ledger from different threads, so
every mutator takes the internal lock. All methods are O(window) or better —
this sits on the dispatch hot path's shoulder, not in it.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Deque, Dict, Optional

from repro.core.tenancy.model import (DEFAULT_TENANT, TenantClass,
                                      TenantRegistry, TenantSpec)


def p95(samples) -> Optional[float]:
    """Nearest-rank p95 (deterministic, no interpolation)."""
    xs = sorted(samples)
    if not xs:
        return None
    rank = max(0, math.ceil(0.95 * len(xs)) - 1)
    return xs[rank]


class TenantLedger:
    """Mutable per-tenant runtime state: job bindings, billed gpu-seconds,
    step-latency windows, SLO attainment counters, pending-queue depth.

    The registry is consulted live (not snapshotted) so a re-registered
    spec — e.g. an operator tightening an SLO mid-serve — takes effect on
    the next read.
    """

    def __init__(self, registry: TenantRegistry, slo_window: int = 16,
                 slo_min_samples: int = 4):
        self.registry = registry
        self.slo_window = max(1, slo_window)
        self.slo_min_samples = max(1, slo_min_samples)
        self._lock = threading.Lock()
        self._job_tenant: Dict[str, str] = {}
        self._gpu_seconds: Dict[str, float] = {}
        self._steps: Dict[str, Deque[float]] = {}
        self._steps_total: Dict[str, int] = {}
        self._steps_ok: Dict[str, int] = {}
        self._pending: Dict[str, int] = {}

    # ------------------------------------------------------------ bindings
    def bind_job(self, job_id: str, tenant_id: str):
        with self._lock:
            self._job_tenant[job_id] = tenant_id

    def unbind_job(self, job_id: str):
        with self._lock:
            self._job_tenant.pop(job_id, None)

    def tenant_of(self, job_id: str) -> str:
        with self._lock:
            return self._job_tenant.get(job_id, DEFAULT_TENANT)

    def spec_of_job(self, job_id: str) -> TenantSpec:
        spec = self.registry.get(self.tenant_of(job_id))
        if spec is None:                       # tenant deregistered mid-run
            spec = self.registry.get(DEFAULT_TENANT)
        return spec

    def is_best_effort(self, job_id: str) -> bool:
        return self.spec_of_job(job_id).class_ == TenantClass.BEST_EFFORT

    # ------------------------------------------------------------- billing
    def add_gpu_seconds(self, tenant_id: str, seconds: float):
        if seconds <= 0.0:
            return
        with self._lock:
            self._gpu_seconds[tenant_id] = (
                self._gpu_seconds.get(tenant_id, 0.0) + seconds)

    def gpu_seconds(self, tenant_id: str) -> float:
        with self._lock:
            return self._gpu_seconds.get(tenant_id, 0.0)

    # --------------------------------------------------------- step window
    def record_step(self, job_id: str, wall_s: float):
        """Fold one closed train-cycle wall into the job's tenant window
        and update SLO attainment against the tenant's current spec."""
        tenant_id = self.tenant_of(job_id)
        spec = self.registry.get(tenant_id)
        with self._lock:
            win = self._steps.get(tenant_id)
            if win is None:
                win = self._steps[tenant_id] = deque(maxlen=self.slo_window)
            win.append(wall_s)
            self._steps_total[tenant_id] = \
                self._steps_total.get(tenant_id, 0) + 1
            slo = spec.slo_step_latency_s if spec is not None else None
            if slo is None or wall_s <= slo:
                self._steps_ok[tenant_id] = \
                    self._steps_ok.get(tenant_id, 0) + 1

    def step_p95(self, tenant_id: str) -> Optional[float]:
        """Rolling p95 step latency; None until ``slo_min_samples`` walls
        have been folded (no trigger-happy preemption off one sample)."""
        with self._lock:
            win = self._steps.get(tenant_id)
            if win is None or len(win) < self.slo_min_samples:
                return None
            return p95(win)

    def slo_breach(self, job_id: str) -> bool:
        """True when the job's tenant is GUARANTEED, has an SLO, and its
        rolling p95 step latency exceeds it."""
        spec = self.spec_of_job(job_id)
        if (spec.class_ != TenantClass.GUARANTEED
                or spec.slo_step_latency_s is None):
            return False
        p = self.step_p95(spec.tenant_id)
        return p is not None and p > spec.slo_step_latency_s

    # ------------------------------------------------------------- pending
    def set_pending(self, tenant_id: str, depth: int):
        with self._lock:
            if depth <= 0:
                self._pending.pop(tenant_id, None)
            else:
                self._pending[tenant_id] = depth

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Per-tenant accounting view merged into Router.tenant_telemetry."""
        with self._lock:
            tenants = (set(self._gpu_seconds) | set(self._steps)
                       | set(self._steps_total) | set(self._pending)
                       | set(self._job_tenant.values()))
            out: Dict[str, Dict[str, object]] = {}
            for t in tenants:
                total = self._steps_total.get(t, 0)
                ok = self._steps_ok.get(t, 0)
                win = self._steps.get(t)
                out[t] = {
                    "gpu_seconds": self._gpu_seconds.get(t, 0.0),
                    "steps_total": total,
                    "slo_attainment": (ok / total) if total else None,
                    "step_p95_s": (p95(win) if win and
                                   len(win) >= self.slo_min_samples
                                   else None),
                    "pending_jobs": self._pending.get(t, 0),
                }
            return out
