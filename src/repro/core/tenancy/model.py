"""Tenant model for the multi-tenant service layer (paper §2; MARLaaS).

PlexRL multiplexes one serviceized LLM plane across jobs from *different
users* — the whole premise is that idle gaps are anti-correlated across
tenants. This module is the policy vocabulary that makes that sharing safe:
who a tenant is (``TenantSpec``), what they are entitled to (quotas), how
urgently their work ages in HRRS admission (``priority``), and what the
plane owes them (``slo_step_latency_s``, enforced for GUARANTEED tenants by
the director's SLO preemption trigger).

Every pre-tenancy call site maps onto the implicit ``DEFAULT_TENANT``:
priority 1.0, BEST_EFFORT, unlimited quotas, no SLO — so the default tenant
is bit-identical to the untenanted plane (1.0 is the multiplicative
identity on the HRRS score line, and unlimited quotas never queue or deny).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional


DEFAULT_TENANT = "default"


class TenantClass(str, enum.Enum):
    """Service class (RL-in-the-Wild's production/experiment split).

    GUARANTEED tenants carry an SLO the director actively defends by
    preempting BEST_EFFORT work; BEST_EFFORT tenants absorb the slack and
    may be held/shed whenever a GUARANTEED SLO is breached.
    """

    GUARANTEED = "guaranteed"
    BEST_EFFORT = "best_effort"


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Declarative per-tenant policy.

    quota_groups     max concurrently admitted jobs (each job reserves one
                     node-group placement); None = unlimited.
    quota_gpu_s      lifetime budget of billed gpu-seconds (busy + switch);
                     admission-time check, None = unlimited.
    slo_step_latency_s
                     rolling-p95 step-latency objective; only enforced for
                     GUARANTEED tenants (the director's fourth reconcile
                     trigger). None = no SLO.
    """

    tenant_id: str
    priority: float = 1.0
    class_: TenantClass = TenantClass.BEST_EFFORT
    quota_groups: Optional[int] = None
    quota_gpu_s: Optional[float] = None
    slo_step_latency_s: Optional[float] = None

    def __post_init__(self):
        if not self.tenant_id:
            raise ValueError("tenant_id must be non-empty")
        if not (self.priority > 0.0):
            raise ValueError(
                f"priority must be > 0 (got {self.priority}); the HRRS "
                "score line needs a positive slope for starvation-freedom")
        if self.quota_groups is not None and self.quota_groups < 0:
            raise ValueError("quota_groups must be >= 0")
        if self.quota_gpu_s is not None and self.quota_gpu_s < 0:
            raise ValueError("quota_gpu_s must be >= 0")


def default_spec() -> TenantSpec:
    return TenantSpec(tenant_id=DEFAULT_TENANT, priority=1.0,
                      class_=TenantClass.BEST_EFFORT)


class TenantRegistry:
    """Registry of known tenants. Auto-creates only the default tenant;
    any other tenant must be registered before its jobs are admitted
    (unknown tenants are an admission *denial*, not a KeyError — the
    service layer's contract is typed outcomes).

    Re-registering an existing tenant replaces its spec — this is how an
    operator tightens a live tenant's SLO or priority (the director picks
    up the new spec on its next fold).
    """

    def __init__(self):
        self._specs: Dict[str, TenantSpec] = {
            DEFAULT_TENANT: default_spec()}

    def register(self, spec: TenantSpec) -> TenantSpec:
        self._specs[spec.tenant_id] = spec
        return spec

    def get(self, tenant_id: str) -> Optional[TenantSpec]:
        return self._specs.get(tenant_id)

    def known(self, tenant_id: str) -> bool:
        return tenant_id in self._specs

    def all(self) -> Dict[str, TenantSpec]:
        return dict(self._specs)
