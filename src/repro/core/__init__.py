"""PlexRL core: cluster-level multiplexing of serviceized LLM execution.

The paper's contribution (§4-5): a Scheduler (spatio-temporal placement +
HRRS runtime ordering), a remote execution service (Router + worker-process
groups), and a per-node StateManager (3-tier residency, canonical offloaded
state, materialisation / weight-sync / migration).
"""
