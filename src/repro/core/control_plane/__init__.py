"""Control plane package: declarative plan, reconciliation loop, director.

Split across three modules (one per layer of the loop):

- :mod:`~repro.core.control_plane.plan` — the declarative state: profiled
  trace folding, :class:`DirectorConfig`, and the versioned
  :class:`ClusterPlan` (job → (group, shift, trace) + group set).
- :mod:`~repro.core.control_plane.reconcile` — drift detection: periodic
  realized-vs-planned occupancy overlap, per-job phase drift, and
  queue-pressure shed selection.
- :mod:`~repro.core.control_plane.director` — the
  :class:`PlacementDirector` that decides (cold place / warm fit /
  repack), applies to the placement state, and realizes batched migrations
  through ``Router.reassign_jobs``.

This package keeps the old ``repro.core.control_plane`` import surface.
"""
from repro.core.control_plane.director import (PlacementDirector, _JobState)
from repro.core.control_plane.plan import (PHASE_OF_OP, TRAIN_PHASES,
                                           ClusterPlan, DirectorConfig,
                                           JobAssignment, plan_from_policy,
                                           trace_from_cycles)
from repro.core.control_plane.reconcile import Reconciler
from repro.core.scheduler.placement import JobMove, RepackPlan

__all__ = [
    "PHASE_OF_OP", "TRAIN_PHASES", "ClusterPlan", "DirectorConfig",
    "JobAssignment", "JobMove", "PlacementDirector", "Reconciler",
    "RepackPlan", "plan_from_policy", "trace_from_cycles", "_JobState",
]
