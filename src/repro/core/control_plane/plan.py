"""The declarative half of the control plane: profiled traces and the
:class:`ClusterPlan`.

A ``ClusterPlan`` is the desired state the director maintains — the
``job → (group, shift, trace)`` assignment plus the group set — extracted
from :class:`~repro.core.scheduler.placement.PlacementPolicy`'s live fitting
state. The realized schedule (what the executor actually ran) is
continuously compared against it by the reconciler
(:mod:`repro.core.control_plane.reconcile`); divergence triggers
re-profiling, repacking, and live migration rather than a one-shot
placement decision.

Also here: the per-op → phase mapping and the fold that turns the
executor's :class:`~repro.core.scheduler.executor.PhaseRecord` stream into
the same :class:`~repro.core.scheduler.placement.JobTrace` the simulator
consumes, and :class:`DirectorConfig` — the knobs for the whole
profile → fit → reconcile loop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from repro.core.scheduler.placement import (  # noqa: F401 (re-exported)
    JobMove, JobTrace, PlacementConfig, PlacementPolicy, RepackPlan)

# Executor op value -> profiled phase (paper Table 2 cycle anatomy).
PHASE_OF_OP = {
    "generate": "rollout",
    "forward": "compute_log_prob",
    "update_actor": "update_actor",
    "forward_backward": "update_actor",
    "optim_step": "update_actor",
    "sync_weights": "sync_weight",
}
TRAIN_PHASES = ("compute_log_prob", "update_actor", "sync_weight")


@dataclasses.dataclass(frozen=True)
class DirectorConfig:
    horizon: float = 600.0          # rolling planning window (seconds)
    max_cycles: int = 64            # cap on pre-allocated warm cycles
    cold_cycles: int = 1            # clean cycles before the warm re-fit
    warmup_cycles: int = 1          # leading cycles DROPPED from the fold
    #   (the first cycle carries JIT compilation / cache warming and would
    #   poison the steady-state trace; set 0 for exact-replay tests)
    cold_reserve_s: float = 60.0    # dedicated-group reservation length
    group_nodes: int = 1            # node count of spawned groups
    min_groups: int = 1
    max_groups: int = 32
    spawn_queue_depth: int = 8      # per-group QUEUED depth triggering
    #   pressure relief (shed onto another group, else keep a spare)
    placement: Optional[PlacementConfig] = None
    # ---- reconciliation loop (§4.3.2's repack-when-diverged) -------------
    repack_interval_s: float = 60.0   # cadence of the occupancy-drift check
    plan_overlap_min: float = 0.5     # realized busy must overlap planned
    #   windows at least this fraction, else the group counts as drifted
    min_drift_busy_s: float = 1.0     # ignore groups with less measured busy
    drift_ratio: float = 1.5          # per-job period divergence (either
    #   direction) between the rolling cycle tail and the placed trace that
    #   triggers a re-profile + re-fit
    drift_window: int = 4             # trailing cycles the tail compares
    migration_floor_s: float = 0.001  # predicted-gain floor under which a
    #   repack move is skipped (fed from the measured
    #   placement/repack_migrate_s benchmark: ~1 ms per realized migration)
    cross_mesh_floor_s: Optional[float] = None  # floor for moves that cross
    #   mesh-slice domains (the reshard-included cost); None = start at
    #   migration_floor_s until the director has measured real cross-mesh
    #   migrations from Router.migrate_log
    # ---- incremental repack planning + stability --------------------------
    incremental_repack: bool = True   # reconcile passes plan deltas with the
    #   RepackIndex (dirty groups only, copy-on-write overlay); False falls
    #   back to the full plan_repack oracle on every pass
    repack_dest_search: int = 12      # cap on exact micro-shift searches per
    #   re-fitted job — the most-promising destinations by duty-overlap
    #   bound; 0 = search every non-pruned group (the oracle's behavior)
    migration_cooldown_s: float = 30.0  # hysteresis: a job migrated at t is
    #   pinned against further repack/shed moves until t + cooldown, so
    #   pressure relief cannot ping-pong it between two groups; promotions
    #   and drift re-fits bypass the cooldown (correctness beats stability
    #   when the trace itself changed). 0 disables.
    interference_ewma: float = 0.2    # weight folding realized-vs-planned
    #   busy overlap into each group's interference_scale (a group whose
    #   execution keeps landing outside the plan scores pessimistically in
    #   phase_interference until reality re-converges); 0 disables
    # ---- SLO-guarded preemption (multi-tenant service layer) --------------
    slo_window: int = 16              # rolling step-latency window per tenant
    #   (walls folded from the PhaseRecord stream; p95 is nearest-rank over
    #   this window)
    slo_min_samples: int = 4          # walls required before the p95 is
    #   meaningful — the SLO trigger never fires off one noisy sample
    slo_hold_s: float = 10.0          # when a breaching group has nowhere to
    #   shed the BEST_EFFORT victim, it is admission-held for this long
    #   (bounded, so best-effort work stays work-conserving, never starved);
    #   released early if the guaranteed tenant's p95 recovers


def trace_from_cycles(cycles: Sequence[Dict[str, float]],
                      nodes: int = 1) -> Optional[JobTrace]:
    """Fold per-cycle phase durations into a JobTrace (mean per phase, the
    same anatomy as ``traces.Profiler.trace``: training segments
    back-to-back after the rollout gap)."""
    mean: Dict[str, float] = {}
    for phase in ("rollout",) + TRAIN_PHASES:
        vals = [c[phase] for c in cycles if phase in c]
        if vals:
            mean[phase] = sum(vals) / len(vals)
    if "rollout" not in mean or "update_actor" not in mean:
        return None
    t = mean["rollout"]
    segs = []
    for p in TRAIN_PHASES:
        if p in mean:
            segs.append((t, mean[p]))
            t += mean[p]
    if t <= 1e-9:
        return None                 # degenerate (clock never advanced)
    return JobTrace(period=t, segments=tuple(segs), nodes=nodes)


@dataclasses.dataclass(frozen=True)
class JobAssignment:
    """One job's desired placement: where its profiled trace is anchored."""
    job_id: str
    group_id: int
    shift: float
    origin: float
    trace: JobTrace
    once: bool = False              # one-shot cold-profiling reservation


@dataclasses.dataclass(frozen=True)
class ClusterPlan:
    """Declarative desired state: the group set plus every job's
    assignment, versioned per placement change. Purely derived — the
    fitting source of truth stays in ``PlacementPolicy``; this is the
    stable snapshot operators, tests, and the reconciler diff against."""
    version: int
    t: float                        # time the snapshot was taken
    groups: Tuple[int, ...]
    assignments: Tuple[JobAssignment, ...]

    def assignment(self, job_id: str) -> Optional[JobAssignment]:
        for a in self.assignments:
            if a.job_id == job_id:
                return a
        return None

    def diff(self, other: "ClusterPlan") -> Dict[str, Tuple]:
        """Jobs whose (group, shift, origin) changed between two plans:
        ``job_id -> ((old group, shift) | None, (new group, shift) | None)``."""
        mine = {a.job_id: a for a in self.assignments}
        theirs = {a.job_id: a for a in other.assignments}
        out: Dict[str, Tuple] = {}
        for job_id in sorted(set(mine) | set(theirs)):
            a, b = mine.get(job_id), theirs.get(job_id)
            ka = (a.group_id, a.shift, a.origin) if a else None
            kb = (b.group_id, b.shift, b.origin) if b else None
            if ka != kb:
                out[job_id] = (ka, kb)
        return out


def plan_from_policy(policy: PlacementPolicy, version: int,
                     t: float) -> ClusterPlan:
    """Snapshot the live fitting state into a declarative ClusterPlan."""
    assigns = tuple(sorted(
        (JobAssignment(p.job_id, p.group_id, p.shift, p.origin, p.trace,
                       once=p.once)
         for p in policy.placed.values()),
        key=lambda a: a.job_id))
    groups = tuple(sorted(g.group_id for g in policy.groups))
    return ClusterPlan(version=version, t=t, groups=groups,
                       assignments=assigns)
