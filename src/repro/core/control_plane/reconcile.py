"""The reconciliation loop: detect divergence between the realized schedule
and the :class:`~repro.core.control_plane.plan.ClusterPlan`, and turn it
into repack / re-profile / shed decisions (paper §4.3.2's "repack when the
realized schedule diverges from the plan").

Four triggers, all event-driven from job-step hooks (no timer thread, so
the whole decision sequence replays bit-identically under a VirtualClock):

1. **Occupancy drift** (periodic, every ``repack_interval_s``): the
   executor's measured per-group busy windows
   (``TaskExecutor.group_busy_since``) are overlapped with the plan's
   predicted windows (``NodeGroup.planned_windows``). A group whose
   realized execution falls mostly OUTSIDE its planned windows has drifted;
   the policy plans an incremental repack
   (:meth:`~repro.core.scheduler.placement.PlacementPolicy.plan_repack`)
   whose moves carry predicted interference deltas and respect the
   migration-cost floor.
2. **Phase drift** (per job): the rolling cycle tail the profiler retains
   is folded into a fresh trace and compared against the trace the job was
   PLACED with. Period divergence beyond ``drift_ratio`` (either direction
   — response lengths grow as policies improve, "RL in the Wild") re-fits
   the job on the re-profiled trace.
3. **Queue pressure** (per telemetry poll): a deep-queued group hosting
   more than one warm job sheds its worst-interfering resident onto
   another group (spawning a spare if none fits) instead of merely adding
   idle capacity.
4. **SLO breach** (multi-tenant service layer): a GUARANTEED tenant whose
   rolling p95 step latency exceeds its SLO preempts the most-interfering
   BEST_EFFORT job sharing its group — shed elsewhere when a placement
   exists, else admission-held for a bounded window (work-conserving:
   best-effort work is delayed, never starved). Cooldown-aware via the
   director's ``migration_cooldown_s`` pins, so preemption cannot
   ping-pong a victim.

The reconciler only *decides*; the director applies decisions to the
placement state and realizes migrations through ``Router.reassign_jobs``.
Scoring is shared with the offline simulator (``phase_interference`` /
``least_interfering_group`` in ``scheduler/placement.py``) so predictions
and the live loop can never disagree by construction.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.control_plane.plan import (DirectorConfig, JobTrace,
                                           trace_from_cycles)
from repro.core.scheduler.placement import (NodeGroup, Placed,
                                            PlacementPolicy, RepackPlan,
                                            phase_interference)
from repro.core.scheduler.repack_index import RepackIndex


class Reconciler:
    """Drift detection + repack planning over one PlacementPolicy.

    Owns the rolling state the triggers need (repack cadence anchor,
    per-group busy-window cursors, the incremental repack index); holds no
    lock of its own — the director serializes calls under its decision
    lock."""

    def __init__(self, policy: PlacementPolicy, cfg: DirectorConfig):
        self.policy = policy
        self.cfg = cfg
        self.index = RepackIndex(policy)
        self._last_repack_t: Optional[float] = None
        self._busy_cursors: Dict[int, int] = {}

    # ------------------------------------------- trigger 1: occupancy drift
    def due(self, now: float) -> bool:
        """Periodic gate — a PURE predicate. Unanchored (no observation
        yet) is never due; :meth:`check`'s first observation anchors the
        cadence. (The old version mutated ``_last_repack_t`` inside this
        predicate, so merely ASKING whether a pass was due silently
        re-anchored the clock.)"""
        if self._last_repack_t is None:
            return False
        return now - self._last_repack_t >= self.cfg.repack_interval_s

    def anchor(self, now: float) -> None:
        """Anchor / advance the periodic cadence. Called on the first
        observation and after each SCHEDULED pass — never by forced
        passes, which would otherwise push back the next scheduled one."""
        self._last_repack_t = now

    def occupancy_drift(self, executor) -> List[dict]:
        """Realized-vs-planned busy overlap per group since the last check.
        Returns the groups whose measured execution diverged from the plan
        (overlap ratio below ``plan_overlap_min`` over at least
        ``min_drift_busy_s`` of measured busy time)."""
        drifted: List[dict] = []
        for g in sorted(self.policy.groups, key=lambda g: g.group_id):
            cursor = self._busy_cursors.get(g.group_id, 0)
            windows = executor.group_busy_since(g.group_id, cursor)
            if not windows:
                continue
            self._busy_cursors[g.group_id] = windows[-1][0]
            busy = sum(t1 - t0 for _, _, t0, t1 in windows)
            if busy < self.cfg.min_drift_busy_s:
                continue
            overlap = sum(min(g.planned_overlap(t0, t1), t1 - t0)
                          for _, _, t0, t1 in windows)
            ratio = overlap / busy
            beta = self.cfg.interference_ewma
            if beta > 0.0:
                # fold realized-vs-planned overlap back into the group's
                # interference prediction: fully on-plan (ratio 1) decays
                # toward neutral 1.0, fully off-plan (ratio 0) toward a 2x
                # pessimistic score, so planners route new load away from
                # groups whose execution keeps missing the plan
                target = min(2.0, max(1.0, 2.0 - ratio))
                g.interference_scale += beta * (target - g.interference_scale)
            if ratio < self.cfg.plan_overlap_min:
                drifted.append(dict(group=g.group_id,
                                    busy_s=round(busy, 6),
                                    overlap_ratio=round(ratio, 4)))
        return drifted

    def check(self, now: float, executor,
              eligible: Optional[Sequence[int]] = None,
              force: bool = False,
              min_gain: Optional[float] = None,
              cross_min_gain: Optional[float] = None,
              mesh_of: Optional[Dict[int, int]] = None,
              exclude: frozenset = frozenset()
              ) -> Optional[Tuple[RepackPlan, List[dict]]]:
        """The periodic reconcile pass: when due (or forced), measure
        occupancy drift and — if any group diverged — plan an incremental
        repack against the live absolute-time windows. Returns
        ``(plan, drifted_groups)`` or None when nothing is due/diverged.

        Cadence rules: the first observation anchors the clock (and plans
        nothing unless forced); only a SCHEDULED (due) pass re-anchors it,
        so forced passes never delay the next scheduled one.

        Planning goes through the :class:`RepackIndex` (drifted groups are
        marked dirty, candidates come from dirty groups only) unless
        ``cfg.incremental_repack`` is off, which falls back to the full
        ``plan_repack`` oracle.

        ``min_gain`` / ``cross_min_gain`` override the configured
        migration-cost floor with the director's MEASURED same-mesh /
        cross-mesh migration costs; ``mesh_of`` maps group ids to
        mesh-slice domains so the planner knows which moves pay the
        cross-mesh reshard; ``exclude`` pins jobs (the director's
        migration cooldown)."""
        if self._last_repack_t is None:
            self.anchor(now)
            if not force:
                return None
        elif self.due(now):
            self.anchor(now)
        elif not force:
            return None
        drifted = self.occupancy_drift(executor)
        if not drifted and not force:
            return None
        floor = (self.cfg.migration_floor_s if min_gain is None
                 else min_gain)
        for d in drifted:
            self.index.mark_dirty(d["group"])
        if self.cfg.incremental_repack:
            cap = self.cfg.repack_dest_search
            plan = self.index.plan(
                origin=now, groups=eligible, min_gain=floor,
                cross_min_gain=cross_min_gain, mesh_of=mesh_of,
                exclude=exclude,
                max_dest_search=cap if cap > 0 else None)
        else:
            plan = self.policy.plan_repack(
                origin=now, groups=eligible, min_gain=floor,
                cross_min_gain=cross_min_gain, mesh_of=mesh_of,
                exclude=exclude)
        return plan, drifted

    # --------------------------------------------- trigger 2: phase drift
    def phase_drift(self, cycles: Sequence[Dict[str, float]],
                    placed_trace: Optional[JobTrace],
                    nodes: int) -> Optional[Tuple[JobTrace, float]]:
        """Compare the rolling cycle tail against the trace the job was
        placed with; on divergence beyond ``drift_ratio`` return the
        re-profiled trace and the observed ratio."""
        cfg = self.cfg
        if placed_trace is None or placed_trace.period <= 0.0:
            return None
        if len(cycles) < cfg.drift_window:
            return None
        recent = trace_from_cycles(cycles[-cfg.drift_window:], nodes)
        if recent is None or recent.period <= 0.0:
            return None
        ratio = max(recent.period / placed_trace.period,
                    placed_trace.period / recent.period)
        if ratio < cfg.drift_ratio:
            return None
        return recent, ratio

    # -------------------------------------------- trigger 3: queue pressure
    def pick_shed(self, group: Optional[NodeGroup],
                  exclude=frozenset()) -> Optional[Placed]:
        """The worst-interfering warm resident of a deep-queued group — the
        job a pressure-relief repack moves onto another group. None when
        the group hosts fewer than two warm jobs (shedding the only job
        just moves the queue). ``exclude`` skips jobs the director already
        has a migration in flight for."""
        if group is None:
            return None
        warm = [p for p in group.resident
                if not p.once and p.job_id not in exclude]
        if len(warm) < 2:
            return None
        scored = sorted(
            warm,
            key=lambda p: (-phase_interference(p.trace, p.shift, group,
                                               p.origin, exclude=p.job_id),
                           p.job_id))
        return scored[0]

    # ----------------------------------------------- trigger 4: SLO breach
    def pick_preempt(self, group: Optional[NodeGroup], is_best_effort,
                     exclude=frozenset()) -> Optional[Placed]:
        """The BEST_EFFORT victim to preempt off a group whose GUARANTEED
        tenant is breaching its SLO: the most-interfering warm best-effort
        resident. Unlike :meth:`pick_shed` there is no min-2 requirement —
        removing the group's only best-effort job is exactly the point.
        ``is_best_effort(job_id) -> bool`` comes from the tenant ledger;
        ``exclude`` pins jobs already migrating, cooled, or held."""
        if group is None:
            return None
        victims = [p for p in group.resident
                   if not p.once and p.job_id not in exclude
                   and is_best_effort(p.job_id)]
        if not victims:
            return None
        scored = sorted(
            victims,
            key=lambda p: (-phase_interference(p.trace, p.shift, group,
                                               p.origin, exclude=p.job_id),
                           p.job_id))
        return scored[0]
