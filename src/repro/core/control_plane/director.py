"""Cluster control plane: online profiling, automatic placement, capacity
adjustment, and continuous reconciliation (paper §4.3-§4.4).

The :class:`PlacementDirector` closes the loop between the trace-fitting
placement machinery (``scheduler/placement.py``), the live serve-mode
dispatch plane (``router.py``), and state migration — and, since the
reconciliation refactor, keeps closing it: placement is a *loop*, not a
one-shot decision at cold→warm promotion.

- **Online profiler.** The executor exports a per-job stream of
  :class:`~repro.core.scheduler.executor.PhaseRecord` completions; the
  director folds them into per-cycle phase durations and, once a clean
  cycle exists, into the same
  :class:`~repro.core.scheduler.placement.JobTrace` the simulator consumes
  (§4.3.2 cold-start profiling). A bounded rolling tail of cycles is
  retained for EVERY job so drift can be re-profiled later.
- **Cold → warm lifecycle.** A job arriving with no trace is placed on a
  dedicated profiling group (``place_cold``); after ``cold_cycles`` clean
  cycles it is re-fitted with ``place_warm`` micro-shift search
  (pack-first) and, if the fit lands elsewhere, migrated live.
- **Reconciliation** (:mod:`repro.core.control_plane.reconcile`). Four
  standing triggers keep the realized schedule converged on the
  :class:`~repro.core.control_plane.plan.ClusterPlan`: periodic
  realized-vs-planned occupancy drift plans an incremental repack
  (migration-cost floor respected), per-job phase drift re-profiles and
  re-fits a diverged job, queue pressure sheds the worst-interfering
  job off a deep-queued group, and a GUARANTEED tenant's SLO breach
  (rolling p95 step latency, folded per tenant from the PhaseRecord
  stream) preempts the most-interfering BEST_EFFORT job on its group —
  shed elsewhere when a placement exists, else admission-held for a
  bounded ``slo_hold_s`` window. Repack planning goes through the
  :class:`~repro.core.scheduler.repack_index.RepackIndex` (dirty groups
  only — flat cost at fleet scale; ``plan_repack`` stays the oracle), and
  a per-job migration cooldown (``migration_cooldown_s``) pins recently
  moved jobs so pressure relief cannot ping-pong them. Decisions batch
  into ordered :class:`~repro.core.scheduler.placement.JobMove` lists
  realized through ``Router.reassign_jobs`` (vacate-before-fill, per-move
  rollback).
- **Capacity adjuster** (§4.4). Queue-depth / occupancy telemetry drives
  group spawn (``Router.ensure_group``) and retire
  (``Router.retire_group``), bounded by ``min_groups`` / ``max_groups``.

Everything is event-driven from job arrivals and step completions (no
background timer thread), so the whole decision sequence is deterministic
under a :class:`~repro.core.scheduler.executor.VirtualClock` and replayable
bit-identically; ``events`` is the append-only decision log tests and
operators read.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence

from repro.core.control_plane.plan import (PHASE_OF_OP, ClusterPlan,
                                           DirectorConfig, plan_from_policy,
                                           trace_from_cycles)
from repro.core.control_plane.reconcile import Reconciler
from repro.core.scheduler.executor import TaskExecutor  # noqa: F401 (docs)
from repro.core.scheduler.intervals import IntervalSet
from repro.core.scheduler.placement import (JobMove, JobTrace, NodeGroup,
                                            Placed, PlacementConfig,
                                            PlacementPolicy, group_duty)


@dataclasses.dataclass
class _JobState:
    job_id: str
    nodes: int
    phase: str = "cold"             # "cold" (profiling) | "warm" (fitted)
    group_id: int = -1
    seq_cursor: int = 0             # last consumed PhaseRecord.seq
    open_cycle: Dict[str, float] = dataclasses.field(default_factory=dict)
    cycles: List[Dict[str, float]] = dataclasses.field(default_factory=list)
    trace: Optional[JobTrace] = None
    # wall-clock bounds of the open cycle (for the per-tenant step-latency
    # fold): first record's start, last record's end
    open_cycle_t0: Optional[float] = None
    last_rec_end: float = 0.0


class PlacementDirector:
    """Live placement + capacity control over a Router's node groups.

    Thread-safe: client threads call :meth:`assign` / :meth:`on_job_step` /
    :meth:`on_job_removed` concurrently; one re-entrant lock serializes
    decisions (the underlying Router/executor operations take their own
    locks). The blocking half of every migration — the admission-hold
    drain — runs OUTSIDE the lock."""

    def __init__(self, router, cfg: Optional[DirectorConfig] = None,
                 initial_groups: Sequence[int] = (), tenancy=None):
        self.router = router
        self.cfg = cfg or DirectorConfig()
        # multi-tenant service layer: a TenantLedger (or None on untenanted
        # planes). Supplies the SLO trigger's inputs — per-tenant rolling
        # step-latency windows (fed back by _fold) and class lookups.
        self.tenancy = tenancy
        # jobs admission-held by the SLO trigger because no placement could
        # absorb them: job_id -> (hold time, guard job whose SLO they broke)
        self._slo_holds: Dict[str, tuple] = {}
        # jobs currently observed in breach (edge-triggered logging)
        self._slo_breached: set = set()
        pcfg = self.cfg.placement or PlacementConfig(horizon=self.cfg.horizon)
        self.policy = PlacementPolicy([], pcfg)
        self.reconciler = Reconciler(self.policy, self.cfg)
        self._lock = threading.RLock()
        self._jobs: Dict[str, _JobState] = {}
        # jobs with a migration currently draining OUTSIDE the lock: no
        # further re-placement decision may target them until the move
        # settles (hold_job/release_job are not refcounted, so a second
        # concurrent migration of the same job would drop the first one's
        # admission hold mid-copy)
        self._migrating: set = set()
        # realized-migration timestamps backing the cooldown hysteresis:
        # repack and pressure-shed may not move a job again until
        # migration_cooldown_s after its last realized move
        self._last_migrated: Dict[str, float] = {}
        # measured migration-cost floors (EWMA of realized costs from
        # Router.migrate_log), keyed by cross_mesh; None = not yet measured
        # (fall back to the configured floors). VirtualClock runs record
        # zero-duration migrations, which are discarded — replay stays
        # bit-identical to the configured-floor decisions.
        self._measured_floor: Dict[bool, Optional[float]] = {
            False: None, True: None}
        self._migrate_cursor = 0
        self.events: List[dict] = []
        self._plan: Optional[ClusterPlan] = None
        self._plan_version = 0
        self._plan_dirty = True
        for g in initial_groups:
            self.register_group(g)

    # Decision-log retention: decisions are per job-lifecycle (not
    # per-step), but a long-lived plane with heavy job churn still accretes
    # — keep the most recent window.
    MAX_EVENTS = 4096

    # ------------------------------------------------------------- helpers
    def _log(self, event: str, **kw):
        self.events.append(dict(event=event, **kw))
        if len(self.events) > self.MAX_EVENTS:
            del self.events[:len(self.events) - self.MAX_EVENTS]

    def job_state(self, job_id: str) -> Optional[_JobState]:
        with self._lock:
            return self._jobs.get(job_id)

    def profiled_trace(self, job_id: str) -> Optional[JobTrace]:
        with self._lock:
            js = self._jobs.get(job_id)
            return js.trace if js else None

    def cluster_plan(self) -> ClusterPlan:
        """The declarative desired state — ``job → (group, shift, trace)``
        plus the group set — re-derived (and re-versioned) whenever a
        decision changed the placement."""
        with self._lock:
            if self._plan is None or self._plan_dirty:
                self._plan_version += 1
                self._plan = plan_from_policy(self.policy,
                                              self._plan_version,
                                              self.router.now())
                self._plan_dirty = False
            return self._plan

    def _cooled(self, now: float) -> frozenset:
        """Jobs inside their migration cooldown: moved less than
        ``migration_cooldown_s`` ago, pinned against repack/shed (the
        hysteresis that keeps pressure relief from ping-ponging one job
        between two groups). Promotions and drift re-fits bypass this —
        when the trace itself changed, correctness beats stability.
        Expired entries are dropped in passing. Call under ``_lock``."""
        cd = self.cfg.migration_cooldown_s
        if cd <= 0.0:
            return frozenset()
        for j in [j for j, t in self._last_migrated.items()
                  if now - t >= cd]:
            del self._last_migrated[j]
        return frozenset(self._last_migrated)

    def _cold_groups(self, exclude_job: Optional[str] = None) -> set:
        return {s.group_id for s in self._jobs.values()
                if s.phase == "cold" and s.job_id != exclude_job}

    def register_group(self, group_id: int):
        """Track an externally created group (e.g. the cluster's seed
        groups) in the placement state."""
        with self._lock:
            if self.policy.group(group_id) is not None:
                return
            now = self.router.now()
            self.policy.add_group(NodeGroup(
                group_id, self.cfg.group_nodes,
                IntervalSet([(now, now + self.cfg.horizon)]),
                horizon_end=now + self.cfg.horizon))
            self._plan_dirty = True

    def _spawn_group(self, now: float, reason: str) -> int:
        known = set(self.router.known_groups()) | \
            {g.group_id for g in self.policy.groups}
        gid = max(known, default=-1) + 1
        self.router.ensure_group(gid)
        self.policy.add_group(NodeGroup(
            gid, self.cfg.group_nodes,
            IntervalSet([(now, now + self.cfg.horizon)]),
            horizon_end=now + self.cfg.horizon))
        self._plan_dirty = True
        self._log("spawn_group", group=gid, reason=reason, t=now)
        return gid

    def _advance(self, now: float):
        """Roll every group's planning window: retire capacity behind
        ``now``, project resident jobs into the extended horizon."""
        for g in self.policy.groups:
            g.advance_to(now)
            g.extend_to(now + self.cfg.horizon)

    # ------------------------------------------------------------- arrival
    def assign(self, job_id: str, nodes: int = 1,
               expected_duration: Optional[float] = None) -> int:
        """Place an arriving (trace-less) job: a dedicated profiling group,
        spawning one if none is free (§4.3.2 cold start). Returns the
        group_id the caller should deploy onto."""
        with self._lock:
            if job_id in self._jobs:
                return self._jobs[job_id].group_id
            now = self.router.now()
            self._advance(now)
            dur = min(expected_duration or self.cfg.cold_reserve_s,
                      self.cfg.horizon * 0.5)
            placed = self.policy.place_cold(job_id, nodes, dur, origin=now)
            if placed is None and len(self.policy.groups) < self.cfg.max_groups:
                self._spawn_group(now, reason=f"cold:{job_id}")
                placed = self.policy.place_cold(job_id, nodes, dur,
                                                origin=now)
            if placed is None:
                # fleet at max size and no clean group: profile on the group
                # with the fewest residents (profiling is noisier, not wrong)
                g = min(self.policy.groups,
                        key=lambda g: (len(g.resident), g.group_id))
                gid = g.group_id
                self._log("cold_overflow", job=job_id, group=gid, t=now)
            else:
                gid = placed.group_id
                self._log("cold_place", job=job_id, group=gid, t=now)
            self._jobs[job_id] = _JobState(job_id, nodes, "cold", gid)
            self._plan_dirty = True
            return gid

    def adopt_warm(self, job_id: str, trace: JobTrace, group_id: int,
                   shift: float = 0.0, nodes: int = 1) -> int:
        """Register an externally profiled WARM job at an exact placement —
        the warm-start handoff path (e.g. re-adopting a checkpointed
        ClusterPlan after a restart): the job skips cold profiling and is
        tracked, drift-checked, and reconciled like any promoted job.
        Returns the group id."""
        with self._lock:
            now = self.router.now()
            self.register_group(group_id)
            self._advance(now)
            # an already-tracked job (e.g. assigned cold) must not leave a
            # ghost reservation behind on its old group
            self.policy.remove(job_id)
            self.policy.place_at(job_id, trace, group_id, shift, origin=now)
            js = self._jobs.get(job_id) or _JobState(job_id, nodes)
            js.nodes, js.phase, js.group_id = nodes, "warm", group_id
            js.trace = trace
            self._jobs[job_id] = js
            self._plan_dirty = True
            self._log("adopt_warm", job=job_id, group=group_id,
                      shift=shift, period=trace.period, t=now)
            return group_id

    # ---------------------------------------------------------- telemetry
    def _fold(self, js: _JobState):
        """Consume the job's new PhaseRecords: carve live completions out of
        group free windows and accumulate per-cycle phase durations."""
        recs = self.router.executor.phase_records_since(js.job_id,
                                                        js.seq_cursor)
        for r in recs:
            js.seq_cursor = max(js.seq_cursor, r.seq)
            g = self.policy.group(r.group_id)
            if g is not None:
                g.note_busy(r.t_started, r.t_finished)
            phase = PHASE_OF_OP.get(r.op)
            if phase is None:
                continue
            if (phase == "rollout" and "rollout" in js.open_cycle
                    and "update_actor" in js.open_cycle):
                self._close_cycle(js)             # next cycle's rollout
            if not js.open_cycle:
                js.open_cycle_t0 = r.t_started
            js.open_cycle[phase] = js.open_cycle.get(phase, 0.0) + r.duration
            js.last_rec_end = r.t_finished
        # a completed step means the open cycle (if whole) is closed
        if "rollout" in js.open_cycle and "update_actor" in js.open_cycle:
            self._close_cycle(js)
        # bounded history for EVERY job: promotion reads the first
        # warmup+cold cycles and drift re-profiling the rolling tail, so
        # nothing needs more than this window — in particular a job stuck
        # cold (its cycles never fold into a usable trace) must not
        # accumulate one dict per step forever
        keep = (self.cfg.warmup_cycles + self.cfg.cold_cycles
                + max(8, self.cfg.drift_window))
        if len(js.cycles) > keep:
            del js.cycles[:len(js.cycles) - keep]

    def _close_cycle(self, js: _JobState):
        """Close the open profiling cycle; its WALL time (first record start
        to last record end — queueing and interference included, which is
        exactly what a tenant experiences) feeds the per-tenant step-latency
        window the SLO trigger reads."""
        js.cycles.append(js.open_cycle)
        js.open_cycle = {}
        if self.tenancy is not None and js.open_cycle_t0 is not None:
            wall = js.last_rec_end - js.open_cycle_t0
            if wall > 0.0:
                self.tenancy.record_step(js.job_id, wall)
        js.open_cycle_t0 = None

    # ----------------------------------------------------------- lifecycle
    def on_job_step(self, job_id: str):
        """Per-step hook (event-driven; deterministic under VirtualClock):
        fold telemetry, promote cold→warm once profiled, run the
        reconciliation triggers (phase drift, periodic occupancy drift,
        queue pressure), adjust capacity.

        Decisions mutate the placement state under the lock; the blocking
        half — the batched migration drain — runs OUTSIDE it, so one job's
        migration never stalls other jobs' step hooks or new-job
        placement."""
        moves: List[JobMove] = []
        with self._lock:
            js = self._jobs.get(job_id)
            if js is None:
                return
            now = self.router.now()
            self._advance(now)
            self._fold(js)
            self._release_slo_holds(now)
            if js.job_id in self._migrating:
                pass          # another thread is mid-move: defer decisions
            elif (js.phase == "cold"
                    and len(js.cycles) >= (self.cfg.warmup_cycles
                                           + self.cfg.cold_cycles)):
                mv = self._promote(js, now)
                if mv is not None:
                    moves.append(mv)
            elif js.phase == "warm":
                mv = self._check_drift(js, now)
                if mv is not None:
                    moves.append(mv)
                mv = self._check_slo(js, now)
                if mv is not None:
                    moves.append(mv)
            moves += self._reconcile(now)
            moves += self._adjust_capacity(now)
            self._migrating.update(m.job_id for m in moves)
        self._realize(moves)

    def _promote(self, js: _JobState, now: float) -> Optional[JobMove]:
        """Cold→warm: build the profiled trace, micro-shift fit it
        (pack-first). Returns the move the caller must realize when the fit
        lands on another group, else None."""
        trace = trace_from_cycles(js.cycles[self.cfg.warmup_cycles:],
                                  js.nodes)
        if trace is None:
            return None
        self.policy.remove(js.job_id)      # release the cold reservation
        placed = self._fit_warm(js.job_id, trace, now)
        js.trace = trace
        js.phase = "warm"
        self._plan_dirty = True
        if placed is None:
            self._log("unplaceable", job=js.job_id, group=js.group_id,
                      period=trace.period, t=now)
            return None
        old_gid = js.group_id
        js.group_id = placed.group_id
        self._log("warm_place", job=js.job_id, group=placed.group_id,
                  shift=placed.shift, period=trace.period,
                  duty=trace.duty(), t=now)
        if placed.group_id != old_gid:
            return JobMove(js.job_id, old_gid, placed.group_id,
                           placed.shift, origin=placed.origin,
                           n_cycles=placed.n_cycles)
        return None

    def _check_drift(self, js: _JobState, now: float) -> Optional[JobMove]:
        """Trigger 2: the rolling cycle tail diverged from the placed trace
        — re-profile, re-fit, and (when the fit moves) migrate."""
        hit = self.reconciler.phase_drift(js.cycles, js.trace, js.nodes)
        if hit is None:
            return None
        recent, ratio = hit
        old = self.policy.placed.get(js.job_id)
        self._log("drift", job=js.job_id, ratio=round(ratio, 4),
                  old_period=js.trace.period, new_period=recent.period,
                  t=now)
        self.policy.remove(js.job_id)
        placed = self._fit_warm(js.job_id, recent, now)
        js.trace = recent
        self._plan_dirty = True
        if placed is None:
            self._log("unplaceable", job=js.job_id, group=js.group_id,
                      period=recent.period, t=now)
            return None
        old_gid = js.group_id
        js.group_id = placed.group_id
        self._log("warm_place", job=js.job_id, group=placed.group_id,
                  shift=placed.shift, period=recent.period,
                  duty=recent.duty(), t=now, reason="drift")
        if placed.group_id != old_gid:
            return JobMove(js.job_id, old_gid, placed.group_id,
                           placed.shift, origin=placed.origin,
                           src_shift=old.shift if old else 0.0,
                           src_origin=old.origin if old else now,
                           n_cycles=placed.n_cycles)
        return None

    def _check_slo(self, js: _JobState, now: float) -> Optional[JobMove]:
        """Trigger 4 (SLO-guarded preemption): the stepping job's tenant is
        GUARANTEED and its rolling p95 step latency breached its SLO —
        preempt the most-interfering BEST_EFFORT job on the group. Shed it
        elsewhere when a placement exists (same hold→drain→migrate
        machinery as queue-pressure shed); otherwise admission-hold it for
        a bounded ``slo_hold_s`` window (work-conserving: delayed, never
        starved). Cooldown pins (``migration_cooldown_s``) apply to victims
        exactly as to repack moves, so preemption cannot ping-pong."""
        if self.tenancy is None:
            return None
        if not self.tenancy.slo_breach(js.job_id):
            if js.job_id in self._slo_breached:
                self._slo_breached.discard(js.job_id)
                self._log("slo_recovered", job=js.job_id, t=now)
            return None
        if js.job_id not in self._slo_breached:
            self._slo_breached.add(js.job_id)
            spec = self.tenancy.spec_of_job(js.job_id)
            self._log("slo_breach", job=js.job_id, group=js.group_id,
                      tenant=spec.tenant_id,
                      p95=self.tenancy.step_p95(spec.tenant_id),
                      slo=spec.slo_step_latency_s, t=now)
        victim = self.reconciler.pick_preempt(
            self.policy.group(js.group_id), self.tenancy.is_best_effort,
            exclude=frozenset(self._migrating) | self._cooled(now)
            | set(self._slo_holds) | {js.job_id})
        if victim is None:
            return None
        cold = self._cold_groups()
        others = [x.group_id for x in self.policy.groups
                  if x.group_id != js.group_id
                  and x.group_id not in cold]
        self.policy.remove(victim.job_id)
        placed = None
        if others:
            placed = self.policy.place_warm(victim.job_id, victim.trace,
                                            origin=now, groups=others,
                                            pack=True)
        if placed is None and len(self.policy.groups) < self.cfg.max_groups:
            spare = self._spawn_group(now, reason=f"slo:{js.job_id}")
            placed = self.policy.place_warm(victim.job_id, victim.trace,
                                            origin=now, groups=[spare])
        if placed is None:
            # nowhere to move it: restore the reservation and HOLD the
            # victim's admissions instead. The hold is bounded (slo_hold_s)
            # and released early if the guard's p95 recovers; the cooldown
            # stamp keeps the next breach from re-targeting it instantly.
            self.policy.place_at(victim.job_id, victim.trace, js.group_id,
                                 victim.shift, origin=victim.origin,
                                 n_cycles=victim.n_cycles)
            self.router.executor.hold_job(victim.job_id)
            self._slo_holds[victim.job_id] = (now, js.job_id)
            self._last_migrated[victim.job_id] = now
            self._log("slo_hold", job=victim.job_id, group=js.group_id,
                      guard=js.job_id, t=now)
            return None
        vjs = self._jobs.get(victim.job_id)
        if vjs is not None:
            vjs.group_id = placed.group_id
        self._plan_dirty = True
        self._log("slo_preempt", job=victim.job_id, src=js.group_id,
                  dst=placed.group_id, guard=js.job_id, t=now)
        return JobMove(victim.job_id, js.group_id, placed.group_id,
                       placed.shift, origin=placed.origin,
                       src_shift=victim.shift, src_origin=victim.origin,
                       n_cycles=placed.n_cycles)

    def _release_slo_holds(self, now: float):
        """Release SLO admission holds whose window elapsed or whose guard
        job's tenant recovered. Event-driven from step hooks (no timer
        thread — deterministic under VirtualClock). Call under ``_lock``."""
        if not self._slo_holds:
            return
        for job_id, (t0, guard) in list(self._slo_holds.items()):
            recovered = (self.tenancy is None
                         or not self.tenancy.slo_breach(guard))
            if recovered or now - t0 >= self.cfg.slo_hold_s:
                del self._slo_holds[job_id]
                self.router.executor.release_job(job_id)
                self._log("slo_release", job=job_id, guard=guard,
                          reason="recovered" if recovered else "timeout",
                          t=now)

    def placement_feasible(self) -> bool:
        """Admission-time feasibility for the tenancy layer: can the
        cluster host one more job WITHOUT unbounded spawning? True while a
        new group may still be spawned (< max_groups) or any existing group
        has duty slack left. Conservative by design — it never spawns or
        reserves anything; the actual placement happens post-admission."""
        with self._lock:
            if len(self.policy.groups) < self.cfg.max_groups:
                return True
            return any(group_duty(g) < g.nodes * 1.0 - 1e-9
                       for g in self.policy.groups)

    def _reconcile(self, now: float, force: bool = False) -> List[JobMove]:
        """Trigger 1: periodic realized-vs-planned occupancy check; on
        drift (or ``force``) plan an incremental repack and apply it."""
        if self._migrating:
            return []     # a move is draining: plan against settled state
        if not any(not p.once for p in self.policy.placed.values()):
            return []
        cold = self._cold_groups()
        eligible = [g.group_id for g in self.policy.groups
                    if g.group_id not in cold]
        if not eligible:
            return []
        mesh_of = (self.router.mesh_domains()
                   if hasattr(self.router, "mesh_domains") else None)
        res = self.reconciler.check(now, self.router.executor, eligible,
                                    force=force,
                                    min_gain=self.migration_floor(False),
                                    cross_min_gain=self.migration_floor(True),
                                    mesh_of=mesh_of,
                                    exclude=self._cooled(now))
        if res is None:
            return []
        plan, drifted = res
        if drifted:
            self._log("occupancy_drift", groups=drifted, t=now)
        if not plan.moves and not plan.reshifts:
            return []
        self.policy.apply_repack(plan)
        self._plan_dirty = True
        for m in plan.moves:
            mjs = self._jobs.get(m.job_id)
            if mjs is not None:
                mjs.group_id = m.dst_group
        self._log("repack",
                  moves=[(m.job_id, m.src_group, m.dst_group,
                          round(m.gain, 6)) for m in plan.moves],
                  reshifts=list(plan.reshifts),
                  skipped=[(m.job_id, m.src_group, m.dst_group,
                            round(m.gain, 6)) for m in plan.skipped],
                  t=now)
        return list(plan.moves)

    def _fit_warm(self, job_id: str, trace: JobTrace,
                  now: float) -> Optional[Placed]:
        n_cycles = max(1, min(self.cfg.max_cycles,
                              int(self.cfg.horizon
                                  // max(trace.period, 1e-9))))
        cold_groups = self._cold_groups(exclude_job=job_id)
        # pack-first: consolidate onto groups already hosting warm jobs so
        # drained profiling groups become retirable (repacking density,
        # §4.3.2) — then the remaining (resident-free) non-profiling
        # groups, then a fresh spawn
        tiers = [
            [g.group_id for g in self.policy.groups
             if g.resident and g.group_id not in cold_groups],
            [g.group_id for g in self.policy.groups
             if not g.resident and g.group_id not in cold_groups],
        ]
        for tier in tiers:
            if not tier:
                continue
            placed = self.policy.place_warm(job_id, trace,
                                            n_cycles=n_cycles,
                                            origin=now, groups=tier)
            if placed is not None:
                return placed
        if len(self.policy.groups) < self.cfg.max_groups:
            gid = self._spawn_group(now, reason=f"warm:{job_id}")
            return self.policy.place_warm(job_id, trace, n_cycles=n_cycles,
                                          origin=now, groups=[gid])
        return None

    def on_job_removed(self, job_id: str):
        with self._lock:
            js = self._jobs.pop(job_id, None)
            self._last_migrated.pop(job_id, None)
            self._slo_breached.discard(job_id)
            # a held victim leaving keeps no dangling hold; holds guarded
            # by the departing job lose their reason and release at once
            if self._slo_holds.pop(job_id, None) is not None:
                self.router.executor.release_job(job_id)
            for held, (_, guard) in list(self._slo_holds.items()):
                if guard == job_id:
                    del self._slo_holds[held]
                    self.router.executor.release_job(held)
            self.policy.remove(job_id)
            self.router.executor.drop_job_telemetry(job_id)
            self._plan_dirty = True
            now = self.router.now()
            if js is not None:
                self._log("job_removed", job=job_id, t=now)
            self._retire_idle(now)

    # -------------------------------------------- measured migration floor
    def migration_floor(self, cross_mesh: bool = False) -> float:
        """The migration-cost floor the planner should charge a move:
        the MEASURED realized cost (EWMA over Router.migrate_log) once any
        migration of that kind has run, else the configured floor
        (``cross_mesh_floor_s`` falls back to the same-mesh measurement,
        then to ``migration_floor_s``)."""
        m = self._measured_floor[cross_mesh]
        if m is not None:
            return m
        if cross_mesh:
            if self.cfg.cross_mesh_floor_s is not None:
                return self.cfg.cross_mesh_floor_s
            if self._measured_floor[False] is not None:
                return self._measured_floor[False]
        return self.cfg.migration_floor_s

    def _ingest_migration_costs(self):
        """Fold newly realized migrations (reshard time included) into the
        per-kind floor EWMAs. Zero-duration records (VirtualClock replays,
        where transfers take no virtual time) are discarded so replayed
        decision sequences stay bit-identical. Call under ``_lock``."""
        log = getattr(self.router, "migrate_log", None)
        if log is None:
            return
        new = log[self._migrate_cursor:]
        self._migrate_cursor = len(log)
        for ev in new:
            dt = ev.get("seconds", 0.0)
            if dt <= 0.0:
                continue
            kind = bool(ev.get("cross_mesh"))
            old = self._measured_floor[kind]
            self._measured_floor[kind] = (dt if old is None
                                          else 0.7 * old + 0.3 * dt)

    # ---------------------------------------------------------- realization
    def _realize(self, moves: List[JobMove]):
        """Realize a batch of decided moves through the router (batched
        hold→drain→migrate→rehome, dependency-ordered). The placement
        state already reflects the decisions; a failed move is rolled back
        — re-fitted onto its source group — leaving the plan partially
        realized but consistent."""
        if not moves:
            return
        try:
            # several triggers may have re-placed the same job in one tick;
            # the policy holds only the LAST decision, so merge into
            # first.src -> last.dst and drop no-ops
            merged: Dict[str, JobMove] = {}
            for m in moves:
                prev = merged.get(m.job_id)
                if prev is None:
                    merged[m.job_id] = m
                else:
                    merged[m.job_id] = dataclasses.replace(
                        m, src_group=prev.src_group,
                        src_shift=prev.src_shift,
                        src_origin=prev.src_origin)
            todo = [m for m in merged.values()
                    if m.src_group != m.dst_group]
            if not todo:
                return
            results = self.router.reassign_jobs(todo)
            with self._lock:
                now = self.router.now()
                # calibrate the migration floors from the realized
                # (reshard-included) costs these moves just measured
                self._ingest_migration_costs()
                for m, moved, err in results:
                    if err is None:
                        self._last_migrated[m.job_id] = now
                        self._log("migrate", job=m.job_id, src=m.src_group,
                                  dst=m.dst_group, bytes=moved, t=now)
                        continue
                    # e.g. a quiesce timeout behind a long-running op: the
                    # job still runs on src. Re-fit it there (freeing the
                    # dst reservation) and keep driving it — a failed
                    # repack move must never kill a healthy job.
                    js = self._jobs.get(m.job_id)
                    self.policy.remove(m.job_id)
                    if (js is not None and js.trace is not None
                            and self.policy.group(m.src_group) is not None):
                        p = self.policy.place_warm(m.job_id, js.trace,
                                                   origin=now,
                                                   groups=[m.src_group])
                        if p is None:
                            self.policy.place_at(m.job_id, js.trace,
                                                 m.src_group, m.src_shift,
                                                 origin=now)
                        js.group_id = m.src_group
                    self._plan_dirty = True
                    self._log("migrate_failed", job=m.job_id,
                              src=m.src_group, dst=m.dst_group,
                              error=str(err), t=now)
                self._retire_idle(now)  # consolidation may drain groups
        finally:
            with self._lock:
                self._migrating.difference_update(
                    m.job_id for m in moves)

    # ------------------------------------------------- capacity adjustment
    def poll(self):
        """Explicit capacity-adjustment tick (the event hooks call this
        implicitly; exposed for external control loops)."""
        with self._lock:
            now = self.router.now()
            self._advance(now)
            moves = self._adjust_capacity(now)
            self._migrating.update(m.job_id for m in moves)
        self._realize(moves)

    def reconcile_now(self, force: bool = True) -> List[JobMove]:
        """Run the periodic reconcile pass immediately; ``force`` skips the
        cadence gate and plans a repack even without measured drift.
        Returns the moves that were decided (already realized)."""
        with self._lock:
            now = self.router.now()
            self._advance(now)
            moves = self._reconcile(now, force=force)
            self._migrating.update(m.job_id for m in moves)
        self._realize(moves)
        return moves

    def _adjust_capacity(self, now: float) -> List[JobMove]:
        """Trigger 3 + §4.4 capacity adjustment: a deep-queued group sheds
        its worst-interfering warm job onto another group; when nothing is
        sheddable a spare group is kept available; with no pressure, idle
        groups retire.

        Process plane: a dead group worker process is respawned first (the
        capacity adjuster IS the plane's supervisor — a crashed group is a
        capacity loss exactly like a failed node). Thread mode returns no
        dead groups, so replay determinism is untouched."""
        respawn = getattr(self.router, "respawn_dead_groups", None)
        if respawn is not None:
            for gid in respawn():
                self._log("respawn_group", group=gid, t=now)
        telem = self.router.group_telemetry()
        deep = sorted(g for g, t in telem.items()
                      if t["queue_depth"] >= self.cfg.spawn_queue_depth)
        if not deep:
            self._retire_idle(now, telem)
            return []
        moves: List[JobMove] = []
        for gid in deep:
            # a job shed earlier in THIS pass is pinned for the rest of it:
            # without this, a second deep group could immediately shed the
            # newcomer back before the move is even realized
            mv = self._shed(now, gid, telem,
                            moved=frozenset(m.job_id for m in moves))
            if mv is not None:
                moves.append(mv)
        if not moves and len(self.policy.groups) < self.cfg.max_groups:
            # nothing sheddable: keep (or create) one spare group so the
            # next warm fit / repack can expand onto it
            spare = [g for g in self.policy.groups
                     if not g.resident and not telem.get(
                         g.group_id, {}).get("deployments")]
            if not spare:
                self._spawn_group(now, reason=f"queue_depth:g{deep[0]}")
        return moves

    def _shed(self, now: float, gid: int, telem: Dict,
              moved: frozenset = frozenset()) -> Optional[JobMove]:
        """Move the worst-interfering warm resident OFF a deep-queued group
        (spawning a spare when nothing else fits)."""
        victim = self.reconciler.pick_shed(
            self.policy.group(gid),
            exclude=frozenset(self._migrating) | self._cooled(now) | moved)
        if victim is None:
            return None
        cold = self._cold_groups()
        others = [x.group_id for x in self.policy.groups
                  if x.group_id != gid and x.group_id not in cold]
        self.policy.remove(victim.job_id)
        placed = None
        if others:
            placed = self.policy.place_warm(victim.job_id, victim.trace,
                                            origin=now, groups=others,
                                            pack=True)
        if placed is None and len(self.policy.groups) < self.cfg.max_groups:
            spare = self._spawn_group(now, reason=f"shed:g{gid}")
            placed = self.policy.place_warm(victim.job_id, victim.trace,
                                            origin=now, groups=[spare])
        if placed is None:
            self.policy.place_at(victim.job_id, victim.trace, gid,
                                 victim.shift, origin=victim.origin,
                                 n_cycles=victim.n_cycles)
            return None
        js = self._jobs.get(victim.job_id)
        if js is not None:
            js.group_id = placed.group_id
        self._plan_dirty = True
        self._log("shed", job=victim.job_id, src=gid, dst=placed.group_id,
                  queue_depth=telem[gid]["queue_depth"], t=now)
        return JobMove(victim.job_id, gid, placed.group_id, placed.shift,
                       origin=placed.origin, src_shift=victim.shift,
                       src_origin=victim.origin, n_cycles=placed.n_cycles)

    def _retire_idle(self, now: float, telem: Optional[Dict] = None):
        """Retire groups with no placed jobs, no deployments, and no queued
        or running work (down to ``min_groups``)."""
        if telem is None:
            telem = self.router.group_telemetry()
        for gid in sorted((g.group_id for g in self.policy.groups),
                          reverse=True):
            if len(self.policy.groups) <= self.cfg.min_groups:
                break
            g = self.policy.group(gid)
            if g is None or g.resident:
                continue
            t = telem.get(gid)
            if t and (t["deployments"] or t["queue_depth"] or t["running"]):
                continue
            try:
                self.router.retire_group(gid)
            except RuntimeError:
                continue               # raced an attach: leave it alone
            self.policy.remove_group(gid)
            self._plan_dirty = True
            self._log("retire_group", group=gid, t=now)
