"""Pallas TPU kernel for the Mamba2 SSD chunk scan.

Grid = (batch, heads, n_chunks); the chunks dim is sequential on TPU, so the
inter-chunk SSM state (head_dim x d_state, f32) is carried in VMEM scratch
across chunk iterations — intra-chunk quadratic work AND the recurrent state
pass happen in ONE fused kernel, with nothing but x/dt/B/C/y touching HBM.

Per (b, h, c) program:
    dA     = dt * A                  (l,)
    L      = exp(segsum(dA))         (l, l) lower-triangular decay
    y_diag = ((C Bᵀ) ∘ L ∘ dt) x     intra-chunk
    y_off  = exp(cumsum dA) * (C Sᵀ) contribution of the carried state
    S      = S * exp(sum dA) + xᵀ (B ∘ dt ∘ decay)   state update

The final state per (b, h) is emitted for prefill seeding. ngroups == 1
(B/C shared across heads), matching the assigned mamba2/zamba2 configs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                s_ref, *, chunk: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, :, 0, :]                       # (l, p)
    dt = dt_ref[0, :, 0].astype(jnp.float32)    # (l,)
    a = a_ref[0]                                # scalar A (negative)
    bmat = b_ref[0, :, 0, :].astype(jnp.float32)  # (l, n)
    cmat = c_ref[0, :, 0, :].astype(jnp.float32)  # (l, n)

    dA = dt * a                                 # (l,)
    dA_cs = jnp.cumsum(dA)                      # (l,)
    # segsum: T[i, j] = sum_{j<k<=i} dA_k, lower-triangular
    seg = dA_cs[:, None] - dA_cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril = ii >= jj
    decay = jnp.where(tril, jnp.exp(seg), 0.0)  # (l, l)

    scores = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (l, l)
    scores = scores * decay * dt[None, :]
    # Accumulate the whole y path in f32: downcasting `scores` to bf16 here
    # loses ~2^-8 relative on each large intermediate term, and the intra-
    # chunk + carried-state contributions cancel, so small outputs absorb
    # absolute error far above the final-cast quantisation (observed 0.18
    # max-abs on |y|~0.03 elements at s=96, chunk=32). The ONLY bf16
    # rounding left is the single y_ref store below.
    y = jax.lax.dot_general(
        scores, x.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # (l, p)

    # off-diagonal: contribution of the incoming state S (p, n)
    s_in = s_ref[...]                           # (p, n) f32
    c_proj = jax.lax.dot_general(
        cmat, s_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (l, p)
    y = y + jnp.exp(dA_cs)[:, None] * c_proj
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update
    chunk_decay = jnp.exp(dA_cs[-1])
    w = jnp.exp(dA_cs[-1] - dA_cs) * dt         # (l,)
    upd = jax.lax.dot_general(
        x.astype(jnp.float32), bmat * w[:, None],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # (p, n)
    s_ref[...] = s_in * chunk_decay + upd

    @pl.when(ic == nc - 1)
    def _emit():
        state_out_ref[0, 0, :, :] = s_ref[...]


def ssd_chunk_scan(x, dt, A, B, C, *, chunk: int = 256,
                   interpret: bool = False):
    """x: (b, s, h, p); dt: (b, s, h) post-softplus; A: (h,) negative;
    B, C: (b, s, 1, n) (ngroups=1). Returns (y (b,s,h,p), state (b,h,p,n))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nc=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), B, C)
    return y, state
