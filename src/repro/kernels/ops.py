"""Jit'd wrappers around the Pallas kernels.

Interpret mode is selected automatically off-TPU (the CPU container runs the
kernel bodies in Python for correctness validation); on TPU the compiled
kernels run natively. Wrappers handle padding to block multiples and the
GQA repeat for the flash path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as dec
from repro.kernels import flash_attention as fa
from repro.kernels import ssd as ssd_k


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128):
    """q: (B,S,H,D); k,v: (B,T,K,D) with K | H (GQA repeat done here)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    if kh != h:
        k = jnp.repeat(k, h // kh, axis=2)
        v = jnp.repeat(v, h // kh, axis=2)
    bq = min(block_q, s) if s % min(block_q, s) == 0 else block_q
    bk = min(block_k, t) if t % min(block_k, t) == 0 else block_k
    pad_q = (-s) % bq
    pad_k = (-t) % bk
    if pad_q:
        q = jnp.pad(q, [(0, 0), (0, pad_q), (0, 0), (0, 0)])
    if pad_k:
        k = jnp.pad(k, [(0, 0), (0, pad_k), (0, 0), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, pad_k), (0, 0), (0, 0)])
        # padded kv slots must be masked: window/causal handle the tail only
        # if padding stays beyond every query position, which holds since
        # pads sit at kv positions >= t > any valid causal query position.
        assert causal or pad_k == 0, "non-causal padding needs a kv mask"
    out = fa.flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=bq, block_k=bk, interpret=_interpret())
    return out[:, :s]


def decode_attention(q, k_cache, v_cache, pos, *,
                     scale: Optional[float] = None, block_k: int = 256):
    t = k_cache.shape[1]
    bk = min(block_k, t)
    if t % bk:
        pad = (-t) % bk
        k_cache = jnp.pad(k_cache, [(0, 0), (0, pad), (0, 0), (0, 0)])
        v_cache = jnp.pad(v_cache, [(0, 0), (0, pad), (0, 0), (0, 0)])
    return dec.decode_attention(q, k_cache, v_cache, pos, scale=scale,
                                block_k=bk, interpret=_interpret())


def ssd(x, dt, A, B, C, *, chunk: int = 256):
    """SSD chunk scan. Shapes as repro.models.mamba2.ssd_chunked with
    ngroups == 1."""
    s = x.shape[1]
    ck = min(chunk, s)
    pad = (-s) % ck
    if pad:
        x = jnp.pad(x, [(0, 0), (0, pad), (0, 0), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
        B = jnp.pad(B, [(0, 0), (0, pad), (0, 0), (0, 0)])
        C = jnp.pad(C, [(0, 0), (0, pad), (0, 0), (0, 0)])
    y, state = ssd_k.ssd_chunk_scan(x, dt, A, B, C, chunk=ck,
                                    interpret=_interpret())
    return y[:, :s], state
