"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None):
    """Dense attention. q: (B,S,H,D); k,v: (B,T,H,D) (pre-repeated GQA)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    scale = d ** -0.5 if scale is None else scale
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
    return out


def ref_decode_attention(q, k_cache, v_cache, pos, *,
                         scale: Optional[float] = None):
    """q: (B,H,D); caches (B,T,K,D); attend to positions <= pos."""
    b, h, d = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    k = jnp.repeat(k_cache, g, axis=2)
    v = jnp.repeat(v_cache, g, axis=2)
    scale = d ** -0.5 if scale is None else scale
    scores = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.arange(t)[None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bht,bthd->bhd", probs.astype(v.dtype), v)


def ref_ssd(x, dt, A, B, C, *, chunk: int = 256):
    """Delegates to the model-level chunked oracle (itself validated against
    the naive sequential recurrence in tests)."""
    from repro.models.mamba2 import ssd_chunked
    return ssd_chunked(x, dt, A, B, C, chunk)


def ref_ssd_naive(x, dt, A, B, C):
    """O(s) sequential recurrence — the ground-truth semantics."""
    from repro.models.mamba2 import ssd_decode
    b, s, h, p = x.shape
    n = B.shape[-1]
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ssd_decode(state, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    return jnp.stack(ys, 1), state
