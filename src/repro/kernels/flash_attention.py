"""Pallas TPU flash-attention forward kernel.

Online-softmax attention with explicit BlockSpec VMEM tiling: the (S, T)
score matrix never leaves VMEM. Grid = (batch, heads, q_blocks, kv_blocks);
TPU grids execute the trailing dim sequentially, so the running max / sum /
accumulator live in VMEM scratch across kv iterations. Causal and
sliding-window blocks that are fully masked are skipped with ``pl.when``
(compute predication) — the causal upper triangle costs nothing, unlike the
XLA fallback path.

Supports: causal, sliding window, logit softcap (gemma2), arbitrary scale.
GQA is handled by the ops.py wrapper (KV repeated to full heads — the
repeat is free on TPU: it lowers to re-reads of the same HBM tiles).

Validated in interpret mode against ref.ref_attention (tests/test_kernels).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  softcap: Optional[float], bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    k_start = ik * bk
    # block-level skip: fully-masked (above diagonal / outside window)
    relevant = jnp.asarray(True)
    if causal:
        relevant &= k_start <= q_start + bq - 1
    if window:
        relevant &= k_start + bk - 1 > q_start - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, :, 0, :]                      # (bq, d)
        k = k_ref[0, :, 0, :]                      # (bk, d)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _write():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: (B, S, H, D); k, v: (B, T, H, D) (KV pre-repeated for GQA)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    assert k.shape == (b, t, h, d) and v.shape == (b, t, h, d)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    scale = d ** -0.5 if scale is None else scale
    nq, nk = s // block_q, t // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=block_q, bk=block_k, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda ib, ih, iq, ik: (ib, ik, ih, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda ib, ih, iq, ik: (ib, ik, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
