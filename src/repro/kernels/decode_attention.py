"""Pallas TPU single-token decode attention over a KV cache.

Grid = (batch, kv_heads, kv_blocks): each program attends the G = H/K query
heads of one KV head against one cache block, carrying the online-softmax
state in VMEM scratch across the (sequential) kv_blocks dim. The GQA group
is processed natively — the cache is read once, NOT repeated, which is the
point of GQA at decode time (HBM-bandwidth-bound).

The current decode position arrives as a scalar-prefetch operand (SMEM) so
cache slots beyond ``pos`` are masked without host-side slicing.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, bk: int, nk: int, g: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0]
    k_start = ik * bk

    @pl.when(k_start <= pos)
    def _compute():
        q = q_ref[0, 0, :, :]                     # (g, d) query-head group
        k = k_ref[0, :, 0, :]                     # (bk, d)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (g, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _write():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, pos, *,
                     scale: Optional[float] = None, block_k: int = 256,
                     interpret: bool = False):
    """q: (B, H, D) one new token's queries; k/v_cache: (B, T, K, D);
    pos: scalar int32 (attend to cache[: pos+1]). Returns (B, H, D)."""
    b, h, d = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    assert h % kh == 0
    g = h // kh
    assert t % block_k == 0, (t, block_k)
    scale = d ** -0.5 if scale is None else scale
    nk = t // block_k
    qg = q.reshape(b, kh, g, d)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, scale=scale, bk=block_k,
                               nk=nk, g=g)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, kh, nk),
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik, pos: (ib, ih, 0, 0)),
                pl.BlockSpec((1, block_k, 1, d),
                             lambda ib, ih, ik, pos: (ib, ik, ih, 0)),
                pl.BlockSpec((1, block_k, 1, d),
                             lambda ib, ih, ik, pos: (ib, ik, ih, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda ib, ih, ik, pos: (ib, ih, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, d), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        interpret=interpret,
    )(pos_arr, qg, k_cache, v_cache)
    return out.reshape(b, h, d)
