"""arctic-480b — MoE 128 experts top-2 with a parallel dense residual MLP.
[hf:Snowflake/snowflake-arctic-base]
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,                  # dense residual branch width
    vocab_size=32_000,
    num_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base; hf",
)
