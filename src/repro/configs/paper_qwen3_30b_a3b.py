"""Qwen3-30B-A3B — the paper's mid-size MoE evaluation model (Tab. 1)."""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="paper-qwen3-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151_936,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B",
)
