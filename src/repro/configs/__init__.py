"""Architecture configs: one module per assigned architecture.

Every config is an immutable :class:`ModelConfig`. ``get_config(name)``
resolves the registry; ``SHAPES`` defines the assigned input-shape set and
``shape_applicable`` encodes the per-family skip policy (documented in
DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

__all__ = [
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
    "ARCH_IDS",
    "get_config",
    "list_configs",
    "shape_applicable",
]


@dataclass(frozen=True)
class ModelConfig:
    """Unified model configuration for every supported family.

    Families: ``dense`` | ``moe`` | ``ssm`` | ``hybrid`` | ``audio`` | ``vlm``.
    Fields irrelevant to a family stay at their zero/None defaults.
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    vocab_size: int
    # ---- attention ----
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                      # 0 -> d_model // num_heads
    d_ff: int = 0
    qk_norm: bool = False                  # qwen3
    qkv_bias: bool = False                 # qwen2
    attn_logit_softcap: Optional[float] = None   # gemma2
    final_logit_softcap: Optional[float] = None  # gemma2
    sliding_window: int = 0                # gemma2 local layers (0 = none)
    local_global_period: int = 0           # every Nth layer is global (gemma2: 2)
    attn_scale: Optional[float] = None     # override 1/sqrt(head_dim)
    post_norms: bool = False               # gemma2 post-attn/post-mlp norms
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: str = "silu"                      # "silu" | "gelu"
    norm: str = "rmsnorm"                  # "rmsnorm" | "layernorm"
    tie_embeddings: bool = False
    # ---- MoE ----
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False           # arctic: dense FFN in parallel w/ MoE
    capacity_factor: float = 1.25
    # ---- SSM (mamba2 / SSD) ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # ---- hybrid (zamba2) ----
    attn_period: int = 0                   # one shared-attn block per N blocks
    # ---- encoder-decoder (whisper) ----
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0                   # stubbed frame/patch embedding length
    # ---- vision-language (llama-3.2-vision) ----
    cross_attn_period: int = 0             # every Nth layer is a cross-attn layer
    vision_seq: int = 0                    # stubbed patch-embedding length
    # ---- numerics ----
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # ---- substrate knobs (perf hillclimb touches these) ----
    remat: str = "full"                    # "full" | "none" | "dots"
    scan_layers: bool = True
    attn_impl: str = "xla"                 # "xla" | "pallas"
    attn_q_chunk: int = 256                # query-block size for chunked attn
    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned (input-shape) cell: lowers train_step or serve_step."""

    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# Assigned architecture ids (order matches the task brief).
ARCH_IDS: Tuple[str, ...] = (
    "mamba2-2.7b",
    "whisper-large-v3",
    "gemma2-27b",
    "qwen3-4b",
    "deepseek-coder-33b",
    "qwen2-0.5b",
    "zamba2-7b",
    "llama-3.2-vision-90b",
    "arctic-480b",
    "granite-moe-3b-a800m",
)

_MODULES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "whisper-large-v3": "whisper_large_v3",
    "gemma2-27b": "gemma2_27b",
    "qwen3-4b": "qwen3_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen2-0.5b": "qwen2_0p5b",
    "zamba2-7b": "zamba2_7b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "arctic-480b": "arctic_480b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    # paper Table-1 models (used by the paper-replication benchmarks)
    "paper-qwen2.5-7b": "paper_qwen25_7b",
    "paper-qwen3-30b-a3b": "paper_qwen3_30b_a3b",
    "paper-qwen3-235b-a22b": "paper_qwen3_235b_a22b",
}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def list_configs() -> Tuple[str, ...]:
    return tuple(_MODULES)


# Families with sub-quadratic sequence mixing run long_500k; pure
# full-attention families skip it (DESIGN.md §Arch-applicability).
_SUBQUADRATIC = {"ssm", "hybrid"}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Return (applicable, reason-if-not)."""
    if shape.name == "long_500k" and cfg.family not in _SUBQUADRATIC:
        return False, "full-attention arch: 500k decode skipped per policy"
    return True, ""


def reduced_config(name: str) -> ModelConfig:
    """Same-family reduced config for CPU smoke tests: tiny widths/depths,
    few experts, small vocab — preserving every structural feature
    (GQA-ness, softcaps, qk-norm, local/global pattern, hybrid periods...)."""
    cfg = get_config(name)
    layers = {
        "dense": 4, "moe": 4, "ssm": 3, "audio": 2,
        "hybrid": 2 * max(cfg.attn_period, 1) + 1,
        "vlm": 2 * max(cfg.cross_attn_period, 1),
    }[cfg.family]
    kw = dict(
        num_layers=layers,
        d_model=64,
        vocab_size=128,
        head_dim=16,
        attn_scale=None,
        ssm_state=16,
        ssm_head_dim=8,
        ssm_chunk=8,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=12 if cfg.encoder_seq else 0,
        vision_seq=9 if cfg.vision_seq else 0,
        sliding_window=8 if cfg.sliding_window else 0,
        attn_q_chunk=32,
    )
    if cfg.num_heads:
        kw["num_heads"] = 4
        kw["num_kv_heads"] = 2 if cfg.num_kv_heads < cfg.num_heads else 4
    if cfg.d_ff:
        kw["d_ff"] = 128
    if cfg.num_experts:
        kw["num_experts"] = 8
        kw["experts_per_token"] = min(cfg.experts_per_token, 4)
        kw["moe_d_ff"] = 48
    return cfg.replace(**kw)
