"""whisper-large-v3 — encoder-decoder audio backbone. Conv/mel frontend is a
STUB: ``input_specs()`` provides precomputed frame embeddings. [arXiv:2212.04356]
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,              # decoder layers
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,            # MHA
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    is_encoder_decoder=True,
    encoder_seq=1500,           # 30 s of audio after conv frontend (stubbed)
    act="gelu",
    norm="layernorm",
    norm_eps=1e-5,
    tie_embeddings=True,
    rope_theta=0.0,             # whisper uses learned/sinusoidal positions, no RoPE
    source="arXiv:2212.04356; unverified",
)
