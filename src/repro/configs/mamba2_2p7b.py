"""mamba2-2.7b — SSD (state-space duality), attention-free. [arXiv:2405.21060]"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    vocab_size=50_280,
    d_ff=0,                 # attention-free, no FFN blocks: mamba2 mixer only
    ssm_state=128,
    ssm_expand=2,           # d_inner = 5120
    ssm_head_dim=64,        # 80 SSD heads
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
