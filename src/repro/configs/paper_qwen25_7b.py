"""Qwen2.5-7B-Instruct — the paper's dense evaluation model (Tab. 1)."""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="paper-qwen2.5-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-7B-Instruct",
)
