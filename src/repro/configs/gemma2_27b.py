"""gemma2-27b — dense, local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    sliding_window=4096,
    local_global_period=2,        # every 2nd layer global, others local
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    attn_scale=(4608 // 32) ** -0.5,   # query_pre_attn_scalar = d_model/num_heads
    post_norms=True,
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2408.00118; hf",
)
