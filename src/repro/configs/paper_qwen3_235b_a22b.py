"""Qwen3-235B-A22B — the paper's large MoE evaluation model (Tab. 1)."""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="paper-qwen3-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151_936,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-235B-A22B",
)
