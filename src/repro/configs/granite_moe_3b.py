"""granite-moe-3b-a800m — MoE 40 experts top-8, narrow experts.
[hf:ibm-granite/granite-3.0-1b-a400m-base family]
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=0,                     # no dense branch
    vocab_size=49_155,
    num_experts=40,
    experts_per_token=8,
    moe_d_ff=512,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
