"""zamba2-7b — hybrid: Mamba2 backbone + one SHARED attention block applied
periodically (weights reused at every application). [arXiv:2411.15242]
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,              # total blocks; every `attn_period`-th is the shared attn block
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,            # MHA in the shared block
    head_dim=112,
    d_ff=14_336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,               # d_inner = 7168
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    attn_period=7,              # one shared attn block per 7 blocks (11 applications)
    source="arXiv:2411.15242; unverified",
)
