"""llama-3.2-vision-90b — dense decoder with cross-attention image layers.
Vision tower is a STUB: ``input_specs()`` provides precomputed patch embeddings
already projected to d_model. [hf:meta-llama/Llama-3.2-11B-Vision]
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    cross_attn_period=5,        # every 5th layer is a cross-attn image layer
    vision_seq=1601,            # 1 tile x (40x40 patches + cls), stubbed
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
