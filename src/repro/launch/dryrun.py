import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production meshes and extract roofline terms from the compiled
artifact. MUST be run as its own process (the device-count flag above is
locked in at first jax init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

Artifacts: benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json with
memory analysis, HLO flops/bytes, per-collective byte totals, and the
collective op schedule — consumed by benchmarks.roofline and EXPERIMENTS.md.
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, ShapeSpec, get_config, shape_applicable
from repro.launch import hlo_cost
from repro.launch.mesh import HW, make_production_mesh
from repro.models import sharding as shd
from repro.models.layers import Ctx
from repro.models.registry import build_model
from repro.rl import grpo
from repro.train import optimizer as opt, train_state as ts

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

# Models whose optimizer state cannot fit on a single pod at f32 moments:
# the paper's answer is ZeRO-offload (host-resident optimizer, §6.2), so
# their train cells lower the grad-step (fwd+bwd+reduce-scatter) and the
# optimizer update runs host-side via the StateManager (§4.5.4).
HOST_OPTIM = {"arctic-480b", "paper-qwen3-235b-a22b"}

# Sharding mode per arch: small models keep the paper-faithful ZeRO-2 layout
# (params TP-only, replicated over data); large models need FSDP+TP to fit
# (analogue of the paper's heavy PP/TP splits in Tab. 1).
def default_rules_name(arch: str, shape: ShapeSpec) -> str:
    if shape.name == "long_500k":
        return "long"
    cfg = get_config(arch)
    from repro.models.registry import build_model as _bm
    big = _bm(cfg).param_count() * 2 > 8e9  # >8 GB of bf16 params
    # MoE always gets FSDP: the dispatch buffers need the embed/data shard
    return "fsdp_tp" if (big or cfg.num_experts) else "tp"


def default_grad_accum(arch: str, shape: ShapeSpec, mesh) -> int:
    """Pick the microbatch count so per-device saved activations stay ~<6GB."""
    if shape.kind != "train":
        return 1
    cfg = get_config(arch)
    data_shards = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            data_shards *= mesh.shape[ax]
    b_local = max(1, shape.global_batch // data_shards)
    layers = cfg.num_layers + cfg.encoder_layers
    carry_bytes = layers * b_local * shape.seq_len * cfg.d_model * 2 * 2.5
    accum = 1
    while carry_bytes / accum > 6e9 and accum < b_local:
        accum *= 2
    return accum


def _collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result-shape bytes of every collective op in the compiled HLO."""
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    widths = {"f64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
              "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
              "u64": 8, "c64": 8, "f8e4m3fn": 1, "f8e5m2": 1}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    totals = {k: 0 for k in kinds}
    counts = {k: 0 for k in kinds}
    ops = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped.startswith("%") and " = " not in stripped:
            continue
        for kind in kinds:
            # match the op use, not substrings of other ops
            marker = f" {kind}("
            alt = f" {kind}-start("
            idx = stripped.find(marker)
            if idx < 0:
                idx = stripped.find(alt)
            if idx < 0:
                continue
            lhs = stripped[:idx]
            if "=" not in lhs:
                continue
            result = lhs.split("=", 1)[1]
            nbytes = 0
            for dt, dims in shape_re.findall(result):
                if dt not in widths:
                    continue
                n = 1
                if dims:
                    for d in dims.split(","):
                        n *= int(d)
                nbytes += n * widths[dt]
            totals[kind] += nbytes
            counts[kind] += 1
            ops.append({"kind": kind, "bytes": nbytes})
            break
    return {"bytes_by_kind": totals, "counts": counts,
            "total_bytes": sum(totals.values()), "ops": ops[:400]}


def _memory_stats(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _cost_stats(compiled) -> Dict[str, Any]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def build_cell(arch: str, shape: ShapeSpec, mesh, rules_name: str,
               host_optim: Optional[bool] = None,
               grad_accum: Optional[int] = None,
               overrides: Optional[dict] = None):
    """Returns (fn, args_abstract, in_shardings, out_shardings, donate)."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    model = build_model(cfg)
    rules = shd.named_rules(rules_name)
    ctx = Ctx(mesh, rules)
    if host_optim is None:
        host_optim = arch in HOST_OPTIM
    if grad_accum is None:
        grad_accum = default_grad_accum(arch, shape, mesh)

    batch_specs = model.input_specs(shape)
    batch_abs = {k: v.sds for k, v in batch_specs.items()}
    batch_shd = {
        k: NamedSharding(mesh, shd.resolve(v.axes, mesh, rules, v.sds.shape))
        for k, v in batch_specs.items()
    }
    param_shd = shd.tree_shardings(model.logical_axes(), mesh, rules,
                                   model.abstract_params())

    if shape.kind == "train":
        if host_optim:
            # ZeRO-offload: lower fwd+bwd; grads reduce-scattered over data
            def grad_step(params, batch):
                return grpo.compute_grads(params, model, batch,
                                          grpo.GRPOConfig(), ctx, grad_accum)

            ap = model.abstract_params()
            pspecs = shd.tree_partition_specs(model.logical_axes(), mesh,
                                              rules, ap)
            gspecs = jax.tree.map(
                lambda ps, a: opt.zero_moment_spec(ps, a.shape, mesh),
                pspecs, ap, is_leaf=lambda x: isinstance(x, P))
            gshd = jax.tree.map(lambda p: NamedSharding(mesh, p), gspecs,
                                is_leaf=lambda x: isinstance(x, P))
            out_shd = (gshd, None)
            return (grad_step, (model.abstract_params(), batch_abs),
                    (param_shd, batch_shd), out_shd, (0,))

        step = grpo.make_update_actor(model, ctx=ctx, grad_accum=grad_accum)
        state_abs = ts.abstract(model)
        state_shd = ts.shardings(model, mesh, rules, zero=True)
        return (step, (state_abs, batch_abs), (state_shd, batch_shd),
                (state_shd, None), (0,))

    if shape.kind == "prefill":
        step = grpo.make_prefill(model, ctx=ctx)
        cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
        from repro.models import common
        cache_axes = common.logical_axes(
            model.cache_specs(shape.global_batch, shape.seq_len))
        # prefill OUTPUTS the cache seq-sharded (cheap per-layer slicing of
        # the K/V stack): forcing the decode layout (cache_hd fallback) here
        # makes GSPMD reshard inside the scan — the prefill->decode reshard
        # belongs between the two calls, paid once
        def _prefill_ax(ax):
            if ax == "cache_hd":
                return None
            if ax == "cache_seq":
                return "cache_seq_out"
            return ax
        cache_axes = jax.tree.map(
            lambda a: tuple(_prefill_ax(ax) for ax in a), cache_axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                v is None or isinstance(v, str) for v in x))
        cache_shd = shd.tree_shardings(cache_axes, mesh, rules, cache_abs)
        logits_shape = (shape.global_batch, 1, cfg.vocab_size)
        logits_shd = NamedSharding(
            mesh, shd.resolve(("batch", None, "vocab"), mesh, rules,
                              shape=logits_shape))
        return (step, (model.abstract_params(), batch_abs),
                (param_shd, batch_shd), (logits_shd, cache_shd), ())

    # decode
    step = grpo.make_decode(model, ctx=ctx)
    from repro.models import common
    cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
    cache_abs = common.abstract_params(cache_specs)
    cache_axes = common.logical_axes(cache_specs)
    cache_shd = shd.tree_shardings(cache_axes, mesh, rules, cache_abs)
    logits_shape = (shape.global_batch, 1, cfg.vocab_size)
    logits_shd = NamedSharding(
        mesh, shd.resolve(("cache_batch", None, "vocab"), mesh, rules,
                          shape=logits_shape))
    return (step, (model.abstract_params(), cache_abs, batch_abs),
            (param_shd, cache_shd, batch_shd), (logits_shd, cache_shd), (1,))


def pad_heads_overrides(arch: str, mesh_model: int = 16) -> dict:
    """Perf variant: pad query heads up to a mesh multiple so attention
    shards over the model axis (extra heads are wasted compute — 14 % for
    deepseek's 56->64 — but beat 16x replication). Semantically the padded
    wq/wo rows would be zero-initialised."""
    cfg = get_config(arch)
    h = cfg.num_heads
    padded = -(-h // mesh_model) * mesh_model
    return {"num_heads": padded} if padded != h else {}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules_name: Optional[str] = None,
             host_optim: Optional[bool] = None,
             verbose: bool = True,
             overrides: Optional[dict] = None) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind,
    }
    if not ok:
        result["status"] = "SKIP"
        result["reason"] = reason
        return result
    rules_name = rules_name or default_rules_name(arch, shape)
    result["rules"] = rules_name
    mesh = make_production_mesh(multi_pod=multi_pod)
    result["grad_accum"] = default_grad_accum(arch, shape, mesh)
    result["host_optim"] = arch in HOST_OPTIM and shape.kind == "train"
    n_chips = mesh.size
    t0 = time.time()
    fn, args_abs, in_shd, out_shd, donate = build_cell(
        arch, shape, mesh, rules_name, host_optim, overrides=overrides)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_shd, out_shardings=out_shd,
                         donate_argnums=donate)
        lowered = jitted.lower(*args_abs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = _memory_stats(compiled)
    cost = _cost_stats(compiled)
    # trip-count-weighted HLO analysis (xla cost_analysis counts scan bodies
    # once — see repro.launch.hlo_cost)
    hc = hlo_cost.analyze(compiled.as_text())

    model = build_model(cfg)
    n_params = model.param_count()
    n_active = model.active_param_count()
    flops = hc["flops"]                      # per-device, trip-weighted
    hlo_flops_total = flops * n_chips
    bytes_acc = hc["traffic_bytes"]
    coll_bytes = hc["collective_bytes"]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = {"train": 6, "prefill": 2, "decode": 2}[shape.kind]
    model_flops = mult * n_active * tokens

    compute_s = flops / HW["peak_flops_bf16"]
    memory_s = bytes_acc / HW["hbm_bw"]
    collective_s = coll_bytes / HW["ici_bw"]
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]

    result.update({
        "status": "OK",
        "n_chips": n_chips,
        "params": n_params,
        "active_params": n_active,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "xla_cost_analysis": cost,
        "collectives": {
            "bytes_by_kind": {k: hc.get(f"bytes_{k}", 0.0)
                              for k in hlo_cost.COLLECTIVES},
            "counts": hc.get("collective_counts", {}),
            "total_bytes": coll_bytes,
        },
        "roofline": {
            "hlo_flops_per_device": flops,
            "hlo_bytes_per_device": bytes_acc,
            "collective_bytes_per_device": coll_bytes,
            "compute_term_s": compute_s,
            "memory_term_s": memory_s,
            "collective_term_s": collective_s,
            "dominant": dominant,
            "model_flops_total": model_flops,
            "hlo_flops_total": hlo_flops_total,
            "useful_flops_ratio": (model_flops / hlo_flops_total
                                   if hlo_flops_total else 0.0),
        },
    })
    if verbose:
        per_dev = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
                   + mem.get("output_bytes", 0) - mem.get("alias_bytes", 0))
        print(f"[{mesh_name}] {arch} x {shape_name} ({rules_name}): "
              f"compile {t_compile:.1f}s | "
              f"mem/dev {per_dev/1e9:.2f} GB | "
              f"flops/dev {flops:.3e} | coll {coll_bytes/1e9:.3f} GB "
              f"| dominant={dominant} | useful={100*result['roofline']['useful_flops_ratio']:.1f}%")
        print("  memory_analysis:", {k: f"{v/1e9:.3f}GB" for k, v in mem.items()
                                     if isinstance(v, int)})
        ck = {k: f"{v/1e6:.1f}MB"
              for k, v in result["collectives"]["bytes_by_kind"].items() if v}
        print("  collectives:", ck or "none")
    return result


def artifact_path(arch: str, shape_name: str, multi_pod: bool) -> str:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    d = os.path.abspath(os.path.join(ART_DIR, mesh_name))
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape_name}.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rules", default=None, choices=[None, "tp", "fsdp_tp", "long"])
    ap.add_argument("--force", action="store_true", help="ignore cached artifacts")
    args = ap.parse_args(argv)

    if args.all:
        archs = list(ARCH_IDS)
        shapes = list(SHAPES)
    else:
        archs = [args.arch]
        shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for multi_pod in meshes:
        for arch in archs:
            for shape_name in shapes:
                path = artifact_path(arch, shape_name, multi_pod)
                if os.path.exists(path) and not args.force and args.rules is None:
                    print(f"cached: {path}")
                    continue
                try:
                    res = run_cell(arch, shape_name, multi_pod, args.rules)
                except Exception as e:
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": "multipod_2x16x16" if multi_pod else "pod_16x16",
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
                    failures.append((arch, shape_name, multi_pod))
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
