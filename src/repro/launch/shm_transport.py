"""Zero-copy shared-memory array transport for the process plane.

The PR 9 process plane ships every execute result, ``sync_weights``
payload, and migration entry as pickled host arrays through a
``multiprocessing`` pipe: pickle copies the array, the kernel copies the
frame twice more (64 KiB pipe chunks), and unpickling copies it again —
four traversals of every byte, for payloads that are routinely hundreds of
MiB of model state. This module moves the BYTES out of the pipe: large
arrays are written once into a pooled ``multiprocessing.shared_memory``
segment and the pipe carries only :class:`ShmRef` descriptors
``(segment, offset, dtype, shape)``; the receiver maps the segment and
reads — or ``jax.device_put``\\ s — directly from the view, with no
intermediate pickle buffer.

Lifecycle is the hard part, and most of this module:

- **Pooling** (:class:`SegmentPool`): the writer packs all of one
  message's large arrays into a single segment sized to the next power of
  two, and a released segment returns to a free list instead of being
  unlinked — a steady-state weight-sync loop reuses the same one or two
  segments forever instead of churning ``shm_open``/``unlink``. The free
  list is bounded by a high-water mark (``max_pool_bytes`` /
  ``max_free_segments``); excess segments are unlinked largest-first.
- **Refcounts + release acks**: a segment is ``busy`` from ``encode``
  until the consumer acks it. For parent→child requests the child's reply
  IS the ack (handlers consume — block on ``device_put`` — before
  replying); for child→parent replies the parent sends an explicit
  fire-and-forget ``shm_release`` frame after decoding. Relayed payloads
  (cross-child sync / migrate) are released by the parent only after the
  *destination* child's reply.
- **Crash-safe reaping**: every segment name is prefixed with the owning
  (parent pid, group, incarnation) — ``pxl{pid}g{gid}s{n}{side}-{seq}`` —
  so when a child dies mid-transfer the parent can unlink everything the
  incarnation ever created by scanning ``/dev/shm`` for the prefix
  (:func:`reap_prefix`; falls back to the tracked-name set where there is
  no scannable shm directory). A week-long plane never leaks ``/dev/shm``.
- **Fallback**: arrays below ``threshold`` (or when ``/dev/shm`` is
  unavailable / ``PLEXRL_SHM=0``) ride the pickle path unchanged. The
  default threshold is MEASURED, not guessed: ``benchmarks/
  transport_bench.py`` sweeps payload sizes and the pickle-vs-shm
  crossover lands between 32 and 128 KiB across runs on one host;
  256 KiB keeps a safety margin for small-array-heavy trees where
  descriptor overhead bites.

Module-level imports are stdlib-only: spawned group processes import this
(via ``launch.proc_plane``) BEFORE applying their device environment, so
neither jax nor numpy may load here. numpy is imported lazily, only once
actual arrays cross the transport.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

logger = logging.getLogger(__name__)

# Pickle-vs-shm crossover measured by benchmarks/transport_bench.py on a
# one-host sync relay (BENCH_PR10.json, transport/crossover_kib; 32-128
# KiB across runs): shm wins from ~128 KiB up at worst (3.5x by 1 MiB,
# ~6x by 256 MiB). The default sits an octave above the worst measured
# crossover for headroom — descriptor/ack overhead bites harder on trees
# of many borderline arrays than a missed 2x win on one of them.
DEFAULT_THRESHOLD = 256 << 10
DEFAULT_POOL_BYTES = 1 << 30          # high-water mark per pool (free bytes)
DEFAULT_FREE_SEGMENTS = 4             # free-list length cap
_ALIGN = 64                           # array offsets are cache-line aligned
_MIN_SEGMENT = 1 << 20                # round tiny packs up for better reuse
SHM_DIR = "/dev/shm"


def _round_segment(nbytes: int) -> int:
    """Next power of two, floored at ``_MIN_SEGMENT`` — bounded (2x) internal
    waste in exchange for a free list that actually gets hits."""
    size = _MIN_SEGMENT
    while size < nbytes:
        size <<= 1
    return size


def _untrack(shm) -> None:
    """Opt a segment out of ``resource_tracker`` right after create or
    attach. The tracker registers on attach as well as create (bpo-39959),
    so an attacher's exit would unlink segments the creator still owns —
    fatal for a pool whose names outlive any one mapping. Lifecycle here
    is explicit instead: pools unlink on destroy and parents reap dead
    children by prefix. Registration is a set-add and unregistration a
    set-remove that makes the tracker process spew ``KeyError`` tracebacks
    when unbalanced, so the rule is: every create/attach is untracked
    immediately, and :func:`_destroy_segment` re-registers just before
    ``unlink()`` (whose internals unregister again)."""
    try:
        from multiprocessing import resource_tracker
        resource_tracker.unregister(shm._name, "shared_memory")
    except Exception:  # noqa: BLE001 - best-effort on every platform
        pass


def shm_available() -> bool:
    """True when pooled shared-memory transport can run here: the stdlib
    module works, a segment can actually be created (a container without
    ``/dev/shm`` raises), and ``PLEXRL_SHM`` does not force it off."""
    if os.environ.get("PLEXRL_SHM", "").lower() in ("0", "off", "false"):
        return False
    try:
        from multiprocessing import shared_memory
        probe = shared_memory.SharedMemory(create=True, size=_ALIGN)
        probe.close()
        probe.unlink()
        return True
    except Exception:  # noqa: BLE001 - any failure means "use pickle"
        return False


# --------------------------------------------------------------- descriptor
@dataclasses.dataclass(frozen=True)
class ShmRef:
    """What the pipe carries instead of an array: where the bytes live.

    ``dtype`` is the numpy dtype string; bfloat16 (no portable numpy
    string) travels as ``"bfloat16"`` with the bytes stored as uint16."""
    segment: str
    offset: int
    shape: Tuple[int, ...]
    dtype: str
    nbytes: int


def _wire_dtype(arr) -> Tuple[str, Any]:
    """(descriptor dtype string, array view safe to memcpy). bf16 has no
    numpy-native string form, so it rides as a uint16 view."""
    import numpy as np
    if arr.dtype.name == "bfloat16":
        return "bfloat16", arr.view(np.uint16)
    return arr.dtype.str, arr


def _view_dtype(dtype: str):
    import numpy as np
    if dtype == "bfloat16":
        return np.uint16
    return np.dtype(dtype)


# ---------------------------------------------------------------- free pool
class SegmentPool:
    """Writer-side pool of named shared-memory segments.

    ``alloc`` prefers the smallest free segment that fits; a miss creates a
    new segment named ``{prefix}-{seq}`` (monotonic seq: names are never
    reused, so a stale reader-side attachment can never alias new data).
    ``release`` returns segments to the free list, trimming it back under
    the high-water mark largest-first. Thread-safe — the parent side is
    driven by per-group dispatch threads."""

    def __init__(self, prefix: str,
                 max_pool_bytes: int = DEFAULT_POOL_BYTES,
                 max_free_segments: int = DEFAULT_FREE_SEGMENTS):
        self.prefix = prefix
        self.max_pool_bytes = max_pool_bytes
        self.max_free_segments = max_free_segments
        self._seq = 0
        self._free: List[Any] = []         # SharedMemory, sorted by size
        self._busy: Dict[str, Any] = {}    # name -> SharedMemory
        self._lock = threading.Lock()
        self.created = 0                   # segments ever created (stats)
        self.reused = 0                    # allocs served from the free list

    # ------------------------------------------------------------- alloc
    def alloc(self, nbytes: int):
        """A segment with capacity >= nbytes, marked busy until released."""
        from multiprocessing import shared_memory
        with self._lock:
            fit = [s for s in self._free if s.size >= nbytes]
            if fit:
                shm = min(fit, key=lambda s: s.size)
                self._free.remove(shm)
                self._busy[shm.name] = shm
                self.reused += 1
                return shm
            self._seq += 1
            name = f"{self.prefix}-{self._seq}"
        shm = shared_memory.SharedMemory(
            name=name, create=True, size=_round_segment(nbytes))
        _untrack(shm)
        with self._lock:
            self._busy[shm.name] = shm
            self.created += 1
        return shm

    # ----------------------------------------------------------- release
    def release(self, names) -> int:
        """Return busy segments to the free list (the consumer's ack),
        enforcing the high-water mark. Unknown names are ignored — a
        release can race a pool that was destroyed by a respawn."""
        victims = []
        n = 0
        with self._lock:
            for name in names:
                shm = self._busy.pop(name, None)
                if shm is None:
                    continue
                self._free.append(shm)
                n += 1
            self._free.sort(key=lambda s: s.size)
            while (len(self._free) > self.max_free_segments
                   or sum(s.size for s in self._free) > self.max_pool_bytes):
                victims.append(self._free.pop())   # largest first
        for shm in victims:
            _destroy_segment(shm)
        return n

    # ------------------------------------------------------------- stats
    def names(self) -> List[str]:
        with self._lock:
            return list(self._busy) + [s.name for s in self._free]

    def free_bytes(self) -> int:
        with self._lock:
            return sum(s.size for s in self._free)

    def busy_count(self) -> int:
        with self._lock:
            return len(self._busy)

    # ----------------------------------------------------------- destroy
    def destroy(self) -> None:
        """Unlink everything — busy included (only correct once no reader
        can still arrive: child exit, or parent teardown of a dead child)."""
        with self._lock:
            segs = list(self._busy.values()) + self._free
            self._busy.clear()
            self._free = []
        for shm in segs:
            _destroy_segment(shm)


def _destroy_segment(shm) -> None:
    try:
        shm.close()
    except (BufferError, OSError):
        pass
    try:
        # pool segments were untracked at alloc; re-register so the
        # unregister inside stdlib unlink() stays balanced (an unbalanced
        # one makes the tracker process spew KeyError tracebacks)
        from multiprocessing import resource_tracker
        resource_tracker.register(shm._name, "shared_memory")
    except Exception:  # noqa: BLE001
        pass
    try:
        shm.unlink()
    except (FileNotFoundError, OSError):
        _untrack(shm)    # unlink raised before its internal unregister ran


# ------------------------------------------------------------ reader cache
class SegmentCache:
    """Receiver-side attachments, keyed by segment name.

    Pool recycling means the same few names repeat for the life of a
    channel; attaching once and keeping the mapping makes the steady-state
    receive path mmap-free. Bounded LRU: writer-side trims unlink segments
    whose names never appear again, so stale attachments are evicted (safe
    between messages — decoded views never outlive message handling)."""

    def __init__(self, max_entries: int = 8):
        self.max_entries = max_entries
        self._shms: Dict[str, Any] = {}
        self._order: List[str] = []
        self.seen: set = set()       # every name ever attached (crash reap
        #                              fallback when /dev/shm is unscannable)

    def attach(self, name: str):
        shm = self._shms.get(name)
        if shm is None:
            from multiprocessing import shared_memory
            shm = shared_memory.SharedMemory(name=name)
            _untrack(shm)
            self._shms[name] = shm
            self.seen.add(name)
            self._order.append(name)
            while len(self._order) > self.max_entries:
                old = self._order.pop(0)
                if old == name:
                    self._order.append(name)
                    continue
                dead = self._shms.pop(old, None)
                if dead is not None:
                    try:
                        dead.close()
                    except BufferError:     # a view survived: keep mapped
                        self._shms[old] = dead
                        self._order.insert(0, old)
                        break
        else:
            self._order.remove(name)
            self._order.append(name)
        return shm

    def view(self, ref: ShmRef):
        """A numpy view straight over the shared buffer — zero copies. The
        caller owns the consume-before-release contract."""
        import numpy as np
        shm = self.attach(ref.segment)
        return np.ndarray(ref.shape, dtype=_view_dtype(ref.dtype),
                          buffer=shm.buf, offset=ref.offset)

    def close(self) -> None:
        for shm in self._shms.values():
            try:
                shm.close()
            except (BufferError, OSError):
                pass
        self._shms.clear()
        self._order = []


# ----------------------------------------------------------- encode/decode
def _is_big_array(x, threshold: int) -> bool:
    import numpy as np
    return (isinstance(x, np.ndarray) and x.nbytes >= threshold
            and not x.dtype.hasobject)


def _walk(obj, fn: Callable[[Any], Any]):
    """Structure-preserving transform over the containers that cross the
    pipe (dict / list / tuple / namedtuple); everything else is a leaf."""
    if isinstance(obj, dict):
        return {k: _walk(v, fn) for k, v in obj.items()}
    if isinstance(obj, tuple):
        items = [_walk(v, fn) for v in obj]
        if hasattr(obj, "_fields"):            # namedtuple
            return type(obj)(*items)
        return tuple(items)
    if isinstance(obj, list):
        return [_walk(v, fn) for v in obj]
    return fn(obj)


def encode(obj, pool: Optional[SegmentPool],
           threshold: int = DEFAULT_THRESHOLD) -> Tuple[Any, List[str]]:
    """Replace every large ndarray leaf in ``obj`` with a :class:`ShmRef`,
    packing all of them into ONE pool segment (cache-line-aligned offsets).
    Returns ``(encoded obj, segment names now busy)``. A tree with no
    large arrays — or no pool — passes through untouched with no numpy
    import (stub children stay featherweight)."""
    import sys
    if pool is None or "numpy" not in sys.modules:
        return obj, []
    import numpy as np

    leaves: List[Any] = []

    def collect(x):
        if _is_big_array(x, threshold):
            leaves.append(x)
        return x

    _walk(obj, collect)
    if not leaves:
        return obj, []

    total = 0
    offsets = []
    for arr in leaves:
        offsets.append(total)
        total += (arr.nbytes + _ALIGN - 1) & ~(_ALIGN - 1)
    shm = pool.alloc(total)

    refs: Dict[int, ShmRef] = {}
    for arr, off in zip(leaves, offsets):
        if id(arr) in refs:                   # shared leaf: write once
            continue
        dtype, wire = _wire_dtype(arr)
        dst = np.ndarray(wire.shape, dtype=wire.dtype,
                         buffer=shm.buf, offset=off)
        np.copyto(dst, wire)                  # handles any source layout
        refs[id(arr)] = ShmRef(segment=shm.name, offset=off,
                               shape=tuple(arr.shape), dtype=dtype,
                               nbytes=arr.nbytes)

    def swap(x):
        r = refs.get(id(x))
        return x if r is None else r

    return _walk(obj, swap), [shm.name]


def decode(obj, cache: SegmentCache, copy: bool = True):
    """Materialise :class:`ShmRef` leaves back into arrays.

    ``copy=True`` (default) returns owning arrays — one memcpy, the safe
    mode for results that outlive the message (client futures, host-tier
    state). ``copy=False`` returns raw views for consumers that drain them
    before the segment is released (``device_put`` + block): the actual
    zero-copy path."""
    if not has_refs(obj):
        return obj
    import numpy as np

    def mat(x):
        if not isinstance(x, ShmRef):
            return x
        view = cache.view(x)
        if x.dtype == "bfloat16":
            import ml_dtypes
            view = view.view(ml_dtypes.bfloat16)
        return np.array(view) if copy else view

    return _walk(obj, mat)


def has_refs(obj) -> bool:
    found = []

    def probe(x):
        if isinstance(x, ShmRef):
            found.append(x)
        return x

    _walk(obj, probe)
    return bool(found)


def refs_in(obj) -> List[str]:
    """Distinct segment names referenced by ``obj`` (release bookkeeping
    for relayed payloads the parent never decodes)."""
    names: List[str] = []

    def probe(x):
        if isinstance(x, ShmRef) and x.segment not in names:
            names.append(x.segment)
        return x

    _walk(obj, probe)
    return names


# ------------------------------------------------------------ crash reaping
def reap_prefix(prefix: str, tracked=()) -> List[str]:
    """Unlink every shared-memory segment whose name starts with ``prefix``
    — the parent's crash-safe sweep of a dead incarnation. Scans the shm
    directory where one exists (Linux); otherwise falls back to the
    explicit ``tracked`` name set. Idempotent: missing segments are not an
    error (a graceful child already unlinked its own)."""
    removed: List[str] = []
    if os.path.isdir(SHM_DIR):
        try:
            names = [n for n in os.listdir(SHM_DIR) if n.startswith(prefix)]
        except OSError:
            names = []
        for name in names:
            try:
                os.unlink(os.path.join(SHM_DIR, name))
                removed.append(name)
            except OSError:
                pass
        return removed
    from multiprocessing import shared_memory
    for name in tracked:
        if not name.startswith(prefix):
            continue
        try:
            # attach registers with the tracker; unlink() unregisters —
            # balanced, so no _untrack here
            shm = shared_memory.SharedMemory(name=name)
            shm.close()
            shm.unlink()
            removed.append(name)
        except (FileNotFoundError, OSError):
            pass
    return removed


# -------------------------------------------------------------- the bundle
class Transport:
    """One side of a channel: a writer pool (under ``prefix``) plus a
    reader cache for the peer's segments. ``enabled=False`` (or arrays
    under the threshold) degrades every call to a clean pickle-path no-op,
    so callers never branch."""

    def __init__(self, prefix: str, enabled: bool = True,
                 threshold: int = DEFAULT_THRESHOLD,
                 max_pool_bytes: int = DEFAULT_POOL_BYTES):
        self.prefix = prefix
        self.enabled = enabled
        self.threshold = threshold
        self._pool: Optional[SegmentPool] = None
        self._max_pool_bytes = max_pool_bytes
        self.cache = SegmentCache()

    @property
    def pool(self) -> Optional[SegmentPool]:
        if not self.enabled:
            return None
        if self._pool is None:
            self._pool = SegmentPool(self.prefix,
                                     max_pool_bytes=self._max_pool_bytes)
        return self._pool

    def encode(self, obj) -> Tuple[Any, List[str]]:
        if not self.enabled:
            return obj, []
        return encode(obj, self.pool, self.threshold)

    def decode(self, obj, copy: bool = True):
        return decode(obj, self.cache, copy=copy)

    def release(self, names) -> int:
        if self._pool is None or not names:
            return 0
        return self._pool.release(names)

    def pool_names(self) -> List[str]:
        return [] if self._pool is None else self._pool.names()

    def close(self) -> None:
        self.cache.close()
        if self._pool is not None:
            self._pool.destroy()
            self._pool = None
