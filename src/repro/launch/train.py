"""End-to-end RLVR training driver.

Trains a model on the synthetic verifiable-math task with GRPO through the
FULL PlexRL stack (Router + HRRS scheduler + StateManager + WPGs): rollout
-> verify -> update_actor -> (periodic) checkpoint, with optional two-job
multiplexing on the shared pool.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 50 --d-model 256 --layers 8 --ckpt-dir /tmp/plexrl_run

On this CPU container the default config is a ~100M-param model; on a pod
the same driver runs the full config (drop the size overrides).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.cluster import PlexCluster
from repro.core.controller import JobConfig


def size_overrides(args) -> tuple:
    ov = []
    if args.layers:
        ov.append(("num_layers", args.layers))
    if args.d_model:
        ov.append(("d_model", args.d_model))
        ov.append(("num_heads", max(4, args.d_model // 64)))
        ov.append(("num_kv_heads", max(2, args.d_model // 128)))
        ov.append(("head_dim", 64))
        ov.append(("d_ff", args.d_model * 4))
    if args.vocab:
        ov.append(("vocab_size", args.vocab))
    return tuple(ov)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--jobs", type=int, default=1,
                    help="number of RLVR jobs multiplexed on the pool")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args(argv)

    cluster = PlexCluster(n_groups=1)
    ov = size_overrides(args)
    for j in range(args.jobs):
        cfg = JobConfig(
            job_id=f"job{j}", model_name=args.arch, steps=args.steps,
            batch_size=args.batch_size, group_size=args.group_size,
            max_new_tokens=args.max_new_tokens, seq_len=args.seq_len,
            overrides=ov, seed=j)
        cluster.add_job(cfg)

    t0 = time.time()
    billing = cluster.run(interleave=args.jobs > 1)
    elapsed = time.time() - t0

    for job_id, ctl in cluster.controllers.items():
        rewards = ctl.reward_log
        print(f"[{job_id}] steps={len(rewards)} "
              f"reward first5={np.round(rewards[:5], 3).tolist()} "
              f"last5={np.round(rewards[-5:], 3).tolist()} "
              f"mean={np.mean(rewards):.3f}")
        losses = [m["loss"] for m in ctl.metrics_log]
        print(f"[{job_id}] loss first={losses[0]:.4f} last={losses[-1]:.4f} "
              f"gpu_s/step={billing[job_id].gpu_seconds_per_step():.2f}")
    print(f"wall={elapsed:.1f}s switches={len(cluster.router.switch_log)}")

    if args.ckpt_dir:
        paths = cluster.checkpoint_all(args.ckpt_dir)
        print("checkpoints:", json.dumps(paths, indent=1))


if __name__ == "__main__":
    main()
