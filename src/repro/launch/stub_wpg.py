"""Spawnable stub WorkerProcessGroup for process-plane tests and benches.

Lives under ``src/`` (not ``benchmarks/`` or ``tests/``) because spawned
group processes must be able to import the factory by name — the pipe
carries ``"repro.launch.stub_wpg:make_busy_wpg"``, never a pickled
callable. ``needs_state_manager = False`` keeps the child jax-free (the
process plane gives it a ``_LiteSM``), so a stub group spawns fast.

Per-op kwargs drive behaviour:

- ``busy_s``   — burn CPU for that long (pure-Python loop, so a THREAD
  worker holds the GIL: this is what makes the thread-vs-process overlap
  comparison honest)
- ``sleep_s``  — blocking sleep (releases the GIL; models device-bound
  work in thread mode)
- ``crash``    — hard-exit the worker process mid-op (``os._exit``), the
  robustness-test stand-in for a device/process failure
- ``fail``     — raise inside ``execute`` (a remote op error, not a death)
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict


class BusyWPG:
    """Minimal WPG protocol surface, compute-bound on demand."""

    needs_state_manager = False

    def __init__(self, spec, sm):
        self.spec = spec
        self.sm = sm
        self.exec_log: list = []
        self._resident = True

    @property
    def job_prefix(self) -> str:
        return f"{self.spec.job_id}:{self.spec.deployment_id}"

    def resident(self) -> bool:
        return self._resident

    def ensure_resident(self) -> float:
        self._resident = True
        return 0.0

    def offload(self, to=None) -> float:
        self._resident = False
        return 0.0

    def execute(self, qop) -> Dict[str, Any]:
        t0 = time.monotonic()
        kw = qop.kwargs
        if kw.get("crash"):
            os._exit(43)
        if kw.get("fail"):
            raise RuntimeError(f"stub op {qop.req_id} asked to fail")
        busy = float(kw.get("busy_s", 0.0))
        if busy > 0.0:
            # pure-Python spin against THREAD CPU time, not wall clock: a
            # GIL-starved thread must take proportionally longer wall time
            # (a wall deadline would let contended threads "finish" on
            # schedule having done less work, faking overlap)
            deadline = time.thread_time() + busy
            x = 0
            while time.thread_time() < deadline:
                x += 1
        sleep = float(kw.get("sleep_s", 0.0))
        if sleep > 0.0:
            time.sleep(sleep)
        dt = time.monotonic() - t0
        self.exec_log.append((qop.op.value, dt))
        return {"op": qop.op.value, "req_id": qop.req_id, "pid": os.getpid(),
                "seconds": dt}


def make_busy_wpg(spec, sm) -> BusyWPG:
    return BusyWPG(spec, sm)


make_busy_wpg.needs_state_manager = False
