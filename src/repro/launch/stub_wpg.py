"""Spawnable stub WorkerProcessGroup for process-plane tests and benches.

Lives under ``src/`` (not ``benchmarks/`` or ``tests/``) because spawned
group processes must be able to import the factory by name — the pipe
carries ``"repro.launch.stub_wpg:make_busy_wpg"``, never a pickled
callable. ``needs_state_manager = False`` keeps the child jax-free (the
process plane gives it a ``_LiteSM``), so a stub group spawns fast.

Per-op kwargs drive behaviour:

- ``busy_s``   — burn CPU for that long (pure-Python loop, so a THREAD
  worker holds the GIL: this is what makes the thread-vs-process overlap
  comparison honest)
- ``sleep_s``  — blocking sleep (releases the GIL; models device-bound
  work in thread mode)
- ``crash``    — hard-exit the worker process mid-op (``os._exit``), the
  robustness-test stand-in for a device/process failure
- ``fail``     — raise inside ``execute`` (a remote op error, not a death)
- ``payload_mb`` / ``payload_kib`` — return that much numpy array in the
  result (cached per size across ops), so transport tests/benches drive
  real bytes through the reply path
- ``stored_sum`` — return the checksum of the last ``_store``\\ d params
  (verifies a cross-child weight sync actually landed)

``make_crash_store_wpg`` builds a group whose ``_store`` hard-exits — the
stand-in for a child dying mid-``sync_weights`` with shm descriptors in
flight. ``sync_mb`` / ``sync_kib`` in the spec overrides size
``host_params``.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict


class BusyWPG:
    """Minimal WPG protocol surface, compute-bound on demand."""

    needs_state_manager = False

    def __init__(self, spec, sm):
        self.spec = spec
        self.sm = sm
        self.exec_log: list = []
        self._resident = True

    @property
    def job_prefix(self) -> str:
        return f"{self.spec.job_id}:{self.spec.deployment_id}"

    def resident(self) -> bool:
        return self._resident

    def ensure_resident(self) -> float:
        self._resident = True
        return 0.0

    def offload(self, to=None) -> float:
        self._resident = False
        return 0.0

    # ------------------------------------------------ weight-sync surface
    def host_params(self) -> Dict[str, Any]:
        """Deterministic host-staged params sized by the spec's ``sync_mb``
        (MiB, default 1) or ``sync_kib`` override — what a cross-child sync
        exports. Cached: repeated syncs time the transport, not arange."""
        params = getattr(self, "_host_params", None)
        if params is None:
            import numpy as np
            ov = dict(self.spec.overrides or ())
            kib = (int(ov["sync_kib"]) if "sync_kib" in ov
                   else int(ov.get("sync_mb", 1)) << 10)
            n = (kib << 10) // 4
            params = self._host_params = {"w": np.arange(n, dtype=np.float32)}
        return params

    def _store(self, params=None) -> None:
        self.stored = params

    def execute(self, qop) -> Dict[str, Any]:
        t0 = time.monotonic()
        kw = qop.kwargs
        if kw.get("crash"):
            os._exit(43)
        if kw.get("fail"):
            raise RuntimeError(f"stub op {qop.req_id} asked to fail")
        busy = float(kw.get("busy_s", 0.0))
        if busy > 0.0:
            # pure-Python spin against THREAD CPU time, not wall clock: a
            # GIL-starved thread must take proportionally longer wall time
            # (a wall deadline would let contended threads "finish" on
            # schedule having done less work, faking overlap)
            deadline = time.thread_time() + busy
            x = 0
            while time.thread_time() < deadline:
                x += 1
        sleep = float(kw.get("sleep_s", 0.0))
        if sleep > 0.0:
            time.sleep(sleep)
        dt = time.monotonic() - t0
        out = {"op": qop.op.value, "req_id": qop.req_id, "pid": os.getpid(),
               "seconds": dt}
        kib = int(kw.get("payload_kib", 0)) + (int(kw.get("payload_mb", 0))
                                               << 10)
        if kib > 0:
            import numpy as np
            # cached per size so repeated ops time the TRANSPORT, not the
            # array construction (transport_bench reps hit this path)
            cache = getattr(self, "_payload_cache", None)
            if cache is None:
                cache = self._payload_cache = {}
            arr = cache.get(kib)
            if arr is None:
                arr = cache[kib] = np.arange((kib << 10) // 8,
                                             dtype=np.float64)
            out["data"] = arr
        if kw.get("stored_sum"):
            import numpy as np
            stored = getattr(self, "stored", None) or {}
            out["stored_sum"] = float(sum(
                np.asarray(v, np.float64).sum() for v in stored.values()))
        return out


class CrashStoreWPG(BusyWPG):
    """Dies inside ``_store`` — a target child crashing mid-sync while the
    source child's shm descriptors are in flight."""

    def _store(self, params=None) -> None:
        os._exit(44)


def make_busy_wpg(spec, sm) -> BusyWPG:
    return BusyWPG(spec, sm)


make_busy_wpg.needs_state_manager = False


def make_crash_store_wpg(spec, sm) -> CrashStoreWPG:
    return CrashStoreWPG(spec, sm)


make_crash_store_wpg.needs_state_manager = False
