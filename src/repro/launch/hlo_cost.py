"""HLO-text cost analyzer with while-loop trip-count weighting.

``jax.stages.Compiled.cost_analysis()`` counts each while-loop body ONCE,
which silently under-counts scan-over-layers models by ~num_layers x. This
module parses ``compiled.as_text()`` and computes:

- flops            — dot ops: 2 x result_elems x contracted size
- traffic_bytes    — per-op operand+result bytes (fusions count boundary
                     traffic only: the HBM model of a fused kernel)
- collective bytes — by kind (all-gather / all-reduce / reduce-scatter /
                     all-to-all / collective-permute), result-shape bytes

each weighted by the computation call graph, where while bodies multiply by
XLA's ``backend_config known_trip_count`` annotation. Nested whiles (e.g.
chunked attention inside a layer scan) multiply through.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_WIDTHS = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<result>\([^)]*\)|[^\s]+)"
    r"\s+(?P<kind>[\w\-]+)\((?P<operands>.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s+\(.*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_NO_TRAFFIC = {"tuple", "get-tuple-element", "parameter", "constant",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id", "iota", "get-dimension-size"}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _WIDTHS:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _WIDTHS[dt]
    return total


def _shape_dims(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Op:
    name: str
    kind: str
    result: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # op name -> result text


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{"):
                m = _COMP_RE.match(line.strip())
                if m:
                    cur = Computation(m.group("name"))
            continue
        if line == "}" or line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, result, kind = m.group("name"), m.group("result"), m.group("kind")
        # operands: %names inside the parens (first level is fine for shapes)
        operands = re.findall(r"%([\w\.\-]+)", m.group("operands"))
        op = Op(name=name, kind=kind, result=result, line=line, operands=operands)
        cur.ops.append(op)
        cur.shapes[name] = result
    return comps


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    res = _shape_dims(op.result)
    if res is None:
        return 0.0
    _, rdims = res
    out_elems = 1
    for d in rdims:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    lhs_name = op.operands[0] if op.operands else None
    if m and lhs_name and lhs_name in shapes:
        lhs = _shape_dims(shapes[lhs_name])
        if lhs is not None:
            _, ldims = lhs
            k = 1
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(ldims):
                    k *= ldims[idx]
            return 2.0 * out_elems * k
    # fallback: assume square-ish contraction unknown -> count as elementwise
    return 2.0 * out_elems


@dataclass
class CompCost:
    flops: float = 0.0
    traffic: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)
    children: List[Tuple[str, float]] = field(default_factory=list)  # (comp, weight)


def _local_cost(comp: Computation) -> CompCost:
    c = CompCost(coll={k: 0.0 for k in COLLECTIVES},
                 coll_counts={k: 0.0 for k in COLLECTIVES})
    for op in comp.ops:
        kind = op.kind
        base_kind = kind[:-6] if kind.endswith("-start") else kind
        if base_kind in COLLECTIVES:
            nbytes = _shape_bytes(op.result)
            c.coll[base_kind] += nbytes
            c.coll_counts[base_kind] += 1
            c.traffic += nbytes
        if kind == "dot":
            c.flops += _dot_flops(op, comp.shapes)
        elif kind == "convolution":
            # rough: 2 x out_elems x (unknown k) — count out elems x 2
            res = _shape_dims(op.result)
            if res:
                n = 1
                for d in res[1]:
                    n *= d
                c.flops += 2.0 * n
        if kind not in _NO_TRAFFIC and not kind.endswith("-done"):
            res_bytes = _shape_bytes(op.result)
            if kind in ("dynamic-slice", "slice", "gather", "pad",
                        "concatenate", "broadcast", "convert", "copy",
                        "transpose", "reshape", "reverse"):
                # in-place-ish / windowed ops: touch the slice, not the buffer
                nbytes = 2 * res_bytes
            elif kind == "dynamic-update-slice":
                upd = (op.operands[1] if len(op.operands) > 1 else None)
                upd_bytes = (_shape_bytes(comp.shapes[upd])
                             if upd in comp.shapes else res_bytes)
                nbytes = 2 * upd_bytes
            elif kind == "scatter":
                upd = (op.operands[2] if len(op.operands) > 2 else None)
                upd_bytes = (_shape_bytes(comp.shapes[upd])
                             if upd in comp.shapes else res_bytes)
                nbytes = 3 * upd_bytes
            else:
                nbytes = res_bytes
                for o in op.operands:
                    if o in comp.shapes:
                        nbytes += _shape_bytes(comp.shapes[o])
            c.traffic += nbytes
        # call graph
        if kind == "while":
            trip = 1.0
            m = _TRIP_RE.search(op.line)
            if m:
                trip = float(m.group(1))
            called = _CALLED.findall(op.line)
            for comp_name in called:
                c.children.append((comp_name, trip))
        elif kind == "conditional":
            m = _BRANCHES.search(op.line)
            if m:
                for b in re.findall(r"%?([\w\.\-]+)", m.group(1)):
                    c.children.append((b, 1.0))
        elif kind in ("call", "fusion", "reduce", "map", "sort", "scatter",
                      "reduce-window", "select-and-scatter", "all-reduce",
                      "reduce-scatter", "custom-call", "async-start"):
            for comp_name in _CALLED.findall(op.line):
                # reduction lambdas are trivial; fusions' internals are
                # already modelled as boundary traffic — count their dots only
                c.children.append((comp_name, 1.0))
    return c


def top_flops(hlo: str, n: int = 15):
    """Debug view: the n largest dot ops by (flops x trip weight)."""
    comps = parse_computations(hlo)
    local = {name: _local_cost(c) for name, c in comps.items()}
    weights: Dict[str, float] = {}
    called = set()
    for c in local.values():
        for nm, _ in c.children:
            called.add(nm)

    def walk(name, w, seen=()):
        if name in seen:
            return
        weights[name] = weights.get(name, 0.0) + w
        for child, cw in local.get(name, CompCost()).children:
            walk(child, w * cw, seen + (name,))

    for r in [nm for nm in comps if nm not in called]:
        walk(r, 1.0)
    out = []
    for name, comp in comps.items():
        w = weights.get(name, 0.0)
        if not w:
            continue
        for op in comp.ops:
            if op.kind == "dot":
                fl = _dot_flops(op, comp.shapes)
                out.append({"comp": name, "flops": fl, "weight": w,
                            "total": fl * w, "line": op.line.strip()[:160]})
    out.sort(key=lambda d: -d["total"])
    return out[:n]


def top_collectives(hlo: str, n: int = 20):
    """Debug view: the n largest collectives by (bytes x trip weight)."""
    comps = parse_computations(hlo)
    local = {name: _local_cost(c) for name, c in comps.items()}
    # weight of each computation = product of trip counts on the path
    weights: Dict[str, float] = {}
    called = set()
    for c in local.values():
        for nm, _ in c.children:
            called.add(nm)
    roots = [nm for nm in comps if nm not in called]

    def walk(name, w):
        weights[name] = weights.get(name, 0.0) + w
        for child, cw in local.get(name, CompCost()).children:
            if child != name:
                walk(child, w * cw)

    for r in roots:
        walk(r, 1.0)
    out = []
    for name, comp in comps.items():
        w = weights.get(name, 0.0)
        if not w:
            continue
        for op in comp.ops:
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base in COLLECTIVES:
                b = _shape_bytes(op.result)
                out.append({"comp": name, "kind": base, "bytes": b,
                            "weight": w, "total": b * w,
                            "line": op.line.strip()[:180]})
    out.sort(key=lambda d: -d["total"])
    return out[:n]


def analyze(hlo: str, entry: Optional[str] = None) -> Dict[str, float]:
    """Full weighted analysis of a compiled HLO module (single device view)."""
    comps = parse_computations(hlo)
    local = {name: _local_cost(c) for name, c in comps.items()}

    # entry = computation that no one calls (or named ENTRY in the text)
    called = set()
    for c in local.values():
        for name, _ in c.children:
            called.add(name)
    entries = [n for n in comps if n not in called]
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
        entry = m.group(1) if m else (entries[0] if entries else None)
    if entry is None:
        return {"flops": 0.0, "traffic_bytes": 0.0, "collective_bytes": 0.0}

    memo: Dict[str, CompCost] = {}

    def total(name: str, seen=()) -> CompCost:
        if name in memo:
            return memo[name]
        if name not in local or name in seen:
            return CompCost(coll={k: 0.0 for k in COLLECTIVES},
                            coll_counts={k: 0.0 for k in COLLECTIVES})
        base = local[name]
        agg = CompCost(flops=base.flops, traffic=base.traffic,
                       coll=dict(base.coll), coll_counts=dict(base.coll_counts))
        for child, w in base.children:
            sub = total(child, seen + (name,))
            agg.flops += w * sub.flops
            agg.traffic += w * sub.traffic
            for k in COLLECTIVES:
                agg.coll[k] += w * sub.coll.get(k, 0.0)
                agg.coll_counts[k] += w * sub.coll_counts.get(k, 0.0)
        memo[name] = agg
        return agg

    t = total(entry)
    out = {
        "flops": t.flops,
        "traffic_bytes": t.traffic,
        "collective_bytes": sum(t.coll.values()),
        "collective_counts": t.coll_counts,
    }
    for k in COLLECTIVES:
        out[f"bytes_{k}"] = t.coll[k]
    return out
