"""Device plane: mesh construction and per-group mesh slices.

Functions (never module-level constants) so importing this module never
touches jax device state. Single pod: 256 chips (16x16, TPU v5e pod).
Multi-pod: 2 pods = 512 chips with a leading ``pod`` axis for cross-pod
data parallelism (DCN-connected in production; the dry-run proves the pod
axis shards).

The :class:`DevicePlane` carves ``jax.devices()`` into disjoint
:class:`MeshSlice`\\ s so that each node group owns real hardware affinity:
a group's WPGs build their jitted primitives against the group's mesh, its
StateManager records per-entry shardings on that mesh, and cross-group
migration means resharding (device_get on the source slice, device_put with
the target slice's NamedShardings). On CI the same code paths run against
virtual CPU devices via::

    XLA_FLAGS=--xla_force_host_platform_device_count=8

(set BEFORE jax's backend initialises — see launch/dryrun.py for the
env-before-import precedent).

Everything here is deterministic and clock-free: slice boundaries depend
only on the device list and the carve parameters, and acquisition follows
group-creation order — so the ``VirtualClock`` bit-identical-replay
contract is untouched.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

XLA_HINT = ("set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "before importing jax to get N virtual CPU devices")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(
            f"make_local_mesh(data={data}, model={model}) needs "
            f"{data * model} devices but only {n} are available; {XLA_HINT}")
    return jax.make_mesh((data, model), ("data", "model"))


def _slice_mesh(devices: Sequence) -> Mesh:
    """A (1, n) data×model mesh over an explicit device subset. Built from
    the raw device array (not jax.make_mesh) so the slice binds exactly the
    devices it was carved with."""
    arr = np.empty((1, len(devices)), dtype=object)
    for i, d in enumerate(devices):
        arr[0, i] = d
    return Mesh(arr, ("data", "model"))


@dataclasses.dataclass(frozen=True)
class MeshSlice:
    """A disjoint subset of the cluster's devices with its own mesh."""
    index: int
    devices: Tuple
    mesh: Mesh

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    def device_ids(self) -> Tuple[int, ...]:
        return tuple(d.id for d in self.devices)


def env_for_slice(sl: MeshSlice) -> Dict[str, str]:
    """The child-process environment that makes a spawned group process see
    EXACTLY its slice's devices. On the CPU backend there is no per-device
    visibility mask, so the child gets its own virtual-device world of the
    slice's size (slice identity is positional there — fine, since CPU
    devices are fungible). On real accelerators, visibility masking means
    the child's ``jax.devices()`` IS the slice. Must be applied in the
    child before jax's backend initialises — see launch/proc_plane.py."""
    if all(d.platform == "cpu" for d in sl.devices):
        return {"JAX_PLATFORMS": "cpu",
                "XLA_FLAGS":
                    f"--xla_force_host_platform_device_count={sl.n_devices}"}
    ids = ",".join(str(i) for i in sl.device_ids())
    return {"CUDA_VISIBLE_DEVICES": ids, "JAX_VISIBLE_DEVICES": ids}


def host_shm_bytes(path: str = "/dev/shm") -> Optional[int]:
    """Free bytes in the host's POSIX shared-memory filesystem, or ``None``
    where it doesn't exist (macOS, some containers). The process plane's
    shm transport sizes its segment pools against this: tmpfs defaults to
    half of RAM, and a container run with a small ``--shm-size`` will make
    ``shm_transport.shm_available()`` fall back to pipe pickling rather
    than fail mid-transfer."""
    try:
        st = os.statvfs(path)
    except OSError:
        return None
    return st.f_bavail * st.f_frsize


class DevicePlane:
    """Carves ``jax.devices()`` into disjoint mesh slices and leases them
    to node groups.

    ``carve(n_groups)`` partitions the device list into contiguous slices
    (``slice_size`` devices each when given, else ``len(devices) //
    n_groups``, minimum 1). ``slice_for_group(gid)`` leases the
    lowest-index free slice to a group; when every slice is held, groups
    share the least-loaded slice (deterministic tie-break by index) — on a
    single default device all groups share the lone one-device slice, which
    is exactly the pre-device-plane behaviour. ``release(gid)`` returns the
    lease on group retirement. Idempotent per group id, and thread-safe
    (the router acquires under its executor lock but benches drive a plane
    directly)."""

    def __init__(self, devices: Optional[Sequence] = None,
                 slice_size: Optional[int] = None):
        self._devices = tuple(devices) if devices is not None else None
        self.slice_size = slice_size
        self._slices: Optional[List[MeshSlice]] = None
        self._owner: Dict[int, int] = {}      # group id -> slice index
        self._holders: Dict[int, int] = {}    # slice index -> lease count
        self._lock = threading.Lock()

    # --------------------------------------------------------- device view
    def devices(self) -> Tuple:
        if self._devices is None:
            self._devices = tuple(jax.devices())
        return self._devices

    # -------------------------------------------------------------- carve
    def carve(self, n_groups: Optional[int] = None) -> List[MeshSlice]:
        """Partition the device list into disjoint slices. Callable once,
        before any lease; ``slices()`` carves lazily with defaults."""
        with self._lock:
            if self._owner:
                raise RuntimeError("cannot re-carve: slices are leased")
            return list(self._carve_locked(n_groups))

    def _carve_locked(self, n_groups: Optional[int] = None) -> List[MeshSlice]:
        devs = self.devices()
        if self.slice_size is not None:
            size = max(1, min(self.slice_size, len(devs)))
        elif n_groups:
            size = max(1, len(devs) // n_groups)
        else:
            size = 1
        n = max(1, len(devs) // size)
        self._slices = [
            MeshSlice(index=i, devices=tuple(devs[i * size:(i + 1) * size]),
                      mesh=_slice_mesh(devs[i * size:(i + 1) * size]))
            for i in range(n)]
        return self._slices

    def slices(self) -> List[MeshSlice]:
        with self._lock:
            if self._slices is None:
                self._carve_locked()
            return list(self._slices)

    # -------------------------------------------------------------- leases
    def slice_for_group(self, group_id: int) -> MeshSlice:
        """The slice leased to ``group_id`` (leasing one if needed)."""
        return self.acquire(group_id)

    def acquire(self, group_id: int) -> MeshSlice:
        with self._lock:
            if self._slices is None:
                self._carve_locked()
            idx = self._owner.get(group_id)
            if idx is None:
                free = [s.index for s in self._slices
                        if self._holders.get(s.index, 0) == 0]
                if free:
                    idx = free[0]
                else:  # oversubscribed: share the least-loaded slice
                    idx = min(self._slices,
                              key=lambda s: (self._holders.get(s.index, 0),
                                             s.index)).index
                self._owner[group_id] = idx
                self._holders[idx] = self._holders.get(idx, 0) + 1
            return self._slices[idx]

    def release(self, group_id: int):
        with self._lock:
            idx = self._owner.pop(group_id, None)
            if idx is not None:
                self._holders[idx] = max(0, self._holders.get(idx, 1) - 1)

    def slice_index(self, group_id: int) -> Optional[int]:
        with self._lock:
            return self._owner.get(group_id)

    def domains(self) -> Dict[int, int]:
        """group id -> slice index for every leased group (the placement
        layer's mesh-domain map: moves across domains pay the reshard)."""
        with self._lock:
            return dict(self._owner)


# TPU v5e hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link (~per chip per direction)
    "hbm_bytes": 16e9,             # HBM capacity per chip
}
