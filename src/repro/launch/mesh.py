"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state. Single pod: 256 chips (16x16, TPU v5e pod).
Multi-pod: 2 pods = 512 chips with a leading ``pod`` axis for cross-pod
data parallelism (DCN-connected in production; the dry-run proves the pod
axis shards).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link (~per chip per direction)
    "hbm_bytes": 16e9,             # HBM capacity per chip
}
