"""Process plane: per-group worker processes with an IPC dispatch protocol.

Thread-mode dispatch workers share one interpreter, so two groups' jit
dispatches serialize on the GIL even when the device plane gives them
disjoint ``MeshSlice``\\ s. The process plane makes cross-group overlap real
wall-clock parallelism on one host: each node group's WPGs live in a
separate OS process bound to the group's device slice, and the Router's
dispatch protocol crosses an IPC boundary instead of a method call.

Pieces
------
- :class:`GroupProcess` — parent-side handle on one group's worker process.
  Spawned (never forked: jax + threads make fork unsafe) with an
  environment derived from the group's slice
  (:func:`repro.launch.mesh.env_for_slice` — ``XLA_FLAGS`` /
  ``JAX_VISIBLE_DEVICES`` applied in the child BEFORE jax imports), talking
  a length-prefixed pickle protocol over a ``multiprocessing`` duplex pipe:
  ``create_deployment`` / ``execute`` / ``migrate_export`` /
  ``migrate_import`` / ``sync_export`` / ``shutdown`` / ``ping`` (the
  liveness heartbeat). ``respawn()`` replaces a dead child in place and
  replays its deployment registrations.
- :class:`WPGProxy` — what ``Router.wpgs[dep]`` holds in process mode: the
  WorkerProcessGroup surface dispatch, teardown, billing and migration
  touch, forwarded over the pipe. Each completed ``execute`` reply carries
  the child's ``(op, seconds)`` log entry, which the proxy appends to a
  LOCAL :class:`~repro.core.worker.ExecLog` mirror — billing cursors read
  the standard ring, and completed work stays billed even if the child
  later dies mid-op.
- :class:`StateManagerProxy` — the group StateManager surface the Router
  reads (job bytes, setup-cost estimates, keys, unregister), plus
  cross-process :meth:`StateManagerProxy.migrate` composed from the
  child-side ``StateManager.export_state`` / ``import_state`` pair
  (host-staged arrays over the pipe, disk-tier fallback for large entries).

The parent thread blocking in ``recv`` releases the GIL, so per-group
dispatch threads proxying into different children genuinely overlap.

Array transport (PR 10): large arrays in requests and replies do NOT
travel through the pipe. Each side owns a :class:`~repro.launch.
shm_transport.Transport` — a pooled ``multiprocessing.shared_memory``
writer plus an attachment cache for the peer's segments — and the pipe
carries :class:`~repro.launch.shm_transport.ShmRef` descriptors instead of
bytes. Release protocol: a reply is the consumption ack for the request's
segments (handlers block on ``device_put`` before replying); reply
segments are acked by an explicit fire-and-forget ``shm_release`` frame
from the parent. Cross-child payloads (sync / migrate) are RELAYED: the
parent forwards the source child's descriptors to the destination child
untouched — the bytes are written once and read once, both in children —
and releases them to the source only after the destination's reply. A
dead child's segments are reaped by prefix during terminate/respawn.

This module imports ONLY the stdlib at module level: a spawned child
imports it before applying its device environment, so any transitive jax
import here would bind the child to the parent's device world. jax-touching
imports (worker, state_manager, mesh) happen lazily, after the env is set
(``shm_transport`` is stdlib-only at module level for the same reason).
"""
from __future__ import annotations

import glob
import importlib
import itertools
import logging
import multiprocessing
import os
import pickle
import struct
import sys
import threading
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

from repro.launch import shm_transport as shmt

logger = logging.getLogger(__name__)

_LEN = struct.Struct("!I")
_nonce = itertools.count(1)

# request kinds whose decoded arrays may stay VIEWS over shared segments:
# their handlers consume (device_put + block, or explicit copy) before
# replying, which is what lets the reply double as the release ack
_VIEW_KINDS = frozenset({"store_params", "migrate_import"})


class GroupProcessError(RuntimeError):
    """The group's worker process is dead or the channel broke mid-call."""


# ------------------------------------------------------------ wire format
def _send(conn, obj) -> None:
    """One logical frame = two pipe messages: a 4-byte big-endian length
    prefix, then the pickled body. The explicit prefix lets the receiver
    reject a truncated or corrupted frame instead of unpickling garbage;
    sending it as its own message (rather than prepending it to the body)
    means the multi-MiB pickle buffer is never copied a second time just
    to gain 4 leading bytes. The protocol is strictly serial per channel,
    so the two messages cannot interleave with another frame."""
    buf = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    conn.send_bytes(_LEN.pack(len(buf)))
    conn.send_bytes(buf)


def _recv(conn):
    hdr = conn.recv_bytes()
    if len(hdr) != _LEN.size:
        raise EOFError("truncated frame (bad length prefix)")
    (n,) = _LEN.unpack(hdr)
    # recv_bytes hands back exactly one message — no prefix slice, so no
    # second traversal of the body either (the old path copied the whole
    # buffer once to strip 4 bytes)
    body = conn.recv_bytes()
    if len(body) != n:
        raise EOFError(
            f"frame length mismatch: prefix says {n}, got {len(body)}")
    return pickle.loads(body)


def _unlink_spills(payload) -> List[str]:
    """Delete a migrate-export payload's transfer-scoped spill files (only
    ``export__``-named ones — never regular disk-tier state). Idempotent:
    an importer that already consumed some is fine."""
    removed = []
    for ent in payload.get("entries", ()):
        path = ent.get("path")
        if path and os.path.basename(path).startswith("export__"):
            try:
                os.unlink(path)
                removed.append(path)
            except OSError:
                pass
    return removed


def _resolve_factory(ref: Optional[str]):
    """Factories cross the spawn boundary by NAME ("module:callable"), not
    by pickle — a lambda in a test module would not survive spawn. None
    resolves to the real WorkerProcessGroup (imports jax, in the child,
    after the device env is applied)."""
    if ref is None:
        from repro.core.worker import WorkerProcessGroup
        return WorkerProcessGroup
    mod, _, name = ref.partition(":")
    if not name:
        raise ValueError(f"factory ref {ref!r} is not 'module:callable'")
    return getattr(importlib.import_module(mod), name)


def _to_host(obj):
    """Stage a result tree to host numpy for the reply pickle. Only does
    work when jax is actually loaded in this process — lite stub children
    never import it."""
    if "jax" not in sys.modules:
        return obj
    import jax
    import numpy as np

    def conv(x):
        return np.asarray(jax.device_get(x)) if isinstance(x, jax.Array) else x

    return jax.tree.map(conv, obj)


def _own_arrays(obj):
    """Copy any array leaf that does not own its buffer (shm views after a
    ``decode(copy=False)``) so the consumer can retain the tree after the
    segment is released. No-op on owning arrays and non-array leaves."""
    if "numpy" not in sys.modules:
        return obj
    import numpy as np

    def conv(x):
        if isinstance(x, np.ndarray) and x.base is not None:
            return np.array(x)
        return x

    return shmt._walk(obj, conv)


# ------------------------------------------------------------- child side
class _LiteSM:
    """Featherweight StateManager stand-in for stub factories
    (``needs_state_manager = False`` on the factory): keeps the child
    jax-free, so a stub group process spawns in ~100 ms."""

    mesh_slice = None

    def __init__(self):
        self.entries: Dict[str, Any] = {}

    def job_bytes(self, job_id: str) -> int:
        return 0

    def load_time_estimate(self, nbytes: int) -> float:
        return 0.0

    def offload_time_estimate(self, nbytes: int) -> float:
        return 0.0

    def keys_for(self, job_id: str, prefix=None) -> list:
        return []

    def unregister(self, keys) -> None:
        pass


class _ChildState:
    """Everything the group's worker process owns: its (lazily created)
    StateManager bound to a mesh over ALL the devices the child can see —
    which, by env construction, IS the group's slice — and one real WPG per
    registered deployment."""

    def __init__(self, cfg: Dict[str, Any]):
        self.cfg = cfg
        self.wpgs: Dict[str, Any] = {}
        self._sm = None
        self._lite: Optional[_LiteSM] = None

    @property
    def sm(self):
        return self._sm if self._sm is not None else self._lite

    def _state_manager(self, needs_real: bool):
        if not needs_real:
            if self._lite is None:
                self._lite = _LiteSM()
            return self._lite
        if self._sm is None:
            import jax

            from repro.core.state_manager import StateManager
            from repro.launch.mesh import MeshSlice, _slice_mesh

            sm = StateManager(node_id=self.cfg["node_id"])
            devs = tuple(jax.devices())
            sm.mesh_slice = MeshSlice(index=self.cfg["slice_index"],
                                      devices=devs, mesh=_slice_mesh(devs))
            self._sm = sm
        return self._sm

    # ---------------------------------------------------------- handlers
    def handle(self, kind: str, payload) -> Tuple[Any, Any]:
        return getattr(self, f"_h_{kind}")(payload)

    def _h_create_deployment(self, p):
        factory = _resolve_factory(p["factory"])
        sm = self._state_manager(getattr(factory, "needs_state_manager", True))
        self.wpgs[p["spec"].deployment_id] = factory(p["spec"], sm)
        return None, None

    def _h_drop_deployment(self, p):
        self.wpgs.pop(p["dep"], None)
        return None, None

    def _h_execute(self, p):
        from repro.core import api

        wpg = self.wpgs[p["dep"]]
        op = api.Op(p["op"])
        args = tuple(p["args"])
        if (op is api.Op.SYNC_WEIGHTS and args
                and isinstance(args[0], tuple) and len(args[0]) == 2
                and args[0][0] == "__dep__"):
            # same-child weight sync: the dep-id marker resolves to the
            # co-resident target WPG (cross-child syncs never reach here —
            # WPGProxy orchestrates sync_export/store_params instead)
            args = (self.wpgs[args[0][1]],) + args[1:]
        qop = api.QueuedOperation(
            req_id=p["req_id"], deployment_id=p["dep"], job_id=p["job_id"],
            op=op, args=args, kwargs=dict(p["kwargs"]))
        t0 = time.monotonic()
        result = wpg.execute(qop)
        return _to_host(result), (op.value, time.monotonic() - t0)

    def _h_resident(self, p):
        return self.wpgs[p["dep"]].resident(), None

    def _h_ensure_resident(self, p):
        return self.wpgs[p["dep"]].ensure_resident(), None

    def _h_offload(self, p):
        from repro.core.state_manager import Tier
        return self.wpgs[p["dep"]].offload(Tier(p["tier"])), None

    def _h_sync_export(self, p):
        return self.wpgs[p["dep"]].host_params(), None

    def _h_store_params(self, p):
        wpg = self.wpgs[p["dep"]]
        tree = p["tree"]
        shardings = wpg.param_shardings() \
            if hasattr(wpg, "param_shardings") else None
        if shardings is not None:
            # the zero-copy landing: device_put reads STRAIGHT from the
            # mapped shm views; block before replying, because the reply
            # is the ack that lets the writer recycle the segment
            import jax
            tree = jax.tree.map(jax.device_put, tree, shardings)
            tree = jax.block_until_ready(tree)
        else:
            # host-retained params (stub/host-only WPGs) must not keep
            # views over a segment about to be recycled
            tree = _own_arrays(tree)
        wpg._store(params=tree)
        return None, None

    def _h_job_bytes(self, p):
        return (0 if self.sm is None else self.sm.job_bytes(p["job"])), None

    def _h_load_estimate(self, p):
        sm = self.sm
        return (0.0 if sm is None
                else sm.load_time_estimate(p["nbytes"])), None

    def _h_offload_estimate(self, p):
        sm = self.sm
        return (0.0 if sm is None
                else sm.offload_time_estimate(p["nbytes"])), None

    def _h_keys_for(self, p):
        sm = self.sm
        return ([] if sm is None
                else list(sm.keys_for(p["job"], p.get("prefix")))), None

    def _h_all_keys(self, p):
        return ([] if self.sm is None else list(self.sm.entries)), None

    def _h_unregister(self, p):
        if self.sm is not None:
            self.sm.unregister(p["keys"])
        return None, None

    def _h_migrate_export(self, p):
        sm = self._state_manager(True)
        return sm.export_state(p["job"],
                               max_inline_bytes=p["max_inline"]), None

    def _h_migrate_import(self, p):
        sm = self._state_manager(True)
        payload = p["payload"]
        moved = sm.import_state(payload)
        # entries that landed DEVICE were device_put directly from shm
        # views (import_state copies host-retained ones); drain the async
        # puts before replying — the reply releases the source segments
        if "jax" in sys.modules:
            import jax
            from repro.core.state_manager import Tier
            refs = []
            for ent in payload["entries"]:
                e = sm.entries.get(ent["key"])
                if e is not None and e.tier == Tier.DEVICE:
                    refs.append(e.ref)
            if refs:
                jax.block_until_ready(refs)
        return moved, None

    def _h_drop_job_state(self, p):
        sm = self.sm
        if sm is not None:
            sm.unregister(sm.keys_for(p["job"]))
        return None, None


def _group_main(conn, cfg: Dict[str, Any]) -> None:
    """Worker-process entry point. The FIRST statement applies the slice
    environment — jax reads ``XLA_FLAGS`` / visibility variables at backend
    init, so nothing jax-touching may be imported before this line (this
    module keeps its own imports stdlib-only for exactly that reason)."""
    os.environ.update(cfg["env"])
    state = _ChildState(cfg)
    shm_cfg = cfg.get("shm") or {}
    transport = shmt.Transport(
        prefix=shm_cfg.get("prefix", f"pxl{os.getpid()}c"),
        enabled=bool(shm_cfg.get("enabled")),
        threshold=int(shm_cfg.get("threshold", shmt.DEFAULT_THRESHOLD)))
    try:
        _send(conn, ("ready", os.getpid()))
    except OSError:
        return
    try:
        while True:
            try:
                kind, payload = _recv(conn)
            except (EOFError, OSError):
                break                  # parent went away: exit with it
            if kind == "shutdown":
                try:
                    _send(conn, ("ok", None, None))
                except OSError:
                    pass
                break
            if kind == "shm_release":
                # fire-and-forget ack from the parent: the listed segments
                # of OUR pool were consumed and may be recycled
                transport.release(payload)
                continue
            if kind == "ping":
                try:
                    _send(conn, ("ok", payload, None))
                except OSError:
                    break
                continue
            reply_segs: List[str] = []
            try:
                if transport.enabled and shmt.has_refs(payload):
                    # view-kind handlers consume before replying; every
                    # other kind gets owning copies (results may be kept)
                    payload = transport.decode(
                        payload, copy=kind not in _VIEW_KINDS)
                result, extra = state.handle(kind, payload)
                result, reply_segs = transport.encode(result)
                reply = ("ok", result, extra)
            except BaseException as e:  # noqa: BLE001 - surface to parent
                reply = ("err", f"{type(e).__name__}: {e}",
                         traceback.format_exc())
            try:
                _send(conn, reply)
            except (OSError, pickle.PicklingError) as e:
                # an unpicklable result must fail the one op, not kill the
                # channel mid-frame protocol; the parent never saw the
                # descriptors, so the segments go straight back to the pool
                transport.release(reply_segs)
                try:
                    _send(conn, ("err",
                                 f"reply serialization failed: {e}", None))
                except OSError:
                    break
    finally:
        # graceful exit unlinks the child pool; after a CRASH this never
        # runs and the parent reaps by prefix instead
        transport.close()


# ------------------------------------------------------------ parent side
class GroupProcess:
    """Parent-side handle on one node group's worker process.

    The request/reply protocol is strictly serial per process, guarded by
    an RLock — per-group dispatch is already serialized by the executor's
    group locks, so the lock only orders control-plane calls (migration,
    teardown, heartbeat) against dispatch. A blocked ``recv`` releases the
    GIL: this is where cross-group overlap becomes real.

    ``start()`` returns as soon as the OS process is launched; the ready
    handshake (env applied, module imports done) is awaited lazily on the
    first call, so spawning a group under the executor lock does not stall
    the plane for the child's interpreter boot."""

    def __init__(self, group_id: int, env: Optional[Dict[str, str]] = None,
                 slice_index: int = 0, wpg_factory: Optional[str] = None,
                 node_id: Optional[str] = None, start: bool = True,
                 shm: Optional[bool] = None,
                 shm_threshold: Optional[int] = None):
        """``shm=None`` auto-enables pooled shared-memory array transport
        when the host supports it (``shm_transport.shm_available``);
        ``False`` forces the pickle path. ``shm_threshold`` is the
        per-array size (bytes) above which arrays ride shm — the default
        is the measured pickle-vs-shm crossover (BENCH_PR10.json)."""
        self.group_id = group_id
        self.env = dict(env or {})
        self.slice_index = slice_index
        self.wpg_factory = wpg_factory
        self.node_id = node_id or f"group{group_id}-proc"
        self.shm_enabled = (shmt.shm_available() if shm is None
                            else bool(shm) and shmt.shm_available())
        self.shm_threshold = (shmt.DEFAULT_THRESHOLD if shm_threshold is None
                              else int(shm_threshold))
        self._transport: Optional[shmt.Transport] = None
        self._child_prefix = ""
        # child segment names observed on this channel: the reap fallback
        # where there is no scannable /dev/shm directory
        self._seen_child_segs: set = set()
        self._lock = threading.RLock()
        self._conn = None
        self._proc = None
        self._ready = False
        self._broken = False
        self.spawn_count = 0
        # replayed on respawn() so proxies survive a child crash
        self._deployments: Dict[str, dict] = {}
        if start:
            self.start()

    # ----------------------------------------------------------- lifecycle
    def start(self) -> None:
        ctx = multiprocessing.get_context("spawn")   # fork is unsafe: jax + threads
        parent_conn, child_conn = ctx.Pipe(duplex=True)
        self.spawn_count += 1
        # segment names carry (parent pid, group, incarnation, side) so a
        # dead incarnation's leftovers are reapable by prefix and can never
        # collide with its replacement's
        base = f"pxl{os.getpid()}g{self.group_id}s{self.spawn_count}"
        self._child_prefix = base + "c"
        self._transport = shmt.Transport(prefix=base + "p",
                                         enabled=self.shm_enabled,
                                         threshold=self.shm_threshold)
        cfg = {"group_id": self.group_id, "env": self.env,
               "slice_index": self.slice_index, "node_id": self.node_id,
               "shm": {"enabled": self.shm_enabled,
                       "threshold": self.shm_threshold,
                       "prefix": self._child_prefix}}
        proc = ctx.Process(target=_group_main, args=(child_conn, cfg),
                           name=f"plexrl-g{self.group_id}", daemon=True)
        proc.start()
        child_conn.close()             # our copy; EOF now tracks the child
        self._conn, self._proc = parent_conn, proc
        self._ready = False
        self._broken = False

    def _ensure_ready(self, timeout: float = 180.0) -> None:
        if self._ready:
            return
        if not self._conn.poll(timeout):
            raise GroupProcessError(
                f"group {self.group_id} worker process sent no ready "
                f"handshake within {timeout}s")
        kind, _pid = _recv(self._conn)
        if kind != "ready":
            raise GroupProcessError(
                f"group {self.group_id}: bad handshake {kind!r}")
        self._ready = True

    def alive(self) -> bool:
        # the broken flag covers the race where the channel already hit EOF
        # (the child called os._exit) but the OS hasn't reaped it yet —
        # health must flip dead the moment a call observed the death
        return (self._proc is not None and not self._broken
                and self._proc.is_alive())

    def pid(self) -> Optional[int]:
        return None if self._proc is None else self._proc.pid

    # ----------------------------------------------------------- protocol
    def call(self, kind: str, payload=None, timeout: Optional[float] = None,
             decode_reply: bool = True):
        """One request/reply round trip. Returns ``(value, extra)``. A
        remote exception re-raises here as RuntimeError (with the child's
        traceback attached as ``remote_traceback``); a dead child or broken
        channel raises :class:`GroupProcessError`.

        Large arrays in ``payload`` are staged through the parent's shm
        pool (descriptors on the pipe); the child's reply is their
        consumption ack. Reply arrays arrive as descriptors over the
        child's pool: ``decode_reply=True`` materialises them (owning
        copies) and acks the child; ``decode_reply=False`` hands back the
        RAW encoded value for relaying to another child — the caller then
        owns the release (:meth:`release_segments`)."""
        tr = self._transport
        req_segs: List[str] = []
        with self._lock:
            if self._conn is None:
                raise GroupProcessError(
                    f"group {self.group_id} worker process is shut down")
            try:
                self._ensure_ready()
                if tr is not None and tr.enabled \
                        and kind not in ("ping", "shutdown"):
                    payload, req_segs = tr.encode(payload)
                _send(self._conn, (kind, payload))
                if timeout is not None and not self._conn.poll(timeout):
                    # NOT released: a slow child may still read them; the
                    # pool keeps them busy until destroy (leak-safe)
                    raise GroupProcessError(
                        f"group {self.group_id} worker process did not "
                        f"reply to {kind!r} within {timeout}s")
                status, value, extra = _recv(self._conn)
                # the reply acks the request's segments: view-kind handlers
                # block on consumption before replying
                if req_segs:
                    tr.release(req_segs)
                if tr is not None and decode_reply:
                    reply_segs = shmt.refs_in(value)
                    if reply_segs:
                        self._seen_child_segs.update(reply_segs)
                        try:
                            value = tr.decode(value, copy=True)
                        finally:
                            self._release_locked(reply_segs)
                elif tr is not None:
                    self._seen_child_segs.update(shmt.refs_in(value))
            except (EOFError, OSError) as e:
                self._broken = True
                if req_segs:       # dead child: no reader can arrive
                    tr.release(req_segs)
                raise GroupProcessError(
                    f"group {self.group_id} worker process died "
                    f"(pid {self.pid()}, exitcode "
                    f"{None if self._proc is None else self._proc.exitcode}) "
                    f"during {kind!r}") from e
        if status == "err":
            err = RuntimeError(f"[group {self.group_id} process] {value}")
            err.remote_traceback = extra
            if extra:
                logger.debug("group %d remote traceback:\n%s",
                             self.group_id, extra)
            raise err
        return value, extra

    def _release_locked(self, names: List[str]) -> None:
        """Fire-and-forget ``shm_release`` to the child (lock held)."""
        try:
            _send(self._conn, ("shm_release", list(names)))
        except OSError:
            self._broken = True    # reaped by prefix at terminate/respawn

    def release_segments(self, names) -> None:
        """Ack child-pool segments consumed outside :meth:`call` (relayed
        sync/migrate payloads). Tolerates a dead or shut-down child — its
        leftovers are reaped by prefix instead."""
        names = list(names)
        if not names:
            return
        with self._lock:
            if self._conn is None or self._broken:
                return
            self._release_locked(names)

    def ping(self, timeout: float = 5.0) -> Optional[float]:
        """Liveness heartbeat: round-trip latency in seconds, or None when
        the child is alive but busy executing (the protocol lock is held by
        a dispatch thread). Raises :class:`GroupProcessError` when dead."""
        if not self.alive():
            raise GroupProcessError(
                f"group {self.group_id} worker process is not alive "
                f"(exitcode {None if self._proc is None else self._proc.exitcode})")
        if not self._lock.acquire(timeout=timeout):
            return None                # mid-execute: occupied, not dead
        try:
            nonce = next(_nonce)
            t0 = time.monotonic()
            value, _ = self.call("ping", nonce, timeout=timeout)
            if value != nonce:
                raise GroupProcessError(
                    f"group {self.group_id}: heartbeat nonce mismatch")
            return time.monotonic() - t0
        finally:
            self._lock.release()

    # --------------------------------------------------------- deployments
    def create_deployment(self, spec, factory: Optional[str] = None) -> None:
        payload = {"spec": spec,
                   "factory": factory if factory is not None
                   else self.wpg_factory}
        self.call("create_deployment", payload)
        self._deployments[spec.deployment_id] = payload

    def drop_deployment(self, dep_id: str) -> None:
        self._deployments.pop(dep_id, None)
        try:
            self.call("drop_deployment", {"dep": dep_id})
        except GroupProcessError:
            pass                       # dead child holds nothing to drop

    # ------------------------------------------------- shutdown / respawn
    def shutdown(self, timeout: float = 10.0) -> None:
        """Graceful stop (protocol shutdown + join), escalating to
        terminate/kill. Safe to call twice and on a dead child."""
        proc = self._proc
        if proc is None:
            return
        if proc.is_alive() and self._lock.acquire(timeout=timeout):
            try:
                _send(self._conn, ("shutdown", None))
                if self._conn.poll(timeout):
                    _recv(self._conn)
            except (EOFError, OSError):
                pass
            finally:
                self._lock.release()
        proc.join(timeout=timeout)
        self._terminate()

    def _terminate(self) -> None:
        proc, conn = self._proc, self._conn
        self._proc = self._conn = None
        self._ready = False
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass
        if proc is not None:
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5.0)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=5.0)
        self._reap_shm()

    def _reap_shm(self) -> None:
        """Drop every shared-memory segment of the (now dead) incarnation:
        the parent pool is unlinked outright, and the child's leftovers —
        its free pool plus any in-flight reply segments it never got to
        release — are swept by name prefix. Runs after the process is
        gone, so nothing can be mid-read. A graceful child already
        unlinked its own pool; the sweep then finds nothing."""
        tr, self._transport = self._transport, None
        if tr is not None:
            tr.close()
        if self._child_prefix:
            reaped = shmt.reap_prefix(self._child_prefix,
                                      tracked=self._seen_child_segs)
            if reaped:
                logger.warning(
                    "group %d: reaped %d orphaned shm segment(s) from dead "
                    "worker process", self.group_id, len(reaped))
        self._seen_child_segs.clear()

    def sweep_spill_files(self) -> List[str]:
        """Unlink orphaned migration spill files (``export__*.npy``) in the
        dead child's disk-spill directory. Spills are transfer-scoped: a
        completed import consumed them and a failed transfer's parent-side
        cleanup removed them, so anything still here belonged to an
        in-flight transfer of a crashed process."""
        spill_dir = os.path.join("/tmp", f"plexrl_{self.node_id}")
        removed = []
        for path in glob.glob(os.path.join(spill_dir, "export__*.npy")):
            try:
                os.unlink(path)
                removed.append(path)
            except OSError:
                pass
        if removed:
            logger.warning("group %d: swept %d orphaned spill file(s)",
                           self.group_id, len(removed))
        return removed

    def respawn(self) -> None:
        """Replace a dead (or wedged) worker process in place: fresh
        process on the same handle, registered deployments replayed, so
        existing :class:`WPGProxy` objects stay valid. Managed state is
        LOST — device-failure semantics; jobs re-init or restore from a
        checkpoint. Billing survives in the parent-side ExecLog mirrors.
        The dead incarnation's shm segments and spill files are reaped
        before the replacement starts, so a crash-looping group cannot
        accrete ``/dev/shm`` or ``/tmp`` residue."""
        with self._lock:
            self._terminate()
            self.sweep_spill_files()
            self.start()
            for payload in self._deployments.values():
                self.call("create_deployment", payload)


class StateManagerProxy:
    """Parent-side view of a group process's StateManager: the narrow
    surface the Router's transition / teardown / retire / migration code
    reads, forwarded over the pipe. ``mesh_slice`` is the PARENT's leased
    slice (domain maps and env derivation); the authoritative entry table
    lives in the child.

    Lifecycle calls (``keys_for`` / ``unregister`` / ``entries``) tolerate
    a dead child — teardown of a crashed group must complete, not raise —
    while dispatch-path stats stay strict so a dead group fails ops fast
    (and the failure poisons dependents through the normal path)."""

    def __init__(self, gp: GroupProcess, mesh_slice=None,
                 node_id: Optional[str] = None):
        self.gp = gp
        self.mesh_slice = mesh_slice
        self.node_id = node_id or gp.node_id
        self.last_migrate: Optional[Dict[str, Any]] = None

    # ------------------------------------------------- dispatch-path stats
    def job_bytes(self, job_id: str) -> int:
        return self.gp.call("job_bytes", {"job": job_id})[0]

    def load_time_estimate(self, nbytes: int) -> float:
        return self.gp.call("load_estimate", {"nbytes": int(nbytes)})[0]

    def offload_time_estimate(self, nbytes: int) -> float:
        return self.gp.call("offload_estimate", {"nbytes": int(nbytes)})[0]

    # ----------------------------------------------------------- lifecycle
    def keys_for(self, job_id: str, prefix=None) -> List[str]:
        try:
            return self.gp.call("keys_for",
                                {"job": job_id, "prefix": prefix})[0]
        except GroupProcessError:
            return []

    def unregister(self, keys) -> None:
        keys = list(keys)
        if not keys:
            return
        try:
            self.gp.call("unregister", {"keys": keys})
        except GroupProcessError:
            logger.warning("group %d process dead; dropping unregister of "
                           "%d keys", self.gp.group_id, len(keys))

    @property
    def entries(self) -> Dict[str, None]:
        """Key view only (truthiness + key iteration — what retire_group
        reads); per-entry tier state never leaves the child."""
        try:
            return dict.fromkeys(self.gp.call("all_keys", None)[0])
        except GroupProcessError:
            return {}

    # ----------------------------------------------------------- migration
    def migrate(self, job_id: str, dst: "StateManagerProxy",
                max_inline_bytes: Optional[int] = None) -> int:
        """Cross-process migration: export in the source child, import in
        the destination child (re-laid-out on ITS slice), then drop the
        source copy. With shm transport the export's arrays land in the
        source child's segment pool and the parent RELAYS the descriptors
        to the importer untouched — written once, read (``device_put``)
        once, both in children; the old ``export__*.npy`` disk-spill tier
        only engages when shm is off (``max_inline_bytes`` then defaults
        to 64 MiB per entry). Transactional like the in-process path: a
        failed or crashed import leaves the source the sole owner
        (``import_state`` rolls back its staged entries) and the parent
        deletes the transfer's spill files — on success the importer
        consumed them, on failure nobody will."""
        if not isinstance(dst, StateManagerProxy):
            raise RuntimeError(
                "process-plane migration needs both groups in process mode")
        if max_inline_bytes is None:
            # shm replaces the same-host disk-spill tier entirely
            max_inline_bytes = (1 << 62) if self.gp.shm_enabled else 64 << 20
        t0 = time.monotonic()
        payload, _ = self.gp.call(
            "migrate_export", {"job": job_id, "max_inline": max_inline_bytes},
            decode_reply=False)
        segs = shmt.refs_in(payload)
        try:
            moved, _ = dst.gp.call("migrate_import", {"payload": payload})
        except BaseException:
            # import never committed (remote rollback or child death): the
            # transfer's spill files are orphans now — ours to delete
            _unlink_spills(payload)
            raise
        finally:
            # the importer consumed (blocked on device_put) before its
            # reply — or died; either way the source segments are done
            self.gp.release_segments(segs)
        self.gp.call("drop_job_state", {"job": job_id})
        cross = (self.mesh_slice is not None and dst.mesh_slice is not None
                 and self.mesh_slice.devices != dst.mesh_slice.devices)
        self.last_migrate = {"bytes": moved,
                             "seconds": time.monotonic() - t0,
                             "cross_mesh": cross,
                             "keys": len(payload["entries"])}
        return moved


class WPGProxy:
    """What ``Router.wpgs[dep]`` holds in process mode. Forwards the WPG
    surface over the group's pipe so every Router code path — dispatch,
    context switching, teardown, billing, migration rehome — runs
    unchanged against it."""

    def __init__(self, spec, sm: StateManagerProxy):
        from repro.core.worker import ExecLog   # parent side: jax is up
        self.spec = spec
        self._sm = sm
        # LOCAL billing mirror: append-on-completion means a child crash
        # cannot lose entries for ops that already finished (conservation)
        self.exec_log = ExecLog()
        sm.gp.create_deployment(spec)

    # ----------------------------------------------------------- bindings
    @property
    def gp(self) -> GroupProcess:
        return self._sm.gp

    @property
    def job_prefix(self) -> str:
        return f"{self.spec.job_id}:{self.spec.deployment_id}"

    @property
    def mesh_slice(self):
        return self._sm.mesh_slice

    @property
    def sm(self) -> StateManagerProxy:
        return self._sm

    @sm.setter
    def sm(self, new_sm: StateManagerProxy):
        """Migration rehome (``Router.migrate_job`` does ``wpg.sm = dst``):
        re-create the deployment's WPG in the destination child — its
        StateManager already holds the imported entries under the same
        keys — and drop the source child's object."""
        if new_sm is self._sm:
            return
        old_gp = self._sm.gp
        new_sm.gp.create_deployment(self.spec)
        if new_sm.gp is not old_gp:
            old_gp.drop_deployment(self.spec.deployment_id)
        self._sm = new_sm

    # ------------------------------------------------------- WPG protocol
    def resident(self) -> bool:
        return self.gp.call("resident", {"dep": self.spec.deployment_id})[0]

    def ensure_resident(self) -> float:
        return self.gp.call("ensure_resident",
                            {"dep": self.spec.deployment_id})[0]

    def offload(self, to=None) -> float:
        tier = 1 if to is None else int(to)
        return self.gp.call("offload", {"dep": self.spec.deployment_id,
                                        "tier": tier})[0]

    def execute(self, qop):
        """Proxy one admitted op into the child. The caller (Router
        dispatch) already spliced future args, so everything shipped is
        plain data. SYNC_WEIGHTS carries a WPG argument: same-child targets
        go as a dep-id marker; cross-child targets are orchestrated here
        as sync_export (source child, host numpy) + store_params (target
        child, device_put on its own shardings)."""
        args = tuple(qop.args)
        if qop.op.value == "sync_weights" and args \
                and isinstance(args[0], WPGProxy):
            target = args[0]
            if target.gp is not self.gp:
                return self._sync_cross_process(target)
            args = (("__dep__", target.spec.deployment_id),) + args[1:]
        payload = {"dep": qop.deployment_id, "req_id": qop.req_id,
                   "job_id": qop.job_id, "op": qop.op.value,
                   "args": args, "kwargs": dict(qop.kwargs)}
        try:
            result, entry = self.gp.call("execute", payload)
        except GroupProcessError as e:
            raise RuntimeError(
                f"group {self.gp.group_id} worker process died executing "
                f"op {qop.req_id} ({qop.op.value})") from e
        if entry is not None:
            self.exec_log.append(tuple(entry))
        return result

    def _sync_cross_process(self, target: "WPGProxy"):
        """Cross-child weight sync as a descriptor relay: the source child
        writes its host params once into ITS shm pool (``sync_export``
        reply), the parent forwards the descriptors — never touching the
        bytes — and the target child ``device_put``s straight from the
        mapped views (``store_params`` blocks before replying). The reply
        triggers the release back to the source pool; a target that dies
        mid-store still releases (or, source-dead, the segments are reaped
        by prefix at respawn)."""
        t0 = time.monotonic()
        tree, _ = self.gp.call("sync_export",
                               {"dep": self.spec.deployment_id},
                               decode_reply=False)
        segs = shmt.refs_in(tree)
        try:
            target.gp.call("store_params",
                           {"dep": target.spec.deployment_id, "tree": tree})
        finally:
            self.gp.release_segments(segs)
        synced = self._sm.job_bytes(self.job_prefix)
        self.exec_log.append(("sync_weights", time.monotonic() - t0))
        return {"synced_bytes": synced}

    # ------------------------------------------------------------ teardown
    def close(self) -> None:
        """Drop the child-side WPG object (Router.teardown calls this after
        the managed state is unregistered)."""
        self.gp.drop_deployment(self.spec.deployment_id)
