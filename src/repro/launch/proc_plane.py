"""Process plane: per-group worker processes with an IPC dispatch protocol.

Thread-mode dispatch workers share one interpreter, so two groups' jit
dispatches serialize on the GIL even when the device plane gives them
disjoint ``MeshSlice``\\ s. The process plane makes cross-group overlap real
wall-clock parallelism on one host: each node group's WPGs live in a
separate OS process bound to the group's device slice, and the Router's
dispatch protocol crosses an IPC boundary instead of a method call.

Pieces
------
- :class:`GroupProcess` — parent-side handle on one group's worker process.
  Spawned (never forked: jax + threads make fork unsafe) with an
  environment derived from the group's slice
  (:func:`repro.launch.mesh.env_for_slice` — ``XLA_FLAGS`` /
  ``JAX_VISIBLE_DEVICES`` applied in the child BEFORE jax imports), talking
  a length-prefixed pickle protocol over a ``multiprocessing`` duplex pipe:
  ``create_deployment`` / ``execute`` / ``migrate_export`` /
  ``migrate_import`` / ``sync_export`` / ``shutdown`` / ``ping`` (the
  liveness heartbeat). ``respawn()`` replaces a dead child in place and
  replays its deployment registrations.
- :class:`WPGProxy` — what ``Router.wpgs[dep]`` holds in process mode: the
  WorkerProcessGroup surface dispatch, teardown, billing and migration
  touch, forwarded over the pipe. Each completed ``execute`` reply carries
  the child's ``(op, seconds)`` log entry, which the proxy appends to a
  LOCAL :class:`~repro.core.worker.ExecLog` mirror — billing cursors read
  the standard ring, and completed work stays billed even if the child
  later dies mid-op.
- :class:`StateManagerProxy` — the group StateManager surface the Router
  reads (job bytes, setup-cost estimates, keys, unregister), plus
  cross-process :meth:`StateManagerProxy.migrate` composed from the
  child-side ``StateManager.export_state`` / ``import_state`` pair
  (host-staged arrays over the pipe, disk-tier fallback for large entries).

The parent thread blocking in ``recv`` releases the GIL, so per-group
dispatch threads proxying into different children genuinely overlap.

This module imports ONLY the stdlib at module level: a spawned child
imports it before applying its device environment, so any transitive jax
import here would bind the child to the parent's device world. jax-touching
imports (worker, state_manager, mesh) happen lazily, after the env is set.
"""
from __future__ import annotations

import importlib
import itertools
import logging
import multiprocessing
import os
import pickle
import struct
import sys
import threading
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

logger = logging.getLogger(__name__)

_LEN = struct.Struct("!I")
_nonce = itertools.count(1)


class GroupProcessError(RuntimeError):
    """The group's worker process is dead or the channel broke mid-call."""


# ------------------------------------------------------------ wire format
def _send(conn, obj) -> None:
    """One frame: a 4-byte big-endian length prefix + the pickled message.
    ``send_bytes`` keeps the frame atomic on the pipe; the explicit prefix
    lets the receiver reject a truncated or corrupted frame instead of
    unpickling garbage."""
    buf = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    conn.send_bytes(_LEN.pack(len(buf)) + buf)


def _recv(conn):
    raw = conn.recv_bytes()
    if len(raw) < _LEN.size:
        raise EOFError("truncated frame (no length prefix)")
    (n,) = _LEN.unpack_from(raw)
    if len(raw) - _LEN.size != n:
        raise EOFError(
            f"frame length mismatch: prefix says {n}, got {len(raw) - _LEN.size}")
    return pickle.loads(raw[_LEN.size:])


def _resolve_factory(ref: Optional[str]):
    """Factories cross the spawn boundary by NAME ("module:callable"), not
    by pickle — a lambda in a test module would not survive spawn. None
    resolves to the real WorkerProcessGroup (imports jax, in the child,
    after the device env is applied)."""
    if ref is None:
        from repro.core.worker import WorkerProcessGroup
        return WorkerProcessGroup
    mod, _, name = ref.partition(":")
    if not name:
        raise ValueError(f"factory ref {ref!r} is not 'module:callable'")
    return getattr(importlib.import_module(mod), name)


def _to_host(obj):
    """Stage a result tree to host numpy for the reply pickle. Only does
    work when jax is actually loaded in this process — lite stub children
    never import it."""
    if "jax" not in sys.modules:
        return obj
    import jax
    import numpy as np

    def conv(x):
        return np.asarray(jax.device_get(x)) if isinstance(x, jax.Array) else x

    return jax.tree.map(conv, obj)


# ------------------------------------------------------------- child side
class _LiteSM:
    """Featherweight StateManager stand-in for stub factories
    (``needs_state_manager = False`` on the factory): keeps the child
    jax-free, so a stub group process spawns in ~100 ms."""

    mesh_slice = None

    def __init__(self):
        self.entries: Dict[str, Any] = {}

    def job_bytes(self, job_id: str) -> int:
        return 0

    def load_time_estimate(self, nbytes: int) -> float:
        return 0.0

    def offload_time_estimate(self, nbytes: int) -> float:
        return 0.0

    def keys_for(self, job_id: str, prefix=None) -> list:
        return []

    def unregister(self, keys) -> None:
        pass


class _ChildState:
    """Everything the group's worker process owns: its (lazily created)
    StateManager bound to a mesh over ALL the devices the child can see —
    which, by env construction, IS the group's slice — and one real WPG per
    registered deployment."""

    def __init__(self, cfg: Dict[str, Any]):
        self.cfg = cfg
        self.wpgs: Dict[str, Any] = {}
        self._sm = None
        self._lite: Optional[_LiteSM] = None

    @property
    def sm(self):
        return self._sm if self._sm is not None else self._lite

    def _state_manager(self, needs_real: bool):
        if not needs_real:
            if self._lite is None:
                self._lite = _LiteSM()
            return self._lite
        if self._sm is None:
            import jax

            from repro.core.state_manager import StateManager
            from repro.launch.mesh import MeshSlice, _slice_mesh

            sm = StateManager(node_id=self.cfg["node_id"])
            devs = tuple(jax.devices())
            sm.mesh_slice = MeshSlice(index=self.cfg["slice_index"],
                                      devices=devs, mesh=_slice_mesh(devs))
            self._sm = sm
        return self._sm

    # ---------------------------------------------------------- handlers
    def handle(self, kind: str, payload) -> Tuple[Any, Any]:
        return getattr(self, f"_h_{kind}")(payload)

    def _h_create_deployment(self, p):
        factory = _resolve_factory(p["factory"])
        sm = self._state_manager(getattr(factory, "needs_state_manager", True))
        self.wpgs[p["spec"].deployment_id] = factory(p["spec"], sm)
        return None, None

    def _h_drop_deployment(self, p):
        self.wpgs.pop(p["dep"], None)
        return None, None

    def _h_execute(self, p):
        from repro.core import api

        wpg = self.wpgs[p["dep"]]
        op = api.Op(p["op"])
        args = tuple(p["args"])
        if (op is api.Op.SYNC_WEIGHTS and args
                and isinstance(args[0], tuple) and len(args[0]) == 2
                and args[0][0] == "__dep__"):
            # same-child weight sync: the dep-id marker resolves to the
            # co-resident target WPG (cross-child syncs never reach here —
            # WPGProxy orchestrates sync_export/store_params instead)
            args = (self.wpgs[args[0][1]],) + args[1:]
        qop = api.QueuedOperation(
            req_id=p["req_id"], deployment_id=p["dep"], job_id=p["job_id"],
            op=op, args=args, kwargs=dict(p["kwargs"]))
        t0 = time.monotonic()
        result = wpg.execute(qop)
        return _to_host(result), (op.value, time.monotonic() - t0)

    def _h_resident(self, p):
        return self.wpgs[p["dep"]].resident(), None

    def _h_ensure_resident(self, p):
        return self.wpgs[p["dep"]].ensure_resident(), None

    def _h_offload(self, p):
        from repro.core.state_manager import Tier
        return self.wpgs[p["dep"]].offload(Tier(p["tier"])), None

    def _h_sync_export(self, p):
        return self.wpgs[p["dep"]].host_params(), None

    def _h_store_params(self, p):
        wpg = self.wpgs[p["dep"]]
        tree = p["tree"]
        shardings = wpg.param_shardings() \
            if hasattr(wpg, "param_shardings") else None
        if shardings is not None:
            import jax
            tree = jax.tree.map(jax.device_put, tree, shardings)
        wpg._store(params=tree)
        return None, None

    def _h_job_bytes(self, p):
        return (0 if self.sm is None else self.sm.job_bytes(p["job"])), None

    def _h_load_estimate(self, p):
        sm = self.sm
        return (0.0 if sm is None
                else sm.load_time_estimate(p["nbytes"])), None

    def _h_offload_estimate(self, p):
        sm = self.sm
        return (0.0 if sm is None
                else sm.offload_time_estimate(p["nbytes"])), None

    def _h_keys_for(self, p):
        sm = self.sm
        return ([] if sm is None
                else list(sm.keys_for(p["job"], p.get("prefix")))), None

    def _h_all_keys(self, p):
        return ([] if self.sm is None else list(self.sm.entries)), None

    def _h_unregister(self, p):
        if self.sm is not None:
            self.sm.unregister(p["keys"])
        return None, None

    def _h_migrate_export(self, p):
        sm = self._state_manager(True)
        return sm.export_state(p["job"],
                               max_inline_bytes=p["max_inline"]), None

    def _h_migrate_import(self, p):
        sm = self._state_manager(True)
        return sm.import_state(p["payload"]), None

    def _h_drop_job_state(self, p):
        sm = self.sm
        if sm is not None:
            sm.unregister(sm.keys_for(p["job"]))
        return None, None


def _group_main(conn, cfg: Dict[str, Any]) -> None:
    """Worker-process entry point. The FIRST statement applies the slice
    environment — jax reads ``XLA_FLAGS`` / visibility variables at backend
    init, so nothing jax-touching may be imported before this line (this
    module keeps its own imports stdlib-only for exactly that reason)."""
    os.environ.update(cfg["env"])
    state = _ChildState(cfg)
    try:
        _send(conn, ("ready", os.getpid()))
    except OSError:
        return
    while True:
        try:
            kind, payload = _recv(conn)
        except (EOFError, OSError):
            break                      # parent went away: exit with it
        if kind == "shutdown":
            try:
                _send(conn, ("ok", None, None))
            except OSError:
                pass
            break
        if kind == "ping":
            try:
                _send(conn, ("ok", payload, None))
            except OSError:
                break
            continue
        try:
            result, extra = state.handle(kind, payload)
            reply = ("ok", result, extra)
        except BaseException as e:  # noqa: BLE001 - surface to the parent
            reply = ("err", f"{type(e).__name__}: {e}",
                     traceback.format_exc())
        try:
            _send(conn, reply)
        except (OSError, pickle.PicklingError) as e:
            # an unpicklable result must fail the one op, not kill the
            # channel mid-frame protocol
            try:
                _send(conn, ("err", f"reply serialization failed: {e}", None))
            except OSError:
                break


# ------------------------------------------------------------ parent side
class GroupProcess:
    """Parent-side handle on one node group's worker process.

    The request/reply protocol is strictly serial per process, guarded by
    an RLock — per-group dispatch is already serialized by the executor's
    group locks, so the lock only orders control-plane calls (migration,
    teardown, heartbeat) against dispatch. A blocked ``recv`` releases the
    GIL: this is where cross-group overlap becomes real.

    ``start()`` returns as soon as the OS process is launched; the ready
    handshake (env applied, module imports done) is awaited lazily on the
    first call, so spawning a group under the executor lock does not stall
    the plane for the child's interpreter boot."""

    def __init__(self, group_id: int, env: Optional[Dict[str, str]] = None,
                 slice_index: int = 0, wpg_factory: Optional[str] = None,
                 node_id: Optional[str] = None, start: bool = True):
        self.group_id = group_id
        self.env = dict(env or {})
        self.slice_index = slice_index
        self.wpg_factory = wpg_factory
        self.node_id = node_id or f"group{group_id}-proc"
        self._lock = threading.RLock()
        self._conn = None
        self._proc = None
        self._ready = False
        self._broken = False
        self.spawn_count = 0
        # replayed on respawn() so proxies survive a child crash
        self._deployments: Dict[str, dict] = {}
        if start:
            self.start()

    # ----------------------------------------------------------- lifecycle
    def start(self) -> None:
        ctx = multiprocessing.get_context("spawn")   # fork is unsafe: jax + threads
        parent_conn, child_conn = ctx.Pipe(duplex=True)
        cfg = {"group_id": self.group_id, "env": self.env,
               "slice_index": self.slice_index, "node_id": self.node_id}
        proc = ctx.Process(target=_group_main, args=(child_conn, cfg),
                           name=f"plexrl-g{self.group_id}", daemon=True)
        proc.start()
        child_conn.close()             # our copy; EOF now tracks the child
        self._conn, self._proc = parent_conn, proc
        self._ready = False
        self._broken = False
        self.spawn_count += 1

    def _ensure_ready(self, timeout: float = 180.0) -> None:
        if self._ready:
            return
        if not self._conn.poll(timeout):
            raise GroupProcessError(
                f"group {self.group_id} worker process sent no ready "
                f"handshake within {timeout}s")
        kind, _pid = _recv(self._conn)
        if kind != "ready":
            raise GroupProcessError(
                f"group {self.group_id}: bad handshake {kind!r}")
        self._ready = True

    def alive(self) -> bool:
        # the broken flag covers the race where the channel already hit EOF
        # (the child called os._exit) but the OS hasn't reaped it yet —
        # health must flip dead the moment a call observed the death
        return (self._proc is not None and not self._broken
                and self._proc.is_alive())

    def pid(self) -> Optional[int]:
        return None if self._proc is None else self._proc.pid

    # ----------------------------------------------------------- protocol
    def call(self, kind: str, payload=None, timeout: Optional[float] = None):
        """One request/reply round trip. Returns ``(value, extra)``. A
        remote exception re-raises here as RuntimeError (with the child's
        traceback attached as ``remote_traceback``); a dead child or broken
        channel raises :class:`GroupProcessError`."""
        with self._lock:
            if self._conn is None:
                raise GroupProcessError(
                    f"group {self.group_id} worker process is shut down")
            try:
                self._ensure_ready()
                _send(self._conn, (kind, payload))
                if timeout is not None and not self._conn.poll(timeout):
                    raise GroupProcessError(
                        f"group {self.group_id} worker process did not "
                        f"reply to {kind!r} within {timeout}s")
                status, value, extra = _recv(self._conn)
            except (EOFError, OSError) as e:
                self._broken = True
                raise GroupProcessError(
                    f"group {self.group_id} worker process died "
                    f"(pid {self.pid()}, exitcode "
                    f"{None if self._proc is None else self._proc.exitcode}) "
                    f"during {kind!r}") from e
        if status == "err":
            err = RuntimeError(f"[group {self.group_id} process] {value}")
            err.remote_traceback = extra
            if extra:
                logger.debug("group %d remote traceback:\n%s",
                             self.group_id, extra)
            raise err
        return value, extra

    def ping(self, timeout: float = 5.0) -> Optional[float]:
        """Liveness heartbeat: round-trip latency in seconds, or None when
        the child is alive but busy executing (the protocol lock is held by
        a dispatch thread). Raises :class:`GroupProcessError` when dead."""
        if not self.alive():
            raise GroupProcessError(
                f"group {self.group_id} worker process is not alive "
                f"(exitcode {None if self._proc is None else self._proc.exitcode})")
        if not self._lock.acquire(timeout=timeout):
            return None                # mid-execute: occupied, not dead
        try:
            nonce = next(_nonce)
            t0 = time.monotonic()
            value, _ = self.call("ping", nonce, timeout=timeout)
            if value != nonce:
                raise GroupProcessError(
                    f"group {self.group_id}: heartbeat nonce mismatch")
            return time.monotonic() - t0
        finally:
            self._lock.release()

    # --------------------------------------------------------- deployments
    def create_deployment(self, spec, factory: Optional[str] = None) -> None:
        payload = {"spec": spec,
                   "factory": factory if factory is not None
                   else self.wpg_factory}
        self.call("create_deployment", payload)
        self._deployments[spec.deployment_id] = payload

    def drop_deployment(self, dep_id: str) -> None:
        self._deployments.pop(dep_id, None)
        try:
            self.call("drop_deployment", {"dep": dep_id})
        except GroupProcessError:
            pass                       # dead child holds nothing to drop

    # ------------------------------------------------- shutdown / respawn
    def shutdown(self, timeout: float = 10.0) -> None:
        """Graceful stop (protocol shutdown + join), escalating to
        terminate/kill. Safe to call twice and on a dead child."""
        proc = self._proc
        if proc is None:
            return
        if proc.is_alive() and self._lock.acquire(timeout=timeout):
            try:
                _send(self._conn, ("shutdown", None))
                if self._conn.poll(timeout):
                    _recv(self._conn)
            except (EOFError, OSError):
                pass
            finally:
                self._lock.release()
        proc.join(timeout=timeout)
        self._terminate()

    def _terminate(self) -> None:
        proc, conn = self._proc, self._conn
        self._proc = self._conn = None
        self._ready = False
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass
        if proc is not None:
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5.0)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=5.0)

    def respawn(self) -> None:
        """Replace a dead (or wedged) worker process in place: fresh
        process on the same handle, registered deployments replayed, so
        existing :class:`WPGProxy` objects stay valid. Managed state is
        LOST — device-failure semantics; jobs re-init or restore from a
        checkpoint. Billing survives in the parent-side ExecLog mirrors."""
        with self._lock:
            self._terminate()
            self.start()
            for payload in self._deployments.values():
                self.call("create_deployment", payload)


class StateManagerProxy:
    """Parent-side view of a group process's StateManager: the narrow
    surface the Router's transition / teardown / retire / migration code
    reads, forwarded over the pipe. ``mesh_slice`` is the PARENT's leased
    slice (domain maps and env derivation); the authoritative entry table
    lives in the child.

    Lifecycle calls (``keys_for`` / ``unregister`` / ``entries``) tolerate
    a dead child — teardown of a crashed group must complete, not raise —
    while dispatch-path stats stay strict so a dead group fails ops fast
    (and the failure poisons dependents through the normal path)."""

    def __init__(self, gp: GroupProcess, mesh_slice=None,
                 node_id: Optional[str] = None):
        self.gp = gp
        self.mesh_slice = mesh_slice
        self.node_id = node_id or gp.node_id
        self.last_migrate: Optional[Dict[str, Any]] = None

    # ------------------------------------------------- dispatch-path stats
    def job_bytes(self, job_id: str) -> int:
        return self.gp.call("job_bytes", {"job": job_id})[0]

    def load_time_estimate(self, nbytes: int) -> float:
        return self.gp.call("load_estimate", {"nbytes": int(nbytes)})[0]

    def offload_time_estimate(self, nbytes: int) -> float:
        return self.gp.call("offload_estimate", {"nbytes": int(nbytes)})[0]

    # ----------------------------------------------------------- lifecycle
    def keys_for(self, job_id: str, prefix=None) -> List[str]:
        try:
            return self.gp.call("keys_for",
                                {"job": job_id, "prefix": prefix})[0]
        except GroupProcessError:
            return []

    def unregister(self, keys) -> None:
        keys = list(keys)
        if not keys:
            return
        try:
            self.gp.call("unregister", {"keys": keys})
        except GroupProcessError:
            logger.warning("group %d process dead; dropping unregister of "
                           "%d keys", self.gp.group_id, len(keys))

    @property
    def entries(self) -> Dict[str, None]:
        """Key view only (truthiness + key iteration — what retire_group
        reads); per-entry tier state never leaves the child."""
        try:
            return dict.fromkeys(self.gp.call("all_keys", None)[0])
        except GroupProcessError:
            return {}

    # ----------------------------------------------------------- migration
    def migrate(self, job_id: str, dst: "StateManagerProxy",
                max_inline_bytes: int = 64 << 20) -> int:
        """Cross-process migration: export in the source child (host-staged
        arrays; entries above ``max_inline_bytes`` spill to the disk tier
        and travel by path), import in the destination child (re-laid-out
        on ITS slice), then drop the source copy. Transactional like the
        in-process path: a failed import leaves the source the sole owner
        (``import_state`` rolls back its staged entries)."""
        if not isinstance(dst, StateManagerProxy):
            raise RuntimeError(
                "process-plane migration needs both groups in process mode")
        t0 = time.monotonic()
        payload, _ = self.gp.call(
            "migrate_export", {"job": job_id, "max_inline": max_inline_bytes})
        moved, _ = dst.gp.call("migrate_import", {"payload": payload})
        self.gp.call("drop_job_state", {"job": job_id})
        cross = (self.mesh_slice is not None and dst.mesh_slice is not None
                 and self.mesh_slice.devices != dst.mesh_slice.devices)
        self.last_migrate = {"bytes": moved,
                             "seconds": time.monotonic() - t0,
                             "cross_mesh": cross,
                             "keys": len(payload["entries"])}
        return moved


class WPGProxy:
    """What ``Router.wpgs[dep]`` holds in process mode. Forwards the WPG
    surface over the group's pipe so every Router code path — dispatch,
    context switching, teardown, billing, migration rehome — runs
    unchanged against it."""

    def __init__(self, spec, sm: StateManagerProxy):
        from repro.core.worker import ExecLog   # parent side: jax is up
        self.spec = spec
        self._sm = sm
        # LOCAL billing mirror: append-on-completion means a child crash
        # cannot lose entries for ops that already finished (conservation)
        self.exec_log = ExecLog()
        sm.gp.create_deployment(spec)

    # ----------------------------------------------------------- bindings
    @property
    def gp(self) -> GroupProcess:
        return self._sm.gp

    @property
    def job_prefix(self) -> str:
        return f"{self.spec.job_id}:{self.spec.deployment_id}"

    @property
    def mesh_slice(self):
        return self._sm.mesh_slice

    @property
    def sm(self) -> StateManagerProxy:
        return self._sm

    @sm.setter
    def sm(self, new_sm: StateManagerProxy):
        """Migration rehome (``Router.migrate_job`` does ``wpg.sm = dst``):
        re-create the deployment's WPG in the destination child — its
        StateManager already holds the imported entries under the same
        keys — and drop the source child's object."""
        if new_sm is self._sm:
            return
        old_gp = self._sm.gp
        new_sm.gp.create_deployment(self.spec)
        if new_sm.gp is not old_gp:
            old_gp.drop_deployment(self.spec.deployment_id)
        self._sm = new_sm

    # ------------------------------------------------------- WPG protocol
    def resident(self) -> bool:
        return self.gp.call("resident", {"dep": self.spec.deployment_id})[0]

    def ensure_resident(self) -> float:
        return self.gp.call("ensure_resident",
                            {"dep": self.spec.deployment_id})[0]

    def offload(self, to=None) -> float:
        tier = 1 if to is None else int(to)
        return self.gp.call("offload", {"dep": self.spec.deployment_id,
                                        "tier": tier})[0]

    def execute(self, qop):
        """Proxy one admitted op into the child. The caller (Router
        dispatch) already spliced future args, so everything shipped is
        plain data. SYNC_WEIGHTS carries a WPG argument: same-child targets
        go as a dep-id marker; cross-child targets are orchestrated here
        as sync_export (source child, host numpy) + store_params (target
        child, device_put on its own shardings)."""
        args = tuple(qop.args)
        if qop.op.value == "sync_weights" and args \
                and isinstance(args[0], WPGProxy):
            target = args[0]
            if target.gp is not self.gp:
                return self._sync_cross_process(target)
            args = (("__dep__", target.spec.deployment_id),) + args[1:]
        payload = {"dep": qop.deployment_id, "req_id": qop.req_id,
                   "job_id": qop.job_id, "op": qop.op.value,
                   "args": args, "kwargs": dict(qop.kwargs)}
        try:
            result, entry = self.gp.call("execute", payload)
        except GroupProcessError as e:
            raise RuntimeError(
                f"group {self.gp.group_id} worker process died executing "
                f"op {qop.req_id} ({qop.op.value})") from e
        if entry is not None:
            self.exec_log.append(tuple(entry))
        return result

    def _sync_cross_process(self, target: "WPGProxy"):
        t0 = time.monotonic()
        tree, _ = self.gp.call("sync_export",
                               {"dep": self.spec.deployment_id})
        target.gp.call("store_params",
                       {"dep": target.spec.deployment_id, "tree": tree})
        synced = self._sm.job_bytes(self.job_prefix)
        self.exec_log.append(("sync_weights", time.monotonic() - t0))
        return {"synced_bytes": synced}

    # ------------------------------------------------------------ teardown
    def close(self) -> None:
        """Drop the child-side WPG object (Router.teardown calls this after
        the managed state is unregistered)."""
        self.gp.drop_deployment(self.spec.deployment_id)
