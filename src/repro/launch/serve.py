"""Batched serving driver: prefill + decode loop through the service API.

Demonstrates the rollout side of PlexRL as a standalone deployment on a
LIVE serve-mode plane: the Router's dispatch worker parks while idle,
admits each batched generate the moment it is submitted, and the client
simply blocks on the returned future — the request/response shape of a
real inference service, through the same dataflow client API the RL
controllers use.

    PYTHONPATH=src python -m repro.launch.serve --batch 8 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core.router import Router
from repro.rl import data as data_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args(argv)

    router = Router()
    spec = api.DeploymentSpec(
        deployment_id="serve", job_id="serve", model_name=args.arch,
        role="rollout",
        overrides=(
            ("num_layers", args.layers), ("d_model", args.d_model),
            ("num_heads", max(4, args.d_model // 64)),
            ("num_kv_heads", max(2, args.d_model // 128)),
            ("head_dim", 64), ("d_ff", args.d_model * 4),
            ("vocab_size", 512),
        ))
    dep = router.deploy(spec, group_id=0)

    ds = data_lib.MathDataset(seed=0)
    batches = ds.batches(args.batch, args.prompt_len)
    lat = []
    with router:                      # persistent plane: serve()...shutdown()
        dep.init(seed=0).wait(timeout=600)
        for r in range(args.rounds):
            prompts, problems = next(batches)
            t0 = time.time()
            out = dep.generate(jnp.asarray(prompts),
                               max_new_tokens=args.max_new,
                               temperature=0.7).wait(timeout=600)
            dt = time.time() - t0
            lat.append(dt)
            toks = int(np.asarray(out["alive"]).sum())
            print(f"round {r}: {dt*1000:.0f} ms, {toks} live tokens, "
                  f"{toks / dt:.1f} tok/s, sample: "
                  f"{data_lib.decode(np.asarray(out['tokens'][0]))!r}")
    print(f"mean latency {np.mean(lat)*1000:.0f} ms "
          f"(first includes jit compile)")


if __name__ == "__main__":
    main()
