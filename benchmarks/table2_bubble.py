"""Table 2 — bubble-ratio analysis across model sizes.

Two parts:
1. The paper's measured anatomy (reproduced from repro.core.traces) — the
   70-81 % training-pool idle that motivates cluster-level reclamation.
2. A live measurement on THIS machine: a tiny RLVR job runs through the
   PlexRL stack and we derive the same anatomy from the WPG execution log
   (generate vs update_actor wall time), demonstrating the measurement
   pipeline end-to-end.
"""
from __future__ import annotations

from repro.core.cluster import PlexCluster
from repro.core.controller import JobConfig
from repro.core.traces import PAPER_TABLE2, bubble_ratio

TINY = (("num_layers", 2), ("d_model", 32), ("num_heads", 4),
        ("num_kv_heads", 2), ("head_dim", 8), ("d_ff", 64),
        ("vocab_size", 64), ("tie_embeddings", True))


def measured_anatomy() -> dict:
    cluster = PlexCluster(n_groups=1)
    cluster.add_job(JobConfig(job_id="probe", model_name="qwen2-0.5b",
                              steps=3, batch_size=4, group_size=2,
                              max_new_tokens=8, seq_len=32, overrides=TINY))
    cluster.run()
    log = cluster.router.wpgs["probe-train"].exec_log
    by_op: dict[str, float] = {}
    for op, dt in log:
        by_op[op] = by_op.get(op, 0.0) + dt
    cycle = sum(by_op.values())
    train_active = by_op.get("update_actor", 0.0)
    return {"cycle": cycle, "update_actor": train_active,
            "generate": by_op.get("generate", 0.0),
            "bubble": 1.0 - train_active / max(cycle, 1e-9)}


def run() -> list[tuple[str, float, str]]:
    rows = []
    paper = {"7B": 0.8010, "30B": 0.7067, "235B": 0.8111}
    for size, e in PAPER_TABLE2.items():
        br = bubble_ratio(e)
        rows.append((f"table2/{size}/bubble_ratio", br,
                     f"paper={paper[size]:.4f}"))
        assert abs(br - paper[size]) < 0.005
    m = measured_anatomy()
    rows.append(("table2/local_probe/bubble_ratio", m["bubble"],
                 f"cycle={m['cycle']:.2f}s update={m['update_actor']:.2f}s"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
