"""Fig. 2 — MFU of (non-agent) rollout under different DP sizes.

Models the paper's §2.2 observation: large data-parallel rollout groups are
efficient only while the effective batch is high; as the long tail drains,
per-replica batch collapses and utilization falls. We draw response lengths
from a lognormal (matching RLVR's long-tailed decoding), hand samples to DP
replicas, and integrate per-GPU useful-token throughput over the rollout
window.

Output: MFU proxy (relative to a saturated replica) per DP size.
"""
from __future__ import annotations

import numpy as np


def rollout_mfu(dp_size: int, n_samples: int = 4096, seed: int = 0,
                sat_batch: int = 32, sigma: float = 0.8) -> float:
    """Fraction of saturated throughput achieved, integrated over the step.

    Each replica decodes its shard of samples concurrently; a replica's
    instantaneous efficiency is min(1, active/sat_batch). The step ends when
    the LAST replica finishes (synchronous rollout barrier).
    """
    rng = np.random.default_rng(seed)
    lengths = rng.lognormal(mean=5.0, sigma=sigma, size=n_samples)
    shards = np.array_split(rng.permutation(lengths), dp_size)
    t_end = max(s.max() for s in shards if len(s))
    # integrate each replica's efficiency over [0, t_end]
    grid = np.linspace(0, t_end, 512)
    total_eff = 0.0
    for s in shards:
        active = (s[None, :] > grid[:, None]).sum(1)
        eff = np.minimum(1.0, active / sat_batch)
        total_eff += np.trapezoid(eff, grid)
    # useful work fraction: integral of efficiency over reserved GPU-time
    return float(total_eff / (dp_size * t_end))


def run() -> list[tuple[str, float, str]]:
    rows = []
    base = None
    for dp in (4, 8, 16, 32, 64, 128):
        mfu = rollout_mfu(dp)
        base = base or mfu
        rows.append((f"fig2/rollout_mfu_dp{dp}", mfu,
                     f"rel_to_dp4={mfu/base:.3f}"))
    # the paper's qualitative claim: MFU monotonically decays with DP
    vals = [r[1] for r in rows]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:])), \
        "MFU should fall as DP grows"
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
